"""Fleet-scale cohort engine (DESIGN.md §13): chunk-streamed rounds ==
single-shot vmapped rounds BITWISE (property-tested across topologies ×
strategies × chunk sizes with straggler dropout), mid-round checkpoint
restore at a chunk boundary, the client-sampler registry, fleet EMA
telemetry, shard_map'd cohorts, and the history_cap accounting fix."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt import restore_server_state, save_server_state
from repro.core import (ClientSampler, CohortContext, FLConfig, Federation,
                        UnknownClientSamplerError, build_cohort_programs,
                        fleet_init, get_client_sampler,
                        register_client_sampler, registered_client_samplers,
                        resolve_client_sampler, unregister_client_sampler)
from repro.models.toy import init_toy_mlp, toy_batches, toy_loss, toy_units

C = 4


def _setup(n_blocks=6, d=16, hidden=32, out=4, steps=2, batch=2):
    key = jax.random.PRNGKey(0)
    params = init_toy_mlp(key, n_blocks=n_blocks, d=d, hidden=hidden,
                          out=out)
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1), n_clients=C,
                          steps=steps, batch=batch, d=d, out=out)
    return params, assign, batches


def _bf(batches):
    """Engine loader contract: (round, ids) -> the ids' rows."""
    return lambda r, ids: jax.tree_util.tree_map(
        lambda x: x[np.asarray(ids)], batches)


def _assert_trees_bitexact(a, b):
    for pa, pb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), \
            "trees diverged bitwise"


def _assert_runs_equal(ref, eng):
    _assert_trees_bitexact(ref.server.params, eng.server.params)
    _assert_trees_bitexact(ref.server.sel_history, eng.server.sel_history)
    for ra, rb in zip(ref.history, eng.history):
        assert (np.isnan(ra.loss) and np.isnan(rb.loss)) \
            or ra.loss == rb.loss
        assert ra.uplink_bytes == rb.uplink_bytes
        assert ra.n_participants == rb.n_participants
        assert ra.skipped == rb.skipped
    assert ref.comm_summary() == eng.comm_summary()


# -- the tentpole property: chunked == single-shot vmapped, BITWISE --------

@settings(max_examples=8, deadline=None)
@given(topology=st.sampled_from(["hub", "hierarchical"]),
       strategy=st.sampled_from(["uniform", "score_weighted"]),
       chunk=st.sampled_from([1, 2, 4]),
       drop=st.booleans())
def test_chunked_bitwise_equals_vmapped(topology, strategy, chunk, drop):
    """With R == C every sampler yields the identity cohort, so the
    engine's chunk-streamed rounds must reproduce the plain synchronous
    packed loop bit-for-bit: params, selection history, per-round loss
    and byte accounting — including straggler-dropped rounds."""
    params, assign, batches = _setup()
    rate = 0.3 if drop else 0.0
    fl0 = FLConfig(n_clients=C, train_fraction=0.5, strategy=strategy,
                   topology=topology, packed=True, fused_agg="off")
    ref = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl0, seed=3, dropout_rate=rate)
    ref.server.run(3, lambda r: batches)
    fl1 = dataclasses.replace(fl0, cohort_chunk=chunk, n_registered=C)
    eng = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl1, seed=3, dropout_rate=rate)
    eng.server.run(3, _bf(batches))
    _assert_runs_equal(ref, eng)


def test_engine_fit_routes_through_loader():
    """Federation.fit in engine mode streams loader.client_batches with
    ABSOLUTE round indices — equal to the plain fit on the same data."""
    from repro.data import FederatedLoader, iid_partition
    params, assign, _ = _setup()
    rng = np.random.default_rng(0)
    data = {"x": rng.normal(0, 1, (64, 16)).astype(np.float32),
            "y": rng.normal(0, 1, (64, 4)).astype(np.float32)}
    shards = iid_partition(64, C, key=1)
    loader = FederatedLoader([{k: v[s] for k, v in data.items()}
                              for s in shards], batch_size=2,
                             steps_per_round=2, key=5)
    fl0 = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                   fused_agg="off")
    ref = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl0, loader=loader, seed=2)
    ref.fit(3)
    fl1 = dataclasses.replace(fl0, cohort_chunk=2, n_registered=C)
    eng = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl1, loader=loader, seed=2)
    eng.fit(3)
    _assert_runs_equal(ref, eng)


# -- mid-round checkpoint restore at a chunk boundary ----------------------

@pytest.mark.parametrize("strategy", ["uniform", "score_weighted"])
def test_midround_restore_at_chunk_boundary(tmp_path, strategy):
    """Save after streaming 1 of 2 chunks, restore into a fresh
    Federation, finish the fit — bitwise an uninterrupted run."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, strategy=strategy,
                  topology="hub", packed=True, fused_agg="off",
                  cohort_chunk=2, n_registered=C)

    def fresh():
        return Federation(loss_fn=toy_loss, params=params, assign=assign,
                          fl=fl, seed=7, dropout_rate=0.3)

    ref = fresh()
    ref.server.run(3, _bf(batches))

    one = fresh()
    one.server.run(1, _bf(batches))
    eng = one.server.cohort_engine
    eng.begin_round()
    eng.step_chunk(_bf(batches))
    assert eng._partial["chunk"] == 1
    path = str(tmp_path / "mid")
    save_server_state(path, one.server)

    two = fresh()
    restore_server_state(path, two.server)
    eng2 = two.server.cohort_engine
    assert eng2._partial is not None and eng2._partial["chunk"] == 1
    two.server.run(2, _bf(batches))  # resumes the partial, then round 2
    _assert_runs_equal(ref, two)
    np.testing.assert_array_equal(eng2.fleet.counts,
                                  ref.server.cohort_engine.fleet.counts)


def test_cohort_ckpt_needs_engine(tmp_path):
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                  fused_agg="off", n_registered=8)
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=1)
    fed.server.run(1, _bf(batches))
    path = str(tmp_path / "ck")
    save_server_state(path, fed.server)
    plain = Federation(loss_fn=toy_loss, params=params, assign=assign,
                       fl=dataclasses.replace(fl, n_registered=0), seed=1)
    with pytest.raises(ValueError, match="cohort-engine state"):
        restore_server_state(path, plain.server)


def test_fleet_size_mismatch_rejected(tmp_path):
    params, assign, batches = _setup()

    def make(r):
        return Federation(
            loss_fn=toy_loss, params=params, assign=assign,
            fl=FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                        fused_agg="off", n_registered=r), seed=1)

    fed = make(8)
    fed.server.run(1, _bf(batches))
    path = str(tmp_path / "ck")
    save_server_state(path, fed.server)
    with pytest.raises(ValueError, match="registered"):
        restore_server_state(path, make(16).server)


# -- client-sampler registry ------------------------------------------------

def test_builtin_samplers_registered():
    assert {"uniform", "loss_proportional", "telemetry_driven"} <= \
        set(registered_client_samplers())
    assert get_client_sampler("telemetry_driven").needs_norms
    assert not get_client_sampler("uniform").needs_norms


def test_unknown_sampler_error_shares_uniform_format():
    with pytest.raises(UnknownClientSamplerError,
                       match=r"unknown client sampler 'nope'; "
                             r"registered: "):
        get_client_sampler("nope")


def test_register_unregister_roundtrip():
    @register_client_sampler
    class FirstOnly(ClientSampler):
        name = "first_only"

        def sample(self, key, ctx):
            return np.arange(ctx.cohort, dtype=np.int32)

    try:
        assert "first_only" in registered_client_samplers()
        assert isinstance(resolve_client_sampler("first_only"), FirstOnly)
    finally:
        unregister_client_sampler("first_only")
    assert "first_only" not in registered_client_samplers()


def test_resolve_defaults_to_uniform():
    s = resolve_client_sampler(None)
    assert s.name == "uniform"
    inst = get_client_sampler("loss_proportional")
    assert resolve_client_sampler(inst) is inst


@pytest.mark.parametrize("name", ["uniform", "loss_proportional",
                                  "telemetry_driven"])
def test_sampler_draw_contract(name):
    """Sorted unique in-range ids; identity when R == C (the anchor the
    bitwise property rests on); valid subsets for R > C both cold
    (no signal -> uniform) and warm (EMAs populated)."""
    s = get_client_sampler(name)
    key = jax.random.PRNGKey(11)
    ids = s.sample(key, CohortContext(C, C, fleet_init(C)))
    np.testing.assert_array_equal(ids, np.arange(C))
    fleet = fleet_init(12)
    for warm in (False, True):
        if warm:
            fleet.loss_ema[:] = np.linspace(0, 3, 12)
            fleet.norm_ema[:] = np.linspace(3, 0, 12)
            fleet.counts[:6] = 2
        ids = np.asarray(s.sample(key, CohortContext(12, C, fleet)))
        assert ids.shape == (C,) and ids.dtype == np.int32
        assert len(set(ids.tolist())) == C
        assert np.all(np.sort(ids) == ids)
        assert ids.min() >= 0 and ids.max() < 12


def test_fleet_emas_track_participation():
    """R > C: counts advance only at sampled-and-surviving ids and sum
    to the recorded participant totals; EMAs populate only where seen."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                  fused_agg="off", n_registered=10,
                  client_sampler="telemetry_driven")
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=5, dropout_rate=0.3)
    fed.server.run(4, _bf(batches))
    eng = fed.server.cohort_engine
    assert eng.fleet.round == 4
    total = sum(r.n_participants for r in fed.history)
    assert eng.fleet.counts.sum() == total
    seen = eng.fleet.counts > 0
    assert np.all(eng.fleet.loss_ema[~seen] == 0)
    assert np.any(eng.fleet.norm_ema[seen] > 0)  # needs_norms telemetry


# -- engine state-machine errors -------------------------------------------

def _engine(fl_kwargs=None, **fed_kwargs):
    params, assign, batches = _setup()
    kw = dict(n_registered=C, cohort_chunk=2)
    kw.update(fl_kwargs or {})
    fl = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                  fused_agg="off", **kw)
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=1, **fed_kwargs)
    return fed, fed.server.cohort_engine, _bf(batches)


def test_begin_twice_raises():
    _, eng, _ = _engine()
    eng.begin_round()
    with pytest.raises(RuntimeError, match="already in flight"):
        eng.begin_round()


def test_step_and_finish_out_of_order():
    _, eng, bf = _engine()
    with pytest.raises(RuntimeError, match="begin_round"):
        eng.step_chunk(bf)
    with pytest.raises(RuntimeError, match="begin_round"):
        eng.finish_round()
    eng.begin_round()
    eng.step_chunk(bf)
    with pytest.raises(RuntimeError, match="streamed 1/2"):
        eng.finish_round()
    eng.step_chunk(bf)
    with pytest.raises(RuntimeError, match="already streamed"):
        eng.step_chunk(bf)
    eng.finish_round()


def test_weights_length_validated():
    _, eng, _ = _engine()
    with pytest.raises(ValueError, match="n_clients.*or.*n_registered"):
        eng.begin_round(weights=np.ones(3))


def test_fleet_weights_gathered_to_cohort():
    fed, eng, bf = _engine({"n_registered": 8, "cohort_chunk": 0})
    wr = np.arange(1, 9, dtype=np.float32)
    p = eng.begin_round(weights=wr)
    np.testing.assert_array_equal(np.asarray(p["w"]), wr[p["ids"]])
    eng.step_chunk(bf)
    eng.finish_round()


def test_dense_full_strategy_rejected():
    params, assign, _ = _setup()
    fl = FLConfig(n_clients=C, train_fraction=1.0, strategy="full",
                  packed=True, fused_agg="off", n_registered=C)
    with pytest.raises(ValueError, match="nothing to pack"):
        Federation(loss_fn=toy_loss, params=params, assign=assign,
                   fl=fl, seed=1)


def test_run_round_rejected_in_engine_mode():
    fed, _, _ = _engine()
    with pytest.raises(RuntimeError, match="cohort-engine mode"):
        fed.server.run_round(lambda r: None)


# -- shard_map'd cohorts ----------------------------------------------------

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="client-shard tests need >= 2 XLA devices "
           "(test.sh forces 8 host devices)")


@needs_devices
def test_sharded_cohort_bitwise_equals_vmapped():
    """client_shards splits the vmapped cohort over the (client,) mesh;
    per-client rows are independent, so results are bitwise equal —
    plain loop and chunked engine alike."""
    params, assign, batches = _setup()
    fl0 = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                   fused_agg="off", strategy="score_weighted")
    ref = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl0, seed=4)
    ref.server.run(2, lambda r: batches)
    sharded = Federation(
        loss_fn=toy_loss, params=params, assign=assign,
        fl=dataclasses.replace(fl0, client_shards=2), seed=4)
    sharded.server.run(2, lambda r: batches)
    _assert_runs_equal(ref, sharded)
    both = Federation(
        loss_fn=toy_loss, params=params, assign=assign,
        fl=dataclasses.replace(fl0, client_shards=2, cohort_chunk=2,
                               n_registered=C), seed=4)
    both.server.run(2, _bf(batches))
    _assert_runs_equal(ref, both)


@needs_devices
def test_sharded_async_cohort_bitwise():
    """The buffered-async engine shares the packed cohort trace, so
    client_shards composes with async_buffer bitwise."""
    params, assign, batches = _setup()
    fl0 = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                   fused_agg="off", async_buffer=2,
                   client_delay_dist="exponential:1.0")
    a = Federation(loss_fn=toy_loss, params=params, assign=assign,
                   fl=fl0, seed=6)
    a.server.run(3, lambda w: batches)
    b = Federation(loss_fn=toy_loss, params=params, assign=assign,
                   fl=dataclasses.replace(fl0, client_shards=2), seed=6)
    b.server.run(3, lambda w: batches)
    _assert_trees_bitexact(a.server.params, b.server.params)
    for ra, rb in zip(a.history, b.history):
        assert ra.loss == rb.loss


# -- history_cap: bounded accounting, exact summaries ----------------------

def test_history_cap_bounds_retention_and_keeps_summary():
    """The satellite bugfix: a capped run retains at most cap rows of
    selection history yet reports the same comm_summary as the
    unbounded run (up to float fold order)."""
    params, assign, batches = _setup()
    fl0 = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                   fused_agg="off")
    ref = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl0, seed=9, dropout_rate=0.25)
    ref.server.run(12, lambda r: batches)
    cap = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=dataclasses.replace(fl0, history_cap=3), seed=9,
                     dropout_rate=0.25)
    cap.server.run(12, lambda r: batches)
    assert len(cap.server.sel_history) == 3
    assert len(ref.server.sel_history) == 12
    assert cap.server._sel_base == 9
    _assert_trees_bitexact(ref.server.params, cap.server.params)
    a, b = ref.comm_summary(), cap.comm_summary()
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_allclose(b[k], a[k], rtol=1e-6)


def test_history_cap_with_cohort_engine_and_ckpt(tmp_path):
    """Cap + engine compose; the folded totals survive a checkpoint
    roundtrip so a resumed run's summary stays exact."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                  fused_agg="off", n_registered=6, cohort_chunk=2,
                  history_cap=2)
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=2)
    fed.server.run(6, _bf(batches))
    assert len(fed.server.sel_history) == 2
    want = fed.comm_summary()
    path = str(tmp_path / "capped")
    save_server_state(path, fed.server)
    res = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=2)
    restore_server_state(path, res.server)
    assert res.server._sel_base == fed.server._sel_base
    assert res.comm_summary() == want
    res.server.run(2, _bf(batches))  # keeps trimming after resume
    assert len(res.server.sel_history) == 2


def test_history_cap_validation():
    with pytest.raises(ValueError, match="history_cap"):
        FLConfig(n_clients=C, train_fraction=0.5, history_cap=-1)
    with pytest.raises(ValueError, match="async_buffer"):
        FLConfig(n_clients=C, train_fraction=0.5, history_cap=2,
                 async_buffer=2)


# -- programs-level guard ---------------------------------------------------

def test_build_programs_standalone():
    """build_cohort_programs is usable outside Federation (the
    benchmark drives it directly)."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                  fused_agg="off", cohort_chunk=2)
    progs = build_cohort_programs(toy_loss, assign, fl)
    assert progs.n_slots >= 1
    assert progs.sampler.name == "uniform"
    sel = progs.select(jax.random.PRNGKey(0))
    assert sel.shape == (C, assign.n_units)
