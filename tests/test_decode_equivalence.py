"""Prefill + step-by-step decode must reproduce the full forward pass.

This is the strongest correctness property of the serving path: for every
architecture family, running the model autoregressively over a cache
(ring buffers, SSM states, cross-attention caches) must give the same
logits as one full-sequence forward.
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_cfg, tiny_batch
from repro.models import get_model

DECODE_ARCHS = ["qwen3-1.7b", "stablelm-3b", "qwen2.5-14b", "gemma3-12b",
                "granite-moe-1b-a400m", "llama4-maverick-400b-a17b",
                "rwkv6-3b", "hymba-1.5b", "whisper-medium", "internvl2-26b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_prefill_then_decode_matches_forward(name, rng):
    cfg = reduced_cfg(name)
    m = get_model(cfg)
    params = m.init_params(rng)
    b, s_pre, n_dec = 2, 24, 6
    s = s_pre + n_dec
    batch = tiny_batch(cfg, rng, b, s)
    tokens = batch["tokens"]
    kw = {"attn_impl": "reference"} if cfg.family != "ssm" else {}
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = batch["patches"]
    if cfg.family == "audio":
        extra["frames"] = batch["frames"]

    full_logits, _, _ = m.forward(params, tokens, **extra, **kw)

    logits_pre, cache = m.prefill(params, tokens[:, :s_pre],
                                  max_len=s + 8, **extra, **kw)
    got = [logits_pre[:, -1]]
    for t in range(s_pre, s):
        step_logits, cache = m.decode_step(params, cache, tokens[:, t:t + 1])
        got.append(step_logits[:, 0])
    got = jnp.stack(got[:-1], axis=1)          # predictions for pos s_pre-1..s-2
    want = full_logits[:, s_pre - 1:s - 1]
    if cfg.family == "vlm":
        want = full_logits[:, cfg.n_patches + s_pre - 1:
                           cfg.n_patches + s - 1]
    err = float(jnp.abs(got - want).max())
    assert err < 2e-2, f"{name}: decode/forward divergence {err}"


def test_ring_buffer_matches_full_cache(rng):
    """Sliding-window ring decode == full-cache windowed attention."""
    from repro.models.attention import (decode_attend, decode_attend_ring)
    b, s, h, hd, w = 2, 37, 4, 16, 16
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    valid = jnp.array([s, s - 5])
    full = decode_attend(q, k, v, valid, window=w)
    # build the ring: slot = pos % w for the last w valid positions
    kr = jnp.zeros((b, w, h, hd))
    vr = jnp.zeros((b, w, h, hd))
    for bi in range(b):
        n = int(valid[bi])
        for pos in range(max(0, n - w), n):
            kr = kr.at[bi, pos % w].set(k[bi, pos])
            vr = vr.at[bi, pos % w].set(v[bi, pos])
    ring = decode_attend_ring(q, kr, vr, valid, window=w)
    assert float(jnp.abs(full - ring).max()) < 1e-5
