"""Pallas flash attention vs the pure-jnp oracle: shape/dtype sweeps,
causal + sliding-window + GQA, fwd + bwd (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models.attention import attend_reference

SHAPES = [
    # (B, S, H, Hkv, hd, blk)
    (1, 128, 2, 2, 64, 64),
    (2, 256, 4, 4, 64, 128),
    (2, 256, 4, 2, 64, 64),       # GQA 2:1
    (1, 256, 8, 1, 32, 64),       # MQA
    (1, 128, 2, 2, 128, 64),
]


@pytest.mark.parametrize("b,s,h,hkv,hd,blk", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_fwd_matches_reference(b, s, h, hkv, hd, blk, causal, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    o = flash_attention(q, k, v, causal, 0, blk, blk, True)
    ref = attend_reference(q, k, v, causal=causal)
    assert float(jnp.abs(o - ref).max()) < 2e-5


@pytest.mark.parametrize("window", [64, 128])
def test_fwd_sliding_window(window, rng):
    b, s, h, hd = 2, 256, 4, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    o = flash_attention(q, k, v, True, window, 64, 64, True)
    ref = attend_reference(q, k, v, causal=True, window=window)
    assert float(jnp.abs(o - ref).max()) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype, rng):
    b, s, h, hd = 1, 128, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, hd)).astype(dtype)
    o = flash_attention(q, k, v, True, 0, 64, 64, True)
    assert o.dtype == dtype
    ref = attend_reference(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.abs(o.astype(jnp.float32) - ref).max()) < tol


@pytest.mark.parametrize("b,s,h,hkv,hd,blk", SHAPES[:3])
def test_bwd_matches_reference(b, s, h, hkv, hd, blk, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))

    def f(q, k, v):
        return (flash_attention(q, k, v, True, 0, blk, blk, True) ** 2).sum()

    def fr(q, k, v):
        return (attend_reference(q, k, v, causal=True) ** 2).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g, gr):
        assert float(jnp.abs(a - b_).max()) < 5e-4


def test_kernel_layout_ref_agrees_with_model_layout(rng):
    """ref.py (kernel layout) is consistent with the model attention."""
    b, s, h, hd = 2, 64, 4, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    o_ref = flash_attention_ref(qk, kk, vk, causal=True)
    o_model = attend_reference(q, k, v, causal=True) \
        .transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    assert float(jnp.abs(o_ref - o_model).max()) < 1e-6
