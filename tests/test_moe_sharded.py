"""shard_map TP-dispatch MoE == GSPMD scatter MoE, numerically, on a
real multi-device (fake CPU) mesh — subprocess test."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.models.moe import apply_moe, apply_moe_sharded, init_moe
from repro.configs.base import MoECfg

mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices()[:8])
out = {}
for e, k, name in [(8, 2, "top2"), (8, 1, "top1"), (4, 4, "top4")]:
    mcfg = MoECfg(num_experts=e, top_k=k, expert_d_ff=16)
    key = jax.random.PRNGKey(e * 10 + k)
    p = init_moe(key, 32, mcfg, jnp.float32)
    x = jax.random.normal(key, (4, 8, 32))
    y0, a0 = apply_moe(p, x, mcfg)
    with mesh:
        y1, a1 = jax.jit(
            lambda p, x: apply_moe_sharded(p, x, mcfg, mesh=mesh))(p, x)
    out[name] = [float(jnp.abs(y0 - y1).max()), float(abs(a0 - a1))]
    # gradients through the sharded path stay finite
    with mesh:
        g = jax.jit(jax.grad(
            lambda p: apply_moe_sharded(p, x, mcfg, mesh=mesh)[0].sum()))(p)
    out[name].append(all(bool(jnp.isfinite(l).all())
                         for l in jax.tree_util.tree_leaves(g)))
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_moe_matches_gspmd_multidevice():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _CODE], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for name, (err, aux_err, grads_ok) in out.items():
        assert err < 1e-5, f"{name}: output mismatch {err}"
        assert aux_err < 1e-6, f"{name}: aux mismatch"
        assert grads_ok, f"{name}: non-finite grads"
