"""Layer-selection strategies: exact counts, determinism, coverage
(paper Fig. 4), synchronized mode — incl. hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import freezing


@settings(max_examples=30, deadline=None)
@given(u=st.integers(2, 40), seed=st.integers(0, 2**16))
def test_uniform_selects_exactly_n(u, seed):
    n = max(1, u // 3)
    sel = freezing.select_uniform(jax.random.PRNGKey(seed), u, n)
    assert sel.shape == (u,)
    assert int(sel.sum()) == n
    assert set(np.unique(np.asarray(sel))) <= {0.0, 1.0}


def test_deterministic_per_key():
    a = freezing.select_uniform(jax.random.PRNGKey(7), 14, 4)
    b = freezing.select_uniform(jax.random.PRNGKey(7), 14, 4)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_clients_independent_vs_synchronized():
    key = jax.random.PRNGKey(3)
    ind = freezing.select_clients(key, 8, 14, 7)
    syn = freezing.select_clients(key, 8, 14, 7, synchronized=True)
    assert np.asarray(syn).std(axis=0).max() == 0          # all rows equal
    assert np.asarray(ind).std(axis=0).max() > 0           # rows differ
    assert (np.asarray(ind).sum(axis=1) == 7).all()


def test_fixed_last():
    sel = freezing.select_clients(jax.random.PRNGKey(0), 3, 10, 4,
                                  strategy="fixed_last")
    assert (np.asarray(sel)[:, -4:] == 1).all()
    assert (np.asarray(sel)[:, :-4] == 0).all()


def test_full_strategy():
    sel = freezing.select_clients(jax.random.PRNGKey(0), 3, 10, 4,
                                  strategy="full")
    assert (np.asarray(sel) == 1).all()


def test_coverage_over_rounds_is_uniform():
    """Paper Fig. 4: over many rounds every unit trains ~equally often."""
    u, n, c, rounds = 14, 4, 10, 300
    counts = np.zeros(u)
    for r in range(rounds):
        sel = freezing.select_clients(jax.random.PRNGKey(r), c, u, n)
        counts += np.asarray(sel).sum(axis=0)
    expected = rounds * c * n / u
    # every unit within 10% of the uniform expectation
    assert (np.abs(counts - expected) / expected < 0.10).all(), counts


def test_weighted_prefers_high_scores():
    u, n = 20, 5
    scores = jnp.zeros(u).at[:5].set(8.0)    # strongly favour units 0-4
    hits = np.zeros(u)
    for r in range(200):
        sel = freezing.select_weighted(jax.random.PRNGKey(r), u, n, scores)
        hits += np.asarray(sel)
    assert hits[:5].min() > hits[5:].max()


@settings(max_examples=20, deadline=None)
@given(frac=st.sampled_from([0.25, 0.33, 0.5, 0.66, 0.75, 1.0]),
       u=st.integers(3, 50))
def test_fraction_mapping(frac, u):
    n = freezing.n_train_from_fraction(u, frac)
    assert 1 <= n <= u
    assert abs(n - frac * u) <= 0.51
