"""Transfer accounting vs the paper's Table 4 (the 75% / 53% claims)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, freezing
from repro.core.masking import build_units_flat, unit_param_counts
from repro.models import paper_models as pm


@pytest.fixture(scope="module")
def vgg():
    p = pm.init_vgg16(jax.random.PRNGKey(0))      # full size: Table 1 exact
    assign = build_units_flat(p, pm.vgg16_units(p))
    return p, assign


def test_vgg16_total_params_exact(vgg):
    p, assign = vgg
    assert int(unit_param_counts(assign, p).sum()) == 14_736_714


def _avg_uplink_frac(assign, p, n_train, rounds=200, clients=10):
    ub = comm.unit_bytes(assign, p)
    fracs = []
    for r in range(rounds):
        sel = freezing.select_clients(jax.random.PRNGKey(r), clients,
                                      assign.n_units, n_train)
        fracs.append(comm.hub_round_bytes(np.asarray(sel), ub)["uplink_frac"])
    return float(np.mean(fracs))


def test_table4_reduction_25pct(vgg):
    """Training 4/14 layers: expected transfer reduction ~71% (uniform
    expectation n/U); the paper reports 75% — we reproduce the uniform
    law and stay within its neighbourhood."""
    p, assign = vgg
    frac = _avg_uplink_frac(assign, p, 4)
    assert abs(frac - 4 / 14) < 0.04
    assert 0.66 < 1 - frac < 0.78                  # paper: ~0.75


def test_table4_reduction_50pct(vgg):
    p, assign = vgg
    frac = _avg_uplink_frac(assign, p, 7)
    assert abs(frac - 0.5) < 0.04
    assert 0.45 < 1 - frac < 0.57                  # paper: ~0.53


def test_uplink_scales_linearly_with_layers(vgg):
    p, assign = vgg
    f = [_avg_uplink_frac(assign, p, n, rounds=60) for n in (4, 7, 10, 14)]
    assert f[0] < f[1] < f[2] < f[3]
    assert abs(f[3] - 1.0) < 1e-6                  # full model -> full bytes


def test_expected_fraction_formula():
    assert comm.expected_uplink_fraction(14, 7) == 0.5
    assert abs(comm.expected_uplink_fraction(14, 4) - 0.2857) < 1e-3


def test_table4_row_from_history(vgg):
    p, assign = vgg
    hist = np.stack([
        np.asarray(freezing.select_clients(jax.random.PRNGKey(r), 10,
                                           assign.n_units, 7))
        for r in range(30)])
    row = comm.table4_row(assign, p, hist)
    total = 14_736_714 * 4 * 10                    # bytes, 10 clients
    assert 0.4 * total < row["avg_uplink_bytes"] < 0.6 * total
    assert 0.40 < row["reduction_vs_full"] < 0.60
