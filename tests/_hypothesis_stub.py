"""Deterministic fallback for the ``hypothesis`` property-testing API.

The container image does not ship hypothesis and nothing may be pip-
installed, so ``conftest.py`` installs this stub into ``sys.modules``
ONLY when the real package is missing.  It implements the tiny subset
the test suite uses (``given``, ``settings``, ``strategies.integers/
sampled_from/booleans``) by drawing ``max_examples`` pseudo-random
examples from a fixed seed — deterministic across runs, no shrinking.
"""
from __future__ import annotations

import random
import sys
import types

_SEED = 0xF1DE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def booleans() -> _Strategy:
    return _Strategy(lambda r: r.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_ignored) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def given(**strategy_kwargs):
    def decorate(fn):
        def runner(*args, **kwargs):
            rnd = random.Random(_SEED)
            n = getattr(runner, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            for _ in range(n):
                drawn = {k: s.example(rnd)
                         for k, s in strategy_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        # keep the test's name/doc but NOT its signature: pytest must
        # not mistake the strategy kwargs for fixtures
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.hypothesis = types.SimpleNamespace(inner_test=fn)
        return runner

    return decorate


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate


def install():
    """Register stub modules as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__stub__ = True
    st = types.ModuleType("hypothesis.strategies")
    for f in (integers, sampled_from, booleans, floats):
        setattr(st, f.__name__, f)
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
