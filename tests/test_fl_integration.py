"""End-to-end federated training behaviour (the paper's headline claims,
at CPU scale): partial-layer rounds converge, comparable to full-model
rounds; server orchestration + comm accounting work."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg, tiny_batch
from repro.core import (FLConfig, build_round_step,
                        build_fullmodel_round_step, build_units_zoo)
from repro.core.server import Server
from repro.data import FederatedLoader, cifar_like, iid_partition
from repro.models import get_model, paper_models as pm


def _lm_setup(rng, arch="qwen3-1.7b"):
    cfg = reduced_cfg(arch)
    m = get_model(cfg)
    params = m.init_params(rng)
    assign = build_units_zoo(cfg, params)
    c, steps, b, s = 4, 2, 2, 32
    key = jax.random.fold_in(rng, 1)
    batches = {"tokens": jax.random.randint(key, (c, steps, b, s), 0,
                                            cfg.vocab)}
    batches["labels"] = jnp.roll(batches["tokens"], -1, axis=-1)
    return cfg, m, params, assign, batches


@pytest.mark.parametrize("frac", [0.5, 1.0])
def test_rounds_decrease_loss(frac, rng):
    cfg, m, params, assign, batches = _lm_setup(rng)
    n_train = max(1, round(assign.n_units * frac))
    fl = FLConfig(n_clients=4, n_train_units=n_train, lr=2e-3)
    step = jax.jit(build_round_step(
        m.loss_fn, assign, fl, loss_kwargs={"attn_impl": "reference"}))
    w = jnp.ones(4)
    losses = []
    p = params
    for r in range(6):
        p, mt = step(p, batches, w, jax.random.PRNGKey(r))
        losses.append(float(mt["loss_mean"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_partial_close_to_full(rng):
    """Fig 2/3 trend: 50%-layer FL reaches a loss close to full-model FL
    on the same stream (within a modest factor at this tiny scale)."""
    cfg, m, params, assign, batches = _lm_setup(rng)
    w = jnp.ones(4)

    def run(fl, builder=build_round_step, **kw):
        step = jax.jit(builder(m.loss_fn, **kw) if builder is
                       build_fullmodel_round_step else
                       builder(m.loss_fn, assign, fl,
                               loss_kwargs={"attn_impl": "reference"}))
        p = params
        for r in range(8):
            p, mt = step(p, batches, w, jax.random.PRNGKey(100 + r))
        return float(mt["loss_mean"])

    full = run(FLConfig(n_clients=4, n_train_units=assign.n_units, lr=2e-3))
    half = run(FLConfig(n_clients=4,
                        n_train_units=max(1, assign.n_units // 2), lr=2e-3))
    assert half < full * 1.35, (half, full)


def test_fedprox_runs(rng):
    cfg, m, params, assign, batches = _lm_setup(rng)
    fl = FLConfig(n_clients=4, n_train_units=2, lr=2e-3, prox_mu=0.01)
    step = jax.jit(build_round_step(m.loss_fn, assign, fl,
                                    loss_kwargs={"attn_impl": "reference"}))
    p, mt = step(params, batches, jnp.ones(4), jax.random.PRNGKey(0))
    assert np.isfinite(mt["loss_mean"])


def test_server_orchestration_and_comm(rng):
    """Server loop + per-round uplink accounting + straggler dropout."""
    p = pm.init_vgg16(rng, width_mult=0.125)
    from repro.core.masking import build_units_flat
    assign = build_units_flat(p, pm.vgg16_units(p))

    def loss_fn(params, batch):
        return pm.xent_loss(pm.vgg16_apply(params, batch["x"]),
                            batch["y"]), {}

    x, y = cifar_like(256, key=0)
    shards = iid_partition(len(x), 4, key=1)
    loader = FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                             batch_size=8, steps_per_round=2)
    fl = FLConfig(n_clients=4, n_train_units=4, lr=1e-3)
    srv = Server(build_round_step(loss_fn, assign, fl), assign, fl, p,
                 dropout_rate=0.25)
    hist = srv.run(3, lambda r: jax.tree_util.tree_map(
        jnp.asarray, loader.round_batches(r)),
        weights=jnp.asarray(loader.weights()))
    assert len(hist) == 3
    full_bytes = sum(int(np.prod(np.shape(l))) * 4
                     for l in jax.tree_util.tree_leaves(p)) * 4  # 4 clients
    for rec in hist:
        assert 0 < rec.uplink_bytes < full_bytes   # partial < full
    summ = srv.comm_summary()
    assert 0.5 < summ["reduction_vs_full"] < 0.9   # 4/14 units selected


def test_synchronized_selection_reduces_collective(rng):
    """Beyond-paper: synchronized selection shrinks the cross-client
    reduce payload to exactly the selected fraction."""
    from repro.core import comm, freezing
    from repro.core.masking import build_units_flat
    p = pm.init_vgg16(rng, width_mult=0.125)
    assign = build_units_flat(p, pm.vgg16_units(p))
    ub = comm.unit_bytes(assign, p)
    key = jax.random.PRNGKey(0)
    ind = freezing.select_clients(key, 10, 14, 7)
    syn = freezing.select_clients(key, 10, 14, 7, synchronized=True)
    r_ind = comm.collective_round_bytes(np.asarray(ind), ub)
    r_syn = comm.collective_round_bytes(np.asarray(syn), ub)
    assert r_syn["active_units"] == 7
    assert r_ind["active_units"] > 7               # union over 10 clients
    assert r_syn["payload"] < r_ind["payload"]
