"""Attention implementation equivalences (pure-JAX variants)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (attend_chunked, attend_reference,
                                    attend_windowed, decode_attend)


@pytest.mark.parametrize("b,s,h,hkv,hd", [
    (2, 128, 4, 4, 32), (1, 256, 4, 2, 64), (2, 64, 8, 1, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_reference(b, s, h, hkv, hd, causal, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    ref = attend_reference(q, k, v, causal=causal)
    got = attend_chunked(q, k, v, causal=causal, q_chunk=32, kv_chunk=32)
    assert float(jnp.abs(ref - got).max()) < 1e-5


@pytest.mark.parametrize("window", [16, 64, 200])
def test_windowed_matches_reference(window, rng):
    b, s, h, hd = 2, 128, 4, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    ref = attend_reference(q, k, v, causal=True, window=window)
    got = attend_windowed(q, k, v, window=window, q_chunk=32)
    assert float(jnp.abs(ref - got).max()) < 1e-5


def test_chunked_gradients_match(rng):
    b, s, h, hd = 1, 64, 2, 16
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))

    gr = jax.grad(lambda *a: (attend_reference(*a) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(lambda *a: (attend_chunked(
        *a, q_chunk=16, kv_chunk=16) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gr, gc):
        assert float(jnp.abs(a - b_).max()) < 1e-4


def test_decode_matches_last_row_of_full(rng):
    b, s, h, hd = 2, 48, 4, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    full = attend_reference(q, k, v, causal=True)
    dec = decode_attend(q[:, -1:], k, v, jnp.full((b,), s))
    assert float(jnp.abs(full[:, -1:] - dec).max()) < 1e-5


def test_gqa_equals_repeated_mha(rng):
    """GQA must equal MHA with explicitly repeated K/V heads."""
    b, s, h, hkv, hd = 2, 64, 8, 2, 16
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    kr = jnp.repeat(k, h // hkv, axis=2)
    vr = jnp.repeat(v, h // hkv, axis=2)
    a = attend_reference(q, k, v, causal=True)
    b_ = attend_reference(q, kr, vr, causal=True)
    assert float(jnp.abs(a - b_).max()) < 1e-6
