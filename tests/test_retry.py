"""Direct coverage for common/retry.py (previously exercised only
through the cohort engine's crash resample)."""
import pytest

from repro.common.retry import Backoff, retry_call


def test_backoff_jitter_deterministic_under_fixed_seed():
    b1 = Backoff(seed=7, jitter=0.5)
    b2 = Backoff(seed=7, jitter=0.5)
    assert b1.delay(2, token=(3, 4)) == b2.delay(2, token=(3, 4))
    # different token or attempt decorrelates the draw
    assert b1.delay(2, token=(3, 4)) != b1.delay(2, token=(3, 5))
    assert b1.delay(1, token=(3, 4)) != b1.delay(2, token=(3, 4))
    # a different seed is a different schedule
    assert b1.delay(2, token=0) != Backoff(seed=8, jitter=0.5).delay(2, 0)


def test_backoff_growth_cap_and_jitter_bounds():
    b = Backoff(base=0.1, factor=2.0, max_delay=0.5, jitter=0.0)
    assert b.delay(0) == pytest.approx(0.1)
    assert b.delay(1) == pytest.approx(0.2)
    assert b.delay(10) == pytest.approx(0.5)          # capped
    j = Backoff(base=0.1, factor=2.0, max_delay=0.5, jitter=0.5)
    for attempt in range(6):
        d = j.delay(attempt, token=1)
        full = min(0.1 * 2.0 ** attempt, 0.5)
        # downward equal-jitter: within [full/2, full], never above cap
        assert full * 0.5 <= d <= full


def test_backoff_rejects_bad_config():
    with pytest.raises(ValueError, match="attempts"):
        Backoff(attempts=-1)
    with pytest.raises(ValueError, match="jitter"):
        Backoff(jitter=1.5)


def test_retry_call_zero_attempts_still_runs_once():
    calls = []

    def fn(attempt):
        calls.append(attempt)
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError, match="boom"):
        retry_call(fn, backoff=Backoff(attempts=0), sleep=None)
    assert calls == [0]


def test_retry_call_propagates_last_exception_and_sleeps_between():
    slept = []
    calls = []

    def fn(attempt):
        calls.append(attempt)
        raise ValueError(f"fail {attempt}")

    b = Backoff(attempts=3, jitter=0.0, base=0.01, factor=2.0)
    with pytest.raises(ValueError, match="fail 2"):
        retry_call(fn, backoff=b, token=9, sleep=slept.append)
    assert calls == [0, 1, 2]
    # sleeps between attempts only (not after the last failure)
    assert slept == [pytest.approx(b.delay(0, 9)),
                     pytest.approx(b.delay(1, 9))]


def test_retry_call_succeeds_after_transient_failures():
    def fn(attempt):
        if attempt < 2:
            raise OSError("transient")
        return f"ok@{attempt}"

    assert retry_call(fn, backoff=Backoff(attempts=3),
                      sleep=None) == "ok@2"


def test_retry_call_non_matching_exception_propagates_immediately():
    calls = []

    def fn(attempt):
        calls.append(attempt)
        raise KeyError("not retryable")

    with pytest.raises(KeyError):
        retry_call(fn, backoff=Backoff(attempts=3),
                   retry_on=(OSError,), sleep=None)
    assert calls == [0]
