import os
import sys

# tests run on the single real CPU device (the 512-device XLA_FLAGS hack is
# confined to launch/dryrun.py subprocesses — see the dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # offline container: fall back to the deterministic stub
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running subprocess/compile tests")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced_cfg(name):
    return get_config(name).reduced()


def tiny_batch(cfg, key, b=2, s=32):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        from repro.models.transformer import vit_width
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_patches, vit_width(cfg)))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    return batch


ALL_ARCHS = list(list_configs())
