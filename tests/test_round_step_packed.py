"""Packed / fused sparse round steps vs the dense-masked reference.

The packed path (FLConfig.packed, DESIGN.md §7) must produce
**bit-exact** global params vs the reference ``masked_fedavg`` round
step — asserted here across strategies {uniform, fixed_last,
synchronized}, topologies {hub, hierarchical}, scalar+stacked leaf
kinds (the toy model has both), straggler (zero-weight) clients, the
always-trained head, and zero-participation units.  The fused Pallas
path is held to the kernel tolerance (interpret mode on CPU).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import masked_fedavg, masked_fedavg_packed
from repro.core.federation import FLConfig, build_round_step
from repro.core.masking import (apply_mask, mask_tree, slot_gather,
                                slot_merge, slot_plan)
from repro.models.toy import (init_toy_mlp, toy_batches, toy_loss,
                              toy_units)

N_BLOCKS, D, HIDDEN, OUT = 6, 16, 32, 4


def _setup(seed, n_clients):
    key = jax.random.PRNGKey(seed)
    params = init_toy_mlp(key, n_blocks=N_BLOCKS, d=D, hidden=HIDDEN,
                          out=OUT)
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1), n_clients=n_clients,
                          steps=2, batch=4, d=D, out=OUT)
    weights = jnp.asarray(np.random.default_rng(seed)
                          .uniform(0.5, 2.0, n_clients), jnp.float32)
    return params, assign, batches, weights


def _assert_trees_equal(a, b, exact=True, atol=0.0):
    for (pa, la), (_, lb) in zip(jax.tree_util.tree_leaves_with_path(a),
                                 jax.tree_util.tree_leaves_with_path(b)):
        if exact:
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb),
                err_msg=jax.tree_util.keystr(pa))
        else:
            np.testing.assert_allclose(
                np.asarray(la), np.asarray(lb), atol=atol, rtol=atol,
                err_msg=jax.tree_util.keystr(pa))


def _round(params, assign, batches, weights, fl, seed):
    step = jax.jit(build_round_step(toy_loss, assign, fl))
    return step(params, batches, weights, jax.random.PRNGKey(seed + 99))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 200),
       strategy=st.sampled_from(["uniform", "fixed_last", "synchronized"]),
       topology=st.sampled_from(["hub", "hierarchical"]))
def test_packed_bit_exact_vs_reference(seed, strategy, topology):
    c = 4
    params, assign, batches, weights = _setup(seed, c)
    fl = FLConfig(n_clients=c, train_fraction=0.4, strategy=strategy,
                  topology=topology, n_edges=2, lr=1e-2)
    ref_p, ref_m = _round(params, assign, batches, weights, fl, seed)
    pk_p, pk_m = _round(params, assign, batches, weights,
                        dataclasses.replace(fl, packed=True), seed)
    np.testing.assert_array_equal(np.asarray(ref_m["sel"]),
                                  np.asarray(pk_m["sel"]))
    _assert_trees_equal(ref_p, pk_p, exact=True)
    np.testing.assert_array_equal(float(ref_m["loss_mean"]),
                                  float(pk_m["loss_mean"]))


def test_packed_straggler_and_head_bit_exact():
    c = 5
    params, assign, batches, weights = _setup(3, c)
    weights = weights.at[1].set(0.0)            # dropped straggler
    fl = FLConfig(n_clients=c, train_fraction=0.25, strategy="uniform",
                  topology="hub", always_train_head=True)
    ref_p, _ = _round(params, assign, batches, weights, fl, 3)
    pk_p, _ = _round(params, assign, batches, weights,
                     dataclasses.replace(fl, packed=True), 3)
    _assert_trees_equal(ref_p, pk_p, exact=True)


def test_packed_zero_participation_units_keep_global():
    """fixed_last trains only the last 2 units: every other unit has
    zero participation and must keep the global value bit-exactly."""
    c = 4
    params, assign, batches, weights = _setup(5, c)
    fl = FLConfig(n_clients=c, n_train_units=2, strategy="fixed_last",
                  topology="hub", packed=True)
    new_p, metrics = _round(params, assign, batches, weights, fl, 5)
    sel = np.asarray(metrics["sel"])
    assert sel[:, :-2].sum() == 0.0
    # untouched units: inp (unit 0) + blocks 0..N-2 (units 1..N-1)
    np.testing.assert_array_equal(np.asarray(new_p["inp"]["w"]),
                                  np.asarray(params["inp"]["w"]))
    for k in params["blocks"]:
        np.testing.assert_array_equal(
            np.asarray(new_p["blocks"][k][:-1]),
            np.asarray(params["blocks"][k][:-1]))
        # the last block (unit N) IS trained — it must have moved
        assert not np.array_equal(np.asarray(new_p["blocks"][k][-1]),
                                  np.asarray(params["blocks"][k][-1]))


def test_packed_prox_matches_reference():
    """FedProx couples the prox sum to the packed representation —
    reduction order differs, so equality is near- rather than bit-."""
    c = 4
    params, assign, batches, weights = _setup(7, c)
    fl = FLConfig(n_clients=c, train_fraction=0.5, strategy="uniform",
                  prox_mu=0.1)
    ref_p, _ = _round(params, assign, batches, weights, fl, 7)
    pk_p, _ = _round(params, assign, batches, weights,
                     dataclasses.replace(fl, packed=True), 7)
    _assert_trees_equal(ref_p, pk_p, exact=False, atol=1e-6)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 200),
       topology=st.sampled_from(["hub", "hierarchical"]))
def test_fused_matches_reference(seed, topology):
    c = 4
    params, assign, batches, weights = _setup(seed, c)
    fl = FLConfig(n_clients=c, train_fraction=0.4, strategy="uniform",
                  topology=topology, n_edges=2)
    ref_p, _ = _round(params, assign, batches, weights, fl, seed)
    fu_p, _ = _round(params, assign, batches, weights,
                     dataclasses.replace(fl, fused_agg="on"), seed)
    _assert_trees_equal(ref_p, fu_p, exact=False, atol=2e-5)


def test_gossip_rejects_packed():
    params, assign, _, _ = _setup(0, 4)
    fl = FLConfig(n_clients=4, train_fraction=0.5, topology="gossip",
                  packed=True)
    with pytest.raises(ValueError, match="packed"):
        build_round_step(toy_loss, assign, fl)


def test_fused_agg_validation():
    with pytest.raises(ValueError, match="fused_agg"):
        FLConfig(n_clients=2, fused_agg="maybe").resolve_fused_agg()
    assert FLConfig(n_clients=2, fused_agg="on").resolve_fused_agg()
    assert not FLConfig(n_clients=2, fused_agg="off").resolve_fused_agg()


def test_slot_roundtrip_gather_merge():
    """slot_gather/slot_merge invert each other on selected rows and
    leave frozen rows untouched."""
    params, assign, _, _ = _setup(11, 1)
    sel_row = jnp.zeros((assign.n_units,)).at[jnp.asarray([1, 3])].set(1.0)
    rows, valid = slot_plan(assign, sel_row, 2, params)
    packed = slot_gather(assign, params, rows)
    merged = slot_merge(assign, params, packed, rows)
    _assert_trees_equal(params, merged, exact=True)
    # pad rows are distinct from selected rows
    r = np.asarray(rows["blocks"]["w1"])
    assert len(set(r.tolist())) == len(r)


def test_packed_aggregation_matches_dense():
    """Direct check of masked_fedavg_packed against masked_fedavg on
    consistent (dense-masked vs gathered) deltas."""
    c = 4
    params, assign, _, weights = _setup(13, c)
    key = jax.random.PRNGKey(13)
    sel = np.zeros((c, assign.n_units), np.float32)
    rng = np.random.default_rng(13)
    for i in range(c):
        sel[i, rng.choice(assign.n_units, 3, replace=False)] = 1.0
    sel = jnp.asarray(sel)
    deltas = jax.tree_util.tree_map(
        lambda x: jax.random.normal(
            jax.random.fold_in(key, abs(hash(str(x.shape))) % 999),
            (c,) + x.shape) * 0.05, params)
    deltas = jax.vmap(
        lambda s, t: apply_mask(mask_tree(assign, s, params), t))(sel, deltas)
    rows, valid = jax.vmap(
        lambda s: slot_plan(assign, s, 3, params))(sel)
    pdeltas = jax.vmap(
        lambda d, r: slot_gather(assign, d, r))(deltas, rows)
    ref = jax.jit(
        lambda p, d, s, w: masked_fedavg(p, d, s, w, assign))(
            params, deltas, sel, weights)
    got = jax.jit(
        lambda p, d, r, v, s, w: masked_fedavg_packed(p, d, r, v, s, w,
                                                      assign))(
            params, pdeltas, rows, valid, sel, weights)
    _assert_trees_equal(ref, got, exact=True)


def test_adam_init_states_independent():
    """adam_init must not alias (or copy) mu into nu."""
    from repro.optim.masked import adam_init
    st_ = adam_init({"w": jnp.ones((3, 2))})
    assert st_.mu["w"] is not st_.nu["w"]
    np.testing.assert_array_equal(np.asarray(st_.mu["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(st_.nu["w"]), 0.0)
