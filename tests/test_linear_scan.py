"""Chunked gated-linear-attention substrate vs the exact per-token
recurrence (RWKV-6 k-decay and mamba/SSD v-decay variants)."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.linear_scan import (chunked_linear_scan,
                                      linear_scan_decode,
                                      reference_linear_scan)


def _inputs(key, b, s, h, dk, dv, decay_scale=1.0):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    ld = -jnp.abs(jax.random.normal(ks[3], (b, s, h, dk))) * decay_scale
    return q, k, v, ld


@pytest.mark.parametrize("decay_on", ["k", "v"])
@pytest.mark.parametrize("chunk", [4, 16, 32])
def test_chunked_matches_reference(decay_on, chunk, rng):
    b, s, h, dk, dv = 2, 64, 3, 16, 24
    q, k, v, ld = _inputs(rng, b, s, h, dk, dv)
    if decay_on == "v":
        ld = ld[..., :1] * jnp.ones((1, 1, 1, dv))
    bonus = jax.random.normal(jax.random.fold_in(rng, 9), (h, dk)) * 0.1 \
        if decay_on == "k" else None
    ref_o, ref_s = reference_linear_scan(q, k, v, ld, decay_on=decay_on,
                                         bonus=bonus)
    got_o, got_s = chunked_linear_scan(q, k, v, ld, decay_on=decay_on,
                                       bonus=bonus, chunk=chunk)
    assert float(jnp.abs(ref_o - got_o).max()) < 1e-3
    assert float(jnp.abs(ref_s - got_s).max()) < 1e-3


@pytest.mark.parametrize("decay_on", ["k", "v"])
def test_state_passing_equals_one_shot(decay_on, rng):
    """scan(first half) -> state -> scan(second half) == one full scan."""
    b, s, h, dk, dv = 1, 32, 2, 8, 8
    q, k, v, ld = _inputs(rng, b, s, h, dk, dv)
    full_o, full_s = chunked_linear_scan(q, k, v, ld, decay_on=decay_on,
                                         chunk=8)
    o1, s1 = chunked_linear_scan(q[:, :16], k[:, :16], v[:, :16],
                                 ld[:, :16], decay_on=decay_on, chunk=8)
    o2, s2 = chunked_linear_scan(q[:, 16:], k[:, 16:], v[:, 16:],
                                 ld[:, 16:], decay_on=decay_on, chunk=8,
                                 state0=s1)
    o_cat = jnp.concatenate([o1, o2], axis=1)
    assert float(jnp.abs(full_o - o_cat).max()) < 1e-4
    assert float(jnp.abs(full_s - s2).max()) < 1e-4


@pytest.mark.parametrize("decay_on", ["k", "v"])
def test_decode_step_equals_scan_tail(decay_on, rng):
    b, s, h, dk, dv = 1, 17, 2, 8, 8
    q, k, v, ld = _inputs(rng, b, s, h, dk, dv)
    ref_o, ref_s = reference_linear_scan(q, k, v, ld, decay_on=decay_on)
    # replay the last token with linear_scan_decode from the s-1 state
    _, s_prev = reference_linear_scan(q[:, :-1], k[:, :-1], v[:, :-1],
                                      ld[:, :-1], decay_on=decay_on)
    o_t, s_t = linear_scan_decode(q[:, -1], k[:, -1], v[:, -1], ld[:, -1],
                                  s_prev, decay_on=decay_on)
    assert float(jnp.abs(o_t - ref_o[:, -1]).max()) < 1e-4
    assert float(jnp.abs(s_t - ref_s).max()) < 1e-4


@settings(max_examples=20, deadline=None)
@given(s=st.integers(4, 48), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2**16), strong=st.booleans())
def test_property_chunking_invariance(s, chunk, seed, strong):
    """Output must not depend on the chunking — for any seq length that the
    chunk divides, any chunk size, and both mild and strong decays."""
    s = (s // chunk) * chunk
    if s == 0:
        return
    key = jax.random.PRNGKey(seed)
    q, k, v, ld = _inputs(key, 1, s, 1, 8, 8,
                          decay_scale=4.0 if strong else 0.5)
    ref_o, _ = reference_linear_scan(q, k, v, ld, decay_on="k")
    got_o, _ = chunked_linear_scan(q, k, v, ld, decay_on="k", chunk=chunk)
    assert float(jnp.abs(ref_o - got_o).max()) < 5e-3
