"""launch/mesh.py failure modes: every invalid mesh request dies with a
message naming the offending value, the visible device count and the
nearest valid alternatives (the satellite's rich-ValueError contract)."""
import jax
import pytest

from repro.launch.mesh import (_nearest_valid, make_client_mesh,
                               make_fl_mesh, make_hier_fl_mesh,
                               shard_over_clients)


def test_nearest_valid_brackets_the_request():
    assert _nearest_valid(16, 5) == "4 or 8"
    assert _nearest_valid(16, 3) == "2 or 4"
    assert _nearest_valid(16, 1) == "2"      # nothing below 1
    assert _nearest_valid(16, 16) == "8"     # nothing above total
    assert _nearest_valid(1, 1) == "none"


def test_fl_mesh_indivisible_clients():
    with pytest.raises(ValueError) as e:
        make_fl_mesh(5)
    msg = str(e.value)
    assert "client axis 5" in msg
    assert "16-way" in msg
    assert f"{len(jax.devices())} devices visible" in msg
    assert "nearest valid cohort sizes: 4 or 8" in msg


def test_fl_mesh_multipod_uneven_pods():
    with pytest.raises(ValueError) as e:
        make_fl_mesh(3, multi_pod=True)
    msg = str(e.value)
    assert "must fill the 2 pods evenly" in msg
    assert "requested 3 clients" in msg
    assert "2 or 4" in msg


def test_hier_mesh_edges_must_divide_clients():
    with pytest.raises(ValueError) as e:
        make_hier_fl_mesh(3, 4)
    msg = str(e.value)
    assert "edge axis 3 must divide the 4 clients" in msg
    assert "nearest valid edge counts" in msg
    assert "2 or 4" in msg


def test_hier_mesh_zero_edges():
    with pytest.raises(ValueError, match="edge axis 0"):
        make_hier_fl_mesh(0, 4)


def test_hier_mesh_indivisible_clients():
    with pytest.raises(ValueError) as e:
        make_hier_fl_mesh(1, 3)
    assert "client axis 3" in str(e.value)
    assert "nearest valid cohort sizes: 2 or 4" in str(e.value)


def test_client_mesh_bounds():
    ndev = len(jax.devices())
    for bad in (0, -1, ndev + 1):
        with pytest.raises(ValueError) as e:
            make_client_mesh(bad)
        msg = str(e.value)
        assert f"client_shards={bad}" in msg
        assert f"between 1 and {ndev}" in msg
        assert f"({ndev} visible)" in msg


def test_shard_over_clients_indivisible_cohort():
    with pytest.raises(ValueError) as e:
        shard_over_clients(lambda g, x: x, 3, 4)
    msg = str(e.value)
    assert "client_shards=3 must divide the cohort of 4 clients" in msg
    assert "valid shard counts here: [1, 2, 4]" in msg


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 XLA devices")
def test_shard_over_clients_runs_valid_config():
    import jax.numpy as jnp
    import numpy as np
    fn = jax.vmap(lambda g, x: g * x, in_axes=(None, 0))
    wrapped = shard_over_clients(fn, 2, 4)
    x = jnp.arange(4.0)
    np.testing.assert_array_equal(np.asarray(wrapped(2.0, x)),
                                  np.asarray(fn(2.0, x)))
