"""Static-analysis subsystem (DESIGN.md §15): every checker is proven
by an intentionally-bad fixture it must flag, the repo itself must pass
clean, and CompileGuard enforces the compile-count contract."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint as L
from repro.analysis import tracecheck as T
from repro.analysis.compileguard import CompileGuard, CompileGuardError
from repro.analysis.findings import (apply_suppressions, load_suppressions,
                                     registered_checkers, report_dict,
                                     run_checkers)

REPO_ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# CompileGuard

def test_compileguard_budget_names_retrace_argument():
    guard = CompileGuard(lambda x, y: x + y, name="adder", max_programs=1)
    guard(jnp.zeros((4,)), jnp.zeros((4,)))
    guard(jnp.ones((4,)), jnp.ones((4,)))          # same program: fine
    assert guard.cache_size == 1
    with pytest.raises(CompileGuardError) as ei:
        guard(jnp.zeros((8,)), jnp.zeros((8,)))
    msg = str(ei.value)
    assert "adder" in msg and "budget 1" in msg
    # the diff names the argument and the shape transition
    assert "float32[4]" in msg and "float32[8]" in msg


def test_compileguard_structure_change_diff():
    guard = CompileGuard(lambda t: jax.tree_util.tree_reduce(
        lambda a, b: a + b.sum(), t, 0.0), max_programs=1)
    guard({"a": jnp.zeros((2,))})
    with pytest.raises(CompileGuardError) as ei:
        guard({"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})
    assert "structure changed" in str(ei.value)


def test_compileguard_unbounded_records_history():
    guard = CompileGuard(lambda x: x * 2, max_programs=None)
    guard(jnp.zeros((2,)))
    guard(jnp.zeros((3,)))
    assert guard.cache_size == 2
    assert len(guard.programs) == 2
    with pytest.raises(CompileGuardError):
        guard.assert_programs(1)


def test_compileguard_lower_counts_against_budget():
    guard = CompileGuard(lambda x: x + 1, max_programs=1)
    guard.lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    with pytest.raises(CompileGuardError):
        guard.lower(jax.ShapeDtypeStruct((8,), jnp.float32))


def test_compileguard_donation_invalidates_input():
    guard = CompileGuard(lambda x: x + 1, max_programs=1,
                         donate_argnums=(0,))
    assert guard.donate_argnums == (0,)
    x = jnp.zeros((16,))
    y = guard(x)
    assert y is not None and x.is_deleted()


# ---------------------------------------------------------------------------
# level-2 lint: bad fixtures

def test_lint_registry_flags_missing_docstring_and_name(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "@register_strategy\n"
        "class Nameless:\n"
        "    pass\n")
    out = L.lint_registry(tmp_path, files=[bad])
    assert {f.message.split(" ")[0] for f in out} == {"@register_strategy"}
    assert len(out) == 2          # no docstring + no resolvable name

    good = tmp_path / "good.py"
    good.write_text(
        "@register_fault\n"
        "class CrashFault:\n"
        "    \"\"\"doc\"\"\"\n"
        "    name, seam = \"crash\", \"crash\"\n"
        "\n"
        "@register_staleness\n"
        "def polynomial(s, a):\n"
        "    \"\"\"doc\"\"\"\n"
        "    return s\n")
    assert L.lint_registry(tmp_path, files=[good]) == []


def test_lint_seeded_random_flags_unseeded_draws(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "import numpy as np, time\n"
        "x = np.random.rand(3)\n"
        "t = time.time()\n")
    out = L.lint_seeded_random(tmp_path, files=[bad])
    assert {f.symbol for f in out} == {"np.random.rand", "time.time"}

    good = tmp_path / "good.py"
    good.write_text(
        "import numpy as np, time\n"
        "rng = np.random.default_rng(np.random.SeedSequence((0, 1)))\n"
        "t = time.perf_counter()\n")
    assert L.lint_seeded_random(tmp_path, files=[good]) == []


def test_lint_bare_jit_flags_unguarded_jit(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\nstep = jax.jit(lambda x: x)\n")
    out = L.lint_bare_jit(tmp_path, files=[bad])
    assert len(out) == 1 and out[0].symbol == "jax.jit"

    good = tmp_path / "good.py"
    good.write_text("from repro.analysis.compileguard import CompileGuard\n"
                    "step = CompileGuard(lambda x: x)\n")
    assert L.lint_bare_jit(tmp_path, files=[good]) == []


def test_lint_flconfig_flags_unvalidated_and_dead_fields(tmp_path):
    cfg = tmp_path / "cfg.py"
    cfg.write_text(
        "import dataclasses\n"
        "@dataclasses.dataclass\n"
        "class FLConfig:\n"
        "    dead_knob: int = 3\n"
        "    live: float = 0.1\n"
        "    def __post_init__(self):\n"
        "        if self.live < 0:\n"
        "            raise ValueError()\n")
    user = tmp_path / "user.py"
    user.write_text("def f(fl):\n    return fl.live\n")
    out = L.lint_flconfig(tmp_path, config_file=cfg, files=[cfg, user])
    # dead_knob: numeric without validator AND never read anywhere
    assert sorted(f.symbol for f in out) == ["dead_knob", "dead_knob"]


# ---------------------------------------------------------------------------
# level-1 trace checkers: bad fixtures

@pytest.fixture(scope="module")
def toy_slot_fixture():
    from repro.core.masking import slot_plan
    from repro.models.toy import init_toy_mlp, toy_batches, toy_units
    key = jax.random.PRNGKey(0)
    params = init_toy_mlp(key, n_blocks=4, d=8, hidden=12, out=4)
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1), n_clients=1,
                          steps=2, batch=2, d=8, out=4)
    batch0 = jax.tree_util.tree_map(lambda x: x[0, 0], batches)
    sel = np.zeros((assign.n_units,), np.float32)
    sel[:assign.n_units // 2] = 1.0
    rows, valid = slot_plan(assign, jnp.asarray(sel), 3, params)
    return params, assign, rows, batch0


def _merge_probe(toy_slot_fixture, *, stop_gradient: bool):
    """grad of the packed merge loss w.r.t. global params — with the
    stop_gradient on the merge base either intact (the real
    local_update_packed contract) or removed (the regression the
    checker exists to catch)."""
    from repro.core.masking import slot_gather, slot_merge
    from repro.models.toy import toy_loss
    params, assign, rows, batch0 = toy_slot_fixture

    def loss(gp):
        base = jax.lax.stop_gradient(gp) if stop_gradient else gp
        packed = slot_gather(assign, gp, rows)
        merged = slot_merge(assign, base, packed, rows)
        return toy_loss(merged, batch0)[0]

    closed = jax.make_jaxpr(jax.grad(loss))(params)
    return closed, T._stacked_leaves(assign, params)


def test_frozen_grad_passes_with_stop_gradient(toy_slot_fixture):
    closed, stacked = _merge_probe(toy_slot_fixture, stop_gradient=True)
    assert stacked                       # the check is not vacuous
    assert T.check_frozen_grad_jaxpr("fix", closed, stacked) == []


def test_frozen_grad_flags_missing_stop_gradient(toy_slot_fixture):
    closed, stacked = _merge_probe(toy_slot_fixture, stop_gradient=False)
    out = T.check_frozen_grad_jaxpr("fix", closed, stacked)
    # every stacked leaf leaks dense cotangent without the stop
    assert len(out) == len(stacked)
    assert "stop_gradient" in out[0].message


def test_key_flow_flags_reuse():
    def reuse(k):
        return jax.random.normal(k, (2,)) + jax.random.normal(k, (2,))
    closed = jax.make_jaxpr(reuse)(jax.random.key(0))
    out = T.check_key_flow_jaxpr("fix", closed)
    assert [f.symbol for f in out] == ["key-reuse"]


def test_key_flow_flags_underived_seed():
    old = jax.config.jax_enable_custom_prng
    jax.config.update("jax_enable_custom_prng", True)
    try:
        def underived(x):
            return jax.random.normal(jax.random.PRNGKey(0), (2,)) + x
        closed = jax.make_jaxpr(underived)(jnp.zeros((2,)))
    finally:
        jax.config.update("jax_enable_custom_prng", old)
    assert [f.symbol for f in T.check_key_flow_jaxpr("fix", closed)] \
        == ["underived-key"]


def test_key_flow_accepts_fold_in_fanout():
    """The serve idiom — fold_in per (request, position) — is derivation,
    not reuse, even under vmap."""
    def serve_like(k, rids):
        def one(r):
            return jax.random.categorical(jax.random.fold_in(k, r),
                                          jnp.ones((5,)))
        return jax.vmap(one)(rids)
    closed = jax.make_jaxpr(serve_like)(jax.random.key(0), jnp.arange(3))
    assert T.check_key_flow_jaxpr("fix", closed) == []


def test_host_sync_flags_callback_and_respects_allowlist():
    def cb(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2
    closed = jax.make_jaxpr(cb)(jnp.zeros((2,)))
    out = T.check_host_sync_jaxpr("fix", closed)
    assert len(out) == 1 and "callback" in out[0].symbol
    assert T.check_host_sync_jaxpr("fix", closed,
                                   allow=(out[0].symbol,)) == []


def test_donation_flags_silent_copy():
    def nocopy(a, b):
        return (a[:2] * b).sum()
    with pytest.warns(UserWarning, match="donated"):
        text = jax.jit(nocopy, donate_argnums=(0,)).lower(
            jnp.zeros((4,)), jnp.zeros((2,))).as_text()
    out = T.check_donation_text("fix", text, 1)
    assert len(out) == 1 and "silent copies" in out[0].message

    def ok(a):
        return a + 1
    text = jax.jit(ok, donate_argnums=(0,)).lower(
        jnp.zeros((4,))).as_text()
    assert T.check_donation_text("fix", text, 1) == []


def test_codec_pad_zeros_passes_real_and_flags_leaky():
    """The real codec transforms keep pads/non-participants at exact
    zero; a transform that skips the valid-mask multiply must fire."""
    from repro.core import codecs as C
    from repro.core.federation import FLConfig
    fl = FLConfig(n_clients=3, train_fraction=0.5, packed=True,
                  fused_agg="off", codec="qint8")
    params, assign, _, n_slots = T._toy_fixture(fl)
    good = C.build_codec_transform(C.get_codec("qint8"), assign, fl)
    assert T.check_codec_pad_zeros("fix", good, assign, params, fl,
                                   n_slots) == []

    def leaky(pdeltas, rows, valid, weights, key, state=None, decay=None):
        return pdeltas, None     # ships the raw payload, mask forgotten

    out = T.check_codec_pad_zeros("fix", leaky, assign, params, fl,
                                  n_slots)
    assert out and "valid mask" in out[0].message


def test_guard_contract_flags_bare_function_and_wrong_budget():
    out = T.check_guard_contract("fix", lambda x: x, 1, ())
    assert len(out) == 1 and "not routed through CompileGuard" \
        in out[0].message
    guard = CompileGuard(lambda x: x, max_programs=None)
    out = T.check_guard_contract("fix", guard, 1, (0,))
    assert sorted(f.symbol for f in out) == ["donate-argnums",
                                             "max-programs"]


# ---------------------------------------------------------------------------
# suppressions / report plumbing

def test_suppressions_match_and_stale_entries_flagged(tmp_path):
    from repro.analysis.findings import Finding
    f = Finding(checker="lint-bare-jit", level="lint", anchor="a.py",
                symbol="jax.jit", message="m")
    sups = [{"checker": "lint-bare-jit", "match": "a.py::*",
             "reason": "documented"},
            {"checker": "lint-bare-jit", "match": "gone.py::*",
             "reason": "stale"}]
    out = apply_suppressions([f], sups)
    assert out[0].suppressed and out[0].suppress_reason == "documented"
    stale = [x for x in out if x.checker == "suppressions"]
    assert len(stale) == 1 and "gone.py" in stale[0].symbol

    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"suppressions": [{"checker": "c"}]}))
    with pytest.raises(ValueError, match="missing required key"):
        load_suppressions(p)


def test_report_dict_summary_counts():
    from repro.analysis.findings import Finding
    fs = [Finding(checker="a", level="lint", anchor="x", message="m"),
          Finding(checker="b", level="trace", anchor="y", message="m",
                  suppressed=True)]
    rep = report_dict(fs, ["a", "b"])
    assert rep["summary"] == {"total": 2, "suppressed": 1,
                              "unsuppressed": 1,
                              "by_checker": {"a": 1, "b": 1}}
    assert rep["findings"][0]["fingerprint"] == "x::"


# ---------------------------------------------------------------------------
# the repo gate itself

def test_repo_lint_level_is_clean():
    assert run_checkers(REPO_ROOT, "lint") == []


def test_repo_trace_level_is_clean_and_covers_all_paths():
    """The acceptance gate: frozen-grad + key-flow + host-sync +
    donation + guard contracts pass on the real traced paths — sync,
    async, cohort and serve."""
    reg = T.traced_programs()
    names = {p.name for p in reg.programs}
    assert {"trace:sync/round_step", "trace:async/flush",
            "trace:async/select", "trace:cohort/chunk",
            "trace:cohort/finalize", "trace:serve/decode",
            "trace:serve/prefill"} <= names
    probe_names = {n for n, _, _ in reg.grad_probes}
    assert {"trace:sync/frozen_grad", "trace:async/frozen_grad",
            "trace:cohort/frozen_grad"} <= probe_names
    # serve paths must actually contain key-typed randomness, or the
    # key-flow pass over them would be vacuous
    dec = next(p for p in reg.programs if p.name == "trace:serve/decode")
    prims = {e.primitive.name for e in T._iter_eqns(dec.closed.jaxpr)}
    assert "random_fold_in" in prims and "random_bits" in prims
    assert run_checkers(REPO_ROOT, "trace") == []


def test_checker_registry_names():
    assert registered_checkers("lint") == [
        "lint-bare-jit", "lint-flconfig", "lint-registry",
        "lint-seeded-random"]
    assert registered_checkers("trace") == [
        "trace-codec-frozen", "trace-compileguard", "trace-donation",
        "trace-frozen-grad", "trace-host-sync", "trace-key-flow"]
