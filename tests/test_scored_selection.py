"""Scored selection engine (DESIGN.md §11): SelectionState threading,
gradient-norm telemetry (packed == dense BITWISE), the three new
score-driven strategies, the `weighted` -> uniform degeneration +
deprecation shim, uniform registry error messages, FLConfig range
validation, and bit-exact mid-fit checkpoint restore of the state."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FLConfig, Federation, NormTelemetry, ScoredStrategy,
                        SelectionContext, SelectionState, Server,
                        UnknownStrategyError, UnknownTopologyError,
                        build_round_step, get_strategy, get_topology,
                        registered_strategies)
from repro.core.async_agg import UnknownStalenessError, get_staleness
from repro.core.masking import unit_sqnorm, unit_sqnorm_packed
from repro.models.toy import init_toy_mlp, toy_batches, toy_loss, toy_units

C = 4


def _setup(n_blocks=6, d=16, hidden=32, out=4, steps=2, batch=2):
    key = jax.random.PRNGKey(0)
    params = init_toy_mlp(key, n_blocks=n_blocks, d=d, hidden=hidden,
                          out=out)
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1), n_clients=C,
                          steps=steps, batch=batch, d=d, out=out)
    return params, assign, batches


def _assert_trees_bitexact(a, b):
    for pa, pb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), \
            "trees diverged bitwise"


def _ctx(n_units=8, n_train=3, scores=None, state=None):
    return SelectionContext(n_clients=C, n_units=n_units, n_train=n_train,
                            scores=scores, state=state)


# -- registry: new strategies + uniform unknown-name errors -----------------

def test_new_strategies_registered():
    assert {"score_weighted", "depth_dropout", "successive"} <= \
        set(registered_strategies())
    for name in ("score_weighted", "depth_dropout", "successive"):
        assert get_strategy(name).stateful


def test_unknown_name_errors_share_uniform_format():
    """The three registries (satellite: shared helper) fail with the
    same ``unknown <kind> '<name>'; registered: ...`` shape."""
    with pytest.raises(UnknownStrategyError,
                       match=r"unknown selection strategy 'nope'; "
                             r"registered: .*uniform"):
        get_strategy("nope")
    with pytest.raises(UnknownTopologyError,
                       match=r"unknown topology 'nope'; "
                             r"registered: .*hierarchical"):
        get_topology("nope")
    with pytest.raises(UnknownStalenessError,
                       match=r"unknown staleness rule 'nope'; "
                             r"registered: .*polynomial"):
        get_staleness("nope")


# -- weighted: explicit uniform degeneration + deprecation ------------------

def test_weighted_without_scores_bitexact_with_uniform():
    """No-signal `weighted` (and `score_weighted`) degenerates to the
    EXACT uniform draw — same key, same selection matrix, bitwise."""
    ctx = _ctx()
    key = jax.random.PRNGKey(11)
    uni = np.asarray(get_strategy("uniform").select(key, ctx))
    with pytest.warns(DeprecationWarning, match="score_weighted"):
        wtd = np.asarray(get_strategy("weighted").select(key, ctx))
    sco = np.asarray(get_strategy("score_weighted").select(key, ctx))
    assert np.array_equal(uni, wtd)
    assert np.array_equal(uni, sco)


def test_weighted_with_scores_keeps_legacy_behavior():
    """Explicit static scores: the historical Gumbel top-k, unchanged —
    high-score units preferred."""
    scores = jnp.asarray([0., 0., 0., 0., 0., 8., 8., 8.])
    with pytest.warns(DeprecationWarning):
        strat = get_strategy("weighted")
    hits = np.zeros(8)
    for r in range(40):
        hits += np.asarray(strat.select_row(
            jax.random.PRNGKey(r), _ctx(scores=scores)))
    assert hits[5:].min() > hits[:5].max()


# -- the three new strategies ----------------------------------------------

def test_score_weighted_prefers_high_score_units_scale_free():
    strat = get_strategy("score_weighted")
    base = jnp.asarray([0., 0., 0., 0., 0., 5., 5., 5.])
    hits = np.zeros(8)
    for r in range(40):
        hits += np.asarray(strat.select_row(
            jax.random.PRNGKey(r), _ctx(scores=base)))
    assert hits[5:].min() > hits[:5].max()
    # standardized ranking: a uniformly rescaled score vector draws the
    # identical selections (selection pressure is scale-free)
    k = jax.random.PRNGKey(3)
    a = np.asarray(strat.select_row(k, _ctx(scores=base)))
    b = np.asarray(strat.select_row(k, _ctx(scores=base * 100.0)))
    np.testing.assert_allclose(a, b)


def test_depth_dropout_anneals_shallow_bias():
    strat = get_strategy("depth_dropout")

    def hits(round_idx, draws=60):
        st = SelectionState(scores=jnp.zeros(8), counts=jnp.zeros(8),
                            round=jnp.asarray(round_idx, jnp.int32))
        h = np.zeros(8)
        for r in range(draws):
            h += np.asarray(strat.select_row(
                jax.random.PRNGKey(r), _ctx(state=st)))
        return h

    early = hits(0)
    late = hits(10 * strat.horizon)
    # early rounds: layer-wise growth concentrates on shallow units
    assert early[:3].sum() > early[-3:].sum() * 1.5
    # annealed out: all depths compete (within sampling noise)
    assert late[-3:].sum() > late[:3].sum() * 0.5
    # every draw keeps the static n_train sparsity (packed-path contract)
    row = np.asarray(strat.select_row(jax.random.PRNGKey(0),
                                      _ctx(state=None)))
    assert row.sum() == 3


def test_successive_window_grows_deterministically():
    strat = get_strategy("successive")
    seen = []
    for r in range(0, 6 * strat.phase_rounds, strat.phase_rounds):
        st = SelectionState(scores=jnp.zeros(8), counts=jnp.zeros(8),
                            round=jnp.asarray(r, jnp.int32))
        row = np.asarray(strat.select_row(None, _ctx(state=st)))
        assert row.sum() == 3                    # exactly n_train
        start = int(np.argmax(row))
        assert np.array_equal(np.flatnonzero(row),
                              np.arange(start, start + 3))
        seen.append(start)
    # windows advance by n_train per phase, then clamp at the deep end
    assert seen == [0, 3, 5, 5, 5, 5]


def test_scored_state_update_ema_and_counts():
    strat = ScoredStrategy()
    ctx = dataclasses.replace(_ctx(n_units=4, n_train=2), score_ema=0.5)
    st = strat.init_state(ctx)
    assert int(st.round) == 0 and float(st.counts.sum()) == 0.0
    # round 1: units 0,1 observed -> scores adopt their first norm
    t1 = NormTelemetry(unit_sqnorm=np.array([4.0, 16.0, 0, 0]),
                       unit_count=np.array([1.0, 4.0, 0, 0]),
                       unit_raw_count=np.array([1.0, 4.0, 0, 0]))
    st = strat.update_state(st, ctx, t1)
    np.testing.assert_allclose(np.asarray(st.scores),
                               [2.0, 2.0, 0.0, 0.0])
    # telemetry None (off-cadence / skipped round): counter still moves
    st = strat.update_state(st, ctx, None)
    assert int(st.round) == 2
    np.testing.assert_allclose(np.asarray(st.counts), [1, 4, 0, 0])
    # round 3: unit 0 observed again -> EMA; unit 2 first-seen -> adopt
    t2 = NormTelemetry(unit_sqnorm=np.array([36.0, 0, 9.0, 0]),
                       unit_count=np.array([1.0, 0, 1.0, 0]),
                       unit_raw_count=np.array([1.0, 0, 1.0, 0]))
    st = strat.update_state(st, ctx, t2)
    np.testing.assert_allclose(np.asarray(st.scores),
                               [0.5 * 2 + 0.5 * 6, 2.0, 3.0, 0.0])
    np.testing.assert_allclose(np.asarray(st.counts), [2, 4, 1, 0])


def test_scored_state_update_decays_with_staleness_confidence():
    """Staleness-weighted telemetry moves the EMA by the mean staleness
    factor of its observations: the factor must NOT cancel out of the
    update (weighted norm / weighted count alone would), and a
    fully-decayed observation must not move the score at all."""
    strat = ScoredStrategy()
    ctx = dataclasses.replace(_ctx(n_units=3, n_train=1), score_ema=0.5)
    st = strat.init_state(ctx)
    # establish a prior score of 2.0 on every unit (confidence 1)
    st = strat.update_state(st, ctx, NormTelemetry(
        unit_sqnorm=np.array([4.0, 4.0, 4.0]),
        unit_count=np.ones(3), unit_raw_count=np.ones(3)))
    np.testing.assert_allclose(np.asarray(st.scores), [2.0, 2.0, 2.0])
    # one observation of norm 6 per unit, at staleness factors 1 / 0.5
    # / 0 -> EMA steps (1-beta)*factor = 0.5 / 0.25 / 0.0
    t = NormTelemetry(unit_sqnorm=np.array([36.0, 18.0, 0.0]),
                      unit_count=np.array([1.0, 0.5, 0.0]),
                      unit_raw_count=np.ones(3))
    st = strat.update_state(st, ctx, t)
    np.testing.assert_allclose(
        np.asarray(st.scores),
        [0.5 * 2 + 0.5 * 6, 0.75 * 2 + 0.25 * 6, 2.0], rtol=1e-6)


# -- telemetry: packed == dense bitwise, stateless trace untouched ----------

@pytest.mark.parametrize("topology", ["hub", "hierarchical"])
def test_packed_dense_norm_telemetry_bitexact(topology):
    """With scoring ON the packed path's per-client per-unit norm
    telemetry equals the dense path's BITWISE (norms reduce from the
    grads each path already materialized; PR 3 made those bitwise)."""
    params, assign, batches = _setup()
    st = get_strategy("score_weighted").init_state(
        _ctx(n_units=assign.n_units, n_train=4))
    w = jnp.asarray([1.0, 2.0, 0.0, 3.0])
    rk = jax.random.PRNGKey(5)
    outs = {}
    for packed in (False, True):
        fl = FLConfig(n_clients=C, train_fraction=0.5,
                      strategy="score_weighted", topology=topology,
                      packed=packed, fused_agg="off")
        step = jax.jit(build_round_step(toy_loss, assign, fl))
        outs[packed] = step(params, batches, w, rk, st)
    (p_d, m_d), (p_p, m_p) = outs[False], outs[True]
    assert m_d["unit_sqnorm"].shape == (C, assign.n_units)
    assert np.array_equal(np.asarray(m_d["unit_sqnorm"]),
                          np.asarray(m_p["unit_sqnorm"]))
    _assert_trees_bitexact(p_d, p_p)
    # trained units carry signal, untouched units exact zeros
    sq = np.asarray(m_d["unit_sqnorm"])
    sel = np.asarray(m_d["sel"])
    assert (sq[sel > 0] > 0).all() and (sq[sel == 0] == 0).all()


def test_unit_sqnorm_helpers_agree_with_tree_norms():
    params, assign, _ = _setup()
    grads = jax.tree_util.tree_map(
        lambda x: jnp.ones_like(x) * 0.5, params)
    per_unit = np.asarray(unit_sqnorm(assign, grads))
    total = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree_util.tree_leaves(grads))
    np.testing.assert_allclose(per_unit.sum(), total, rtol=1e-6)
    # packed twin over a full-width identity slot plan matches
    rows = jax.tree_util.tree_map(
        lambda lu, g: jnp.zeros((0,), jnp.int32) if lu.kind == "scalar"
        else jnp.arange(g.shape[0], dtype=jnp.int32),
        assign.leaf_units, grads,
        is_leaf=lambda x: hasattr(x, "kind"))
    packed = np.asarray(unit_sqnorm_packed(assign, grads, rows))
    np.testing.assert_allclose(packed, per_unit)


def test_stateless_round_metrics_carry_no_telemetry():
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off")
    step = jax.jit(build_round_step(toy_loss, assign, fl))
    _, metrics = step(params, batches, jnp.ones(C), jax.random.PRNGKey(0))
    assert "unit_sqnorm" not in metrics


@pytest.mark.parametrize("topology", ["hub", "hierarchical"])
def test_stateless_server_bitexact_with_raw_round_step(topology):
    """The scored-engine plumbing must be invisible to stateless
    strategies: a Server-driven run equals driving the bare jitted
    round step by hand, bitwise."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, topology=topology,
                  fused_agg="off")
    srv = Server(build_round_step(toy_loss, assign, fl), assign, fl,
                 params, seed=13)
    assert srv.sel_state is None
    srv.run_round(batches)
    srv.run_round(batches)

    raw = jax.jit(build_round_step(toy_loss, assign, fl))
    key = jax.random.PRNGKey(13)
    p = params
    for _ in range(2):
        key, rk = jax.random.split(key)
        p, _ = raw(p, batches, jnp.ones(C), rk)
    _assert_trees_bitexact(srv.params, p)


# -- the engine end-to-end --------------------------------------------------

@pytest.mark.parametrize("topology,packed", [("hub", False), ("hub", True),
                                             ("hierarchical", True),
                                             ("gossip", False)])
def test_scored_federation_accumulates_state(topology, packed):
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5,
                  strategy="score_weighted", topology=topology,
                  packed=packed, fused_agg="off")
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=1)
    fed.server.run(3, lambda r: batches)
    st = fed.server.sel_state
    assert int(st.round) == 3
    # every round each client trains n_train=4 units
    assert float(np.asarray(st.counts).sum()) == 3 * C * 4
    assert float(np.asarray(st.scores).max()) > 0.0
    assert len(fed.history) == 3


def test_score_every_throttles_updates_but_round_advances():
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5,
                  strategy="score_weighted", fused_agg="off",
                  score_every=2)
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=1)
    fed.server.run(3, lambda r: batches)          # telemetry rounds 0, 2
    st = fed.server.sel_state
    assert int(st.round) == 3
    assert float(np.asarray(st.counts).sum()) == 2 * C * 4


def test_dropped_clients_contribute_no_telemetry():
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5,
                  strategy="score_weighted", fused_agg="off")

    from repro.core import ServerHook

    class DropAllButOne(ServerHook):
        def on_round_start(self, server, r, weights):
            return weights * jnp.asarray([1.0, 0.0, 0.0, 0.0])

    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=1, hooks=(DropAllButOne(),))
    fed.server.run(2, lambda r: batches)
    st = fed.server.sel_state
    # only client 0's updates count: 2 rounds x 1 client x 4 units
    assert float(np.asarray(st.counts).sum()) == 2 * 4


def test_scored_selection_follows_live_scores():
    """After training, score_weighted's next selections are biased
    toward the units with large norm EMAs (the future-work behaviour:
    live signal feeds selection)."""
    params, assign, batches = _setup(n_blocks=6)
    fl = FLConfig(n_clients=C, train_fraction=0.25,
                  strategy="score_weighted", fused_agg="off",
                  score_ema=0.5)
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=0)
    fed.server.run(12, lambda r: batches)
    scores = np.asarray(fed.server.sel_state.scores)
    late_sel = np.stack(fed.server.sel_history[6:]).sum((0, 1))
    top = np.argsort(-scores)[:2]
    bottom = np.argsort(-scores)[-2:]
    assert late_sel[top].mean() > late_sel[bottom].mean()


def test_server_honors_round_step_strategy_override():
    """A strategy= override baked into build_round_step must drive the
    Server's state ownership even when fl.strategy says otherwise (the
    instance rides on the round step; no parallel name re-resolution)."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off")
    step = build_round_step(toy_loss, assign, fl,
                            strategy="score_weighted")
    srv = Server(step, assign, fl, params, seed=2)
    assert srv.strategy.name == "score_weighted"
    srv.run_round(batches)
    assert srv.sel_state is not None and int(srv.sel_state.round) == 1
    assert float(np.asarray(srv.sel_state.scores).max()) > 0.0


# -- FLConfig validation (satellite) ---------------------------------------

@pytest.mark.parametrize("kw", [dict(train_fraction=0.0),
                                dict(train_fraction=25.0),
                                dict(train_fraction=-0.5)])
def test_flconfig_rejects_bad_train_fraction(kw):
    with pytest.raises(ValueError, match="train_fraction"):
        FLConfig(n_clients=4, **kw)


@pytest.mark.parametrize("kw", [dict(score_ema=1.0), dict(score_ema=-0.1),
                                dict(score_every=0)])
def test_flconfig_rejects_bad_score_knobs(kw):
    with pytest.raises(ValueError, match="score_"):
        FLConfig(n_clients=4, **kw)


def test_flconfig_accepts_paper_settings():
    for f in (0.25, 0.5, 0.75, 1.0):
        assert FLConfig(n_clients=4, train_fraction=f).train_fraction == f


# -- checkpoint restore (satellite): sync path ------------------------------

def test_sel_state_ckpt_restore_sync_bitexact(tmp_path):
    """Kill/restore mid-fit with score_weighted: the resumed run's
    params AND selection state match the uninterrupted run bitwise."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5,
                  strategy="score_weighted", fused_agg="off")
    path = str(tmp_path / "scored")
    from repro.ckpt import restore_server_state, save_server_state

    f1 = Federation(loss_fn=toy_loss, params=params, assign=assign,
                    fl=fl, seed=3)
    f1.server.run(2, lambda r: batches)
    save_server_state(path, f1.server)
    f1.server.run(2, lambda r: batches)

    f2 = Federation(loss_fn=toy_loss, params=params, assign=assign,
                    fl=fl, seed=3)
    meta = restore_server_state(path, f2.server)
    assert meta["round"] == 2 and meta["sel_state"]
    f2.server.run(2, lambda r: batches)
    _assert_trees_bitexact(f1.params, f2.params)
    _assert_trees_bitexact(f1.server.sel_state, f2.server.sel_state)


def test_sel_state_ckpt_mismatch_rejected(tmp_path):
    params, assign, batches = _setup()
    from repro.ckpt import restore_server_state, save_server_state
    scored = FLConfig(n_clients=C, train_fraction=0.5,
                      strategy="score_weighted", fused_agg="off")
    plain = dataclasses.replace(scored, strategy="uniform")
    f1 = Federation(loss_fn=toy_loss, params=params, assign=assign,
                    fl=scored, seed=0)
    f1.server.run(1, lambda r: batches)
    p1 = str(tmp_path / "scored")
    save_server_state(p1, f1.server)
    f2 = Federation(loss_fn=toy_loss, params=params, assign=assign,
                    fl=plain, seed=0)
    with pytest.raises(ValueError, match="stateful strategy"):
        restore_server_state(p1, f2.server)

    f3 = Federation(loss_fn=toy_loss, params=params, assign=assign,
                    fl=plain, seed=0)
    f3.server.run(1, lambda r: batches)
    p2 = str(tmp_path / "plain")
    save_server_state(p2, f3.server)
    f4 = Federation(loss_fn=toy_loss, params=params, assign=assign,
                    fl=scored, seed=0)
    with pytest.raises(ValueError, match="no selection state"):
        restore_server_state(p2, f4.server)
