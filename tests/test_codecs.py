"""Uplink compression codec axis (DESIGN.md §16): the codec registry,
per-row round-trip error bounds, Pallas quantize-pack kernel == jnp
reference bitwise, claimed bytes == encoded wire bytes, error-feedback
residual exactness + bit-exact mid-fit checkpoint restore on the sync
AND async paths, encoded-width wasted-bytes accounting under faults ×
codecs, and config-time validation of the codec knobs."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import restore_server_state, save_server_state
from repro.core import (Codec, FLConfig, Federation, ServerHook,
                        UnknownCodecError, available_codecs,
                        build_codec_transform, codec_unit_bytes, comm,
                        encoded_wire_bytes, get_codec, init_codec_state,
                        register_codec, resolve_codec, slot_plan,
                        unregister_codec)
from repro.models.toy import init_toy_mlp, toy_batches, toy_loss, toy_units

C = 4


def _setup():
    key = jax.random.PRNGKey(0)
    params = init_toy_mlp(key, n_blocks=6, d=16, hidden=32, out=4)
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1), n_clients=C,
                          steps=2, batch=2, d=16, out=4)
    return params, assign, batches


SYNC = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                fused_agg="off")
COHORT = dataclasses.replace(SYNC, cohort_chunk=2, n_registered=C)
ASYNC = dataclasses.replace(SYNC, async_buffer=C, staleness="constant",
                            client_delay_dist="none")


def _fed(fl, params, assign, **kw):
    return Federation(loss_fn=toy_loss, params=params, assign=assign,
                      fl=fl, seed=3, **kw)


def _run(fed, fl, batches, rounds=3):
    if fl.uses_cohort_engine():
        return fed.server.run(rounds, lambda r, ids: jax.tree_util.tree_map(
            lambda x: x[np.asarray(ids)], batches))
    return fed.server.run(rounds, lambda r: batches)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _assert_bitequal(a, b, what="trees"):
    for x, y in zip(_leaves(a), _leaves(b)):
        assert np.array_equal(x, y), f"{what} diverged bitwise"


# -- registry (the plugin-axis contract) -----------------------------------

def test_codec_registry_and_plugin():
    assert {"none", "qint8", "qint4", "topk_ef"} <= set(available_codecs())
    with pytest.raises(UnknownCodecError) as e:
        get_codec("gzip")
    assert "registered" in str(e.value)

    @register_codec
    class Half(Codec):
        """Test-only: claims half-width rows, decodes to identity."""
        name = "half"

        def row_bytes(self, p, fl=None):
            return 2 * p

        def row_roundtrip(self, x2, key, fl=None):
            return x2

    try:
        assert "half" in available_codecs()
        assert resolve_codec("half").row_bytes(4) == 8
    finally:
        unregister_codec("half")
    assert "half" not in available_codecs()
    assert resolve_codec(None).name == "none"
    inst = get_codec("qint8")
    assert resolve_codec(inst) is inst


# -- per-row round-trip properties -----------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_kernel_matches_reference_bitwise(bits):
    """The fused Pallas quantize-pack kernel and the jnp reference are
    the SAME function: packed codes and scales bitwise equal, eager and
    under jit (odd row width exercises the int4 pad lane)."""
    from repro.kernels.codec import (dequantize_unpack, quantize_pack,
                                     quantize_pack_ref)
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (9, 37)) * jnp.linspace(0.1, 10.0, 9)[:, None]
    u = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    pk, sk = quantize_pack(x, u, bits)
    pr, sr = quantize_pack_ref(x, u, bits)
    assert pk.dtype == pr.dtype
    assert np.array_equal(np.asarray(pk), np.asarray(pr))
    assert np.array_equal(np.asarray(sk), np.asarray(sr))
    pj, sj = jax.jit(lambda a, b: quantize_pack(a, b, bits))(x, u)
    assert np.array_equal(np.asarray(pj), np.asarray(pk))
    assert np.array_equal(np.asarray(sj), np.asarray(sk))
    # decode shape/width round-trips through the packed layout
    xh = dequantize_unpack(pk, sk, bits, x.shape[1])
    assert xh.shape == x.shape


@pytest.mark.parametrize("name,qmax", [("qint8", 127), ("qint4", 7)])
def test_quant_roundtrip_error_bounded_by_scale(name, qmax):
    """|decode(encode(x)) - x| <= absmax/qmax per row (one quantization
    step), and all-zero rows survive EXACTLY (no spurious scale)."""
    codec = get_codec(name)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (16, 33)) * \
        jnp.logspace(-2, 1, 16)[:, None]
    xh = codec.row_roundtrip(x, jax.random.fold_in(key, 1))
    scale = np.abs(np.asarray(x)).max(axis=1) / qmax
    err = np.abs(np.asarray(xh) - np.asarray(x))
    assert (err <= scale[:, None] * (1 + 1e-5) + 1e-12).all()
    z = codec.row_roundtrip(jnp.zeros((3, 33)), key)
    assert np.array_equal(np.asarray(z), np.zeros((3, 33), np.float32))


def test_none_roundtrip_bitwise_and_topk_support():
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 40))
    assert get_codec("none").row_roundtrip(x, None) is x
    topk = get_codec("topk_ef")
    xh = np.asarray(topk.row_roundtrip(x, None))     # default keep 0.1
    assert ((xh != 0).sum(axis=1) <= 4).all()        # k = ceil(.1 * 40)
    # transmitted coords carry the exact original values
    mask = xh != 0
    assert np.array_equal(xh[mask], np.asarray(x)[mask])
    # and they are the largest-magnitude ones per row
    kept_min = np.where(mask, np.abs(np.asarray(x)), np.inf).min(axis=1)
    dropped_max = np.where(mask, 0.0, np.abs(np.asarray(x))).max(axis=1)
    assert (kept_min >= dropped_max).all()


def test_row_bytes_formulas_and_none_matches_fp32():
    p = 37
    assert get_codec("none").row_bytes(p) == 4 * p
    assert get_codec("qint8").row_bytes(p) == p + 4
    assert get_codec("qint4").row_bytes(p) == (p + 1) // 2 + 4
    fl = dataclasses.replace(SYNC, codec="topk_ef", codec_topk=0.25)
    assert get_codec("topk_ef").row_bytes(40, fl) == 8 * 10
    params, assign, _ = _setup()
    assert np.array_equal(
        codec_unit_bytes(get_codec("none"), assign, params),
        comm.unit_bytes(assign, params).astype(np.int64))


# -- claimed bytes == encoded wire bytes -----------------------------------

@pytest.mark.parametrize("name", ["none", "qint8", "qint4", "topk_ef"])
def test_claimed_bytes_equal_encoded_wire_bytes(name):
    """``sel @ codec_unit_bytes`` (what CommAccounting bills) equals the
    ground-truth sum of per-row wire bytes over the slot plan's valid
    rows — for strategy-shaped selections (exactly n_train units per
    participant) including a zero-participation client."""
    params, assign, _ = _setup()
    fl = dataclasses.replace(SYNC, codec=name, codec_topk=0.25) \
        if name != "none" else SYNC
    codec = get_codec(name)
    n_slots = fl.resolve_n_slots(assign.n_units)
    n_train = fl.resolve_n_train(assign.n_units)
    rng = np.random.default_rng(0)
    for trial in range(4):
        sel = np.zeros((C, assign.n_units), np.float32)
        for c in range(C):
            sel[c, rng.choice(assign.n_units, n_train, replace=False)] = 1
        if trial == 0:
            sel[-1] = 0.0                 # non-participant ships nothing
        _, valid = jax.vmap(
            lambda s: slot_plan(assign, s, n_slots, params)
        )(jnp.asarray(sel))
        claimed = float((sel @ codec_unit_bytes(codec, assign, params,
                                                fl)).sum())
        assert claimed == encoded_wire_bytes(codec, assign, params,
                                             valid, fl)


@pytest.mark.parametrize("fl0,topo", [(SYNC, "hub"),
                                      (SYNC, "hierarchical"),
                                      (COHORT, "hub")],
                         ids=["sync-hub", "sync-hier", "cohort-hub"])
def test_billed_uplink_matches_encoded_wire_bytes_in_runs(fl0, topo):
    """End-to-end: every round's billed uplink equals the encoded bytes
    the round's selection actually put on the WAN (hierarchical bills
    the per-edge union — one encoded partial aggregate per union unit)."""
    params, assign, batches = _setup()
    fl = dataclasses.replace(fl0, codec="qint8", topology=topo)
    fed = _fed(fl, params, assign)
    _run(fed, fl, batches)
    codec = get_codec("qint8")
    n_slots = fl.resolve_n_slots(assign.n_units)
    wub = np.asarray(fed.server.wire_unit_bytes(), np.float64)
    for r, rec in enumerate(fed.history):
        sel = np.asarray(fed.server.sel_history[r])
        if topo == "hierarchical":
            # the WAN carries one partial aggregate per *union* unit —
            # plan at full width, a union can exceed n_slots
            mem = comm.edge_membership(C, fl.resolve_n_edges())
            wire_sel = (mem @ sel > 0).astype(np.float32)
            plan_slots = assign.n_units
        else:
            wire_sel = sel
            plan_slots = n_slots
        _, valid = jax.vmap(
            lambda s: slot_plan(assign, s, plan_slots, params)
        )(jnp.asarray(wire_sel))
        encoded = encoded_wire_bytes(codec, assign, params, valid, fl)
        assert rec.uplink_bytes == encoded, f"round {r}"
        assert float((wire_sel @ wub).sum()) == encoded


def test_qint8_cuts_uplink_at_least_3x_under_partial_freeze():
    """The composed story: at 50% freeze, switching the remaining uplink
    to qint8 cuts billed bytes close to 4x (scale overhead costs a
    little) while the fp32 full-model denominator stays put."""
    params, assign, batches = _setup()
    ref = _fed(SYNC, params, assign)
    _run(ref, SYNC, batches)
    q = _fed(dataclasses.replace(SYNC, codec="qint8"), params, assign)
    _run(q, SYNC, batches)
    su, sq = ref.comm_summary(), q.comm_summary()
    assert sq["avg_uplink_bytes"] * 3.5 < su["avg_uplink_bytes"]
    assert sq["reduction_vs_full"] > su["reduction_vs_full"]
    # selection itself is codec-independent (same strategy stream)
    _assert_bitequal(ref.server.sel_history, q.server.sel_history, "sel")


# -- error feedback --------------------------------------------------------

def test_topk_ef_residual_identity_and_dropped_clients():
    """Per round: transmitted + residual == signal EXACTLY (error
    feedback loses nothing), and a zero-weight client's residual stays
    untouched — it never uploaded."""
    params, assign, _ = _setup()
    fl = dataclasses.replace(SYNC, codec="topk_ef", codec_topk=0.25)
    codec = get_codec("topk_ef")
    transform = build_codec_transform(codec, assign, fl)
    n_slots = fl.resolve_n_slots(assign.n_units)
    n_train = fl.resolve_n_train(assign.n_units)
    rng = np.random.default_rng(1)
    sel = np.zeros((C, assign.n_units), np.float32)
    for c in range(C):
        sel[c, rng.choice(assign.n_units, n_train, replace=False)] = 1
    rows, valid = jax.vmap(
        lambda s: slot_plan(assign, s, n_slots, params))(jnp.asarray(sel))
    key = jax.random.PRNGKey(5)
    pdeltas = jax.tree_util.tree_map(
        lambda r, v: jax.random.normal(
            jax.random.fold_in(key, v.ndim), np.shape(r)), *[None], None) \
        if False else None
    # build a random packed payload with the decoded shapes
    flat, treedef = jax.tree_util.tree_flatten(params)
    from repro.core.masking import _is_leafunit
    lus = jax.tree_util.tree_leaves(assign.leaf_units,
                                    is_leaf=_is_leafunit)
    leaves = []
    for i, (leaf, lu, r) in enumerate(
            zip(flat, lus, jax.tree_util.tree_leaves(rows))):
        shape = (C,) + tuple(leaf.shape) if lu.kind == "scalar" \
            else (C, r.shape[1]) + tuple(leaf.shape[1:])
        leaves.append(jax.random.normal(jax.random.fold_in(key, i),
                                        shape, jnp.float32))
    pdeltas = jax.tree_util.tree_unflatten(treedef, leaves)
    state = init_codec_state(codec, params, C)
    w = jnp.ones((C,), jnp.float32).at[1].set(0.0)   # client 1 dropped
    decay = jnp.ones((C,), jnp.float32)
    decoded, new_state = transform(pdeltas, rows, valid, w,
                                   jax.random.fold_in(key, 99), state,
                                   decay)
    for d, dec, v, s0, s1, lu, r in zip(
            jax.tree_util.tree_leaves(pdeltas),
            jax.tree_util.tree_leaves(decoded),
            jax.tree_util.tree_leaves(valid),
            jax.tree_util.tree_leaves(state),
            jax.tree_util.tree_leaves(new_state), lus,
            jax.tree_util.tree_leaves(rows)):
        d, dec, v = np.asarray(d), np.asarray(dec), np.asarray(v)
        s1 = np.asarray(s1)
        vm = v.reshape(v.shape + (1,) * (d.ndim - v.ndim))
        if lu.kind == "scalar":
            res_rows = s1                          # (C, ...) leaf-space
        else:
            res_rows = np.stack([np.asarray(s1[c])[np.asarray(r)[c]]
                                 for c in range(C)])
        active = (vm > 0) & \
            (np.asarray(w).reshape((C,) + (1,) * (d.ndim - 1)) > 0)
        # transmitted + residual reconstructs the signal exactly
        np.testing.assert_array_equal(
            np.where(active, dec + res_rows, 0.0),
            np.where(active, d * vm, 0.0))
        # the dropped client's residual is bitwise the old one (zeros)
        assert np.array_equal(s1[1], np.asarray(s0)[1])


@pytest.mark.parametrize("fl0", [SYNC, ASYNC], ids=["sync", "async"])
def test_ef_state_checkpoint_restore_bit_exact_mid_fit(fl0, tmp_path):
    """run(4) == run(2) + save + restore-into-fresh + run(2), bitwise —
    params AND the EF residual pytree (DESIGN.md §16 + ckpt/store.py)."""
    params, assign, batches = _setup()
    fl = dataclasses.replace(fl0, codec="topk_ef", codec_topk=0.25)
    ref = _fed(fl, params, assign)
    _run(ref, fl, batches, rounds=4)
    a = _fed(fl, params, assign)
    _run(a, fl, batches, rounds=2)
    path = os.path.join(tmp_path, "ck")
    save_server_state(path, a.server)
    b = _fed(fl, params, assign)
    restore_server_state(path, b.server)
    _assert_bitequal(a.server.codec_state, b.server.codec_state, "EF")
    _run(b, fl, batches, rounds=2)
    _assert_bitequal(ref.server.params, b.server.params, "params")
    _assert_bitequal(ref.server.codec_state, b.server.codec_state, "EF")
    # EF is live, not a zeros pytree
    assert sum(float(np.abs(x).sum())
               for x in _leaves(ref.server.codec_state)) > 0
    if fl.async_buffer:
        eng = ref.server.async_engine
        assert eng._codec_version.max() > 0     # dispatches tagged


def test_codec_checkpoint_restore_validates_both_directions(tmp_path):
    params, assign, batches = _setup()
    fl = dataclasses.replace(SYNC, codec="topk_ef")
    a = _fed(fl, params, assign)
    _run(a, fl, batches, rounds=1)
    path = os.path.join(tmp_path, "ck")
    save_server_state(path, a.server)
    plain = _fed(SYNC, params, assign)
    with pytest.raises(ValueError, match="error-feedback"):
        restore_server_state(path, plain.server)
    save_server_state(os.path.join(tmp_path, "ck2"), plain.server)
    fresh = _fed(fl, params, assign)
    with pytest.raises(ValueError, match="no codec state"):
        restore_server_state(os.path.join(tmp_path, "ck2"), fresh.server)


# -- wasted-bytes accounting under faults × codecs (the PR 8 bugfix) -------

class _Quars(ServerHook):
    def __init__(self):
        self.rows = []

    def on_round_end(self, server, record, metrics):
        q = None if metrics is None else metrics.get("quarantined")
        self.rows.append(None if q is None else np.asarray(q, np.float32))


def test_wasted_bytes_billed_at_encoded_width_under_faults():
    """A quarantined upload crossed the WAN *encoded*: wasted bytes must
    be the codec wire bytes of the quarantined selections — not their
    fp32 width (the accounting bug this PR fixes) — and comm_summary
    must stay exact."""
    params, assign, batches = _setup()
    cap = _Quars()
    fl = dataclasses.replace(SYNC, codec="qint8", faults="nan:0.4")
    fed = _fed(fl, params, assign, hooks=[cap])
    _run(fed, fl, batches, rounds=4)
    wub = np.asarray(fed.server.wire_unit_bytes(), np.float64)
    ub = np.asarray(comm.unit_bytes(assign, params), np.float64)
    assert (wub < ub).any() and (wub <= ub).all()
    hit = 0
    for r, rec in enumerate(fed.history):
        sel = np.asarray(fed.server.sel_history[r])
        q = cap.rows[r]
        expect = float((sel[q > 0] @ wub).sum())
        assert rec.wasted_bytes == expect, f"round {r}"
        hit += int((q > 0).sum())
    assert hit > 0, "rate 0.4 over 16 draws fired nothing; seed broken?"
    total = fed.comm_summary()["total_wasted_bytes"]
    assert total == pytest.approx(
        sum(r.wasted_bytes for r in fed.history))


# -- config-time validation ------------------------------------------------

def test_flconfig_codec_validation():
    with pytest.raises(UnknownCodecError):
        dataclasses.replace(SYNC, codec="gzip")
    with pytest.raises(ValueError, match="codec_topk"):
        dataclasses.replace(SYNC, codec_topk=0.0)
    with pytest.raises(ValueError, match="codec_topk"):
        dataclasses.replace(SYNC, codec_topk=1.5)
    with pytest.raises(ValueError, match="packed"):
        FLConfig(n_clients=C, train_fraction=0.5, codec="qint8")
    with pytest.raises(ValueError, match="gossip"):
        dataclasses.replace(SYNC, codec="qint8", topology="gossip")
    with pytest.raises(ValueError, match="cohort"):
        dataclasses.replace(COHORT, codec="topk_ef")
    # stateless codecs DO compose with the cohort engine
    assert dataclasses.replace(COHORT, codec="qint4").codec == "qint4"


def test_none_codec_compiles_no_transform():
    """codec='none' is the absence of a codec: no transform is built, no
    EF state allocated, and the billed unit bytes are the fp32 ones —
    the structural guarantee that every pre-codec path is untouched."""
    params, assign, batches = _setup()
    assert build_codec_transform(get_codec("none"), assign, SYNC) is None
    assert init_codec_state(get_codec("none"), params, C) is None
    fed = _fed(SYNC, params, assign)
    assert fed.server.codec.name == "none"
    assert fed.server.codec_state is None
    assert np.array_equal(np.asarray(fed.server.wire_unit_bytes()),
                          comm.unit_bytes(assign, params))
