"""Masked Adam/SGD: unmasked == textbook; masked leaves state untouched."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.masked import (adam_init, adam_step, sgd_init, sgd_step)


def _textbook_adam(g, m, v, p, t, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    return p - lr * mhat / (np.sqrt(vhat) + eps), m, v


def test_adam_matches_textbook(rng):
    p = {"w": jax.random.normal(rng, (5, 3))}
    st = adam_init(p)
    pn, vn, mn = np.asarray(p["w"]), None, None
    mref = np.zeros((5, 3)); vref = np.zeros((5, 3))
    for t in range(1, 4):
        g = {"w": jax.random.normal(jax.random.fold_in(rng, t), (5, 3))}
        p, st = adam_step(g, st, p, lr=1e-2)
        pn, mref, vref = _textbook_adam(np.asarray(g["w"]), mref, vref,
                                        pn, t)
        np.testing.assert_allclose(np.asarray(p["w"]), pn, rtol=1e-5)


def test_masked_adam_freezes_param_and_state(rng):
    p = {"a": jnp.ones((4, 2)), "b": jnp.ones((3,))}
    mask = {"a": jnp.zeros(()), "b": jnp.ones(())}
    st = adam_init(p)
    g = {"a": jnp.full((4, 2), 0.5), "b": jnp.full((3,), 0.5)}
    p2, st2 = adam_step(g, st, p, lr=1e-2, mask=mask)
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.ones((4, 2)))
    np.testing.assert_array_equal(np.asarray(st2.mu["a"]), 0.0)
    np.testing.assert_array_equal(np.asarray(st2.nu["a"]), 0.0)
    assert not np.allclose(np.asarray(p2["b"]), 1.0)
    assert np.abs(np.asarray(st2.mu["b"])).max() > 0


def test_masked_adam_partial_leaf(rng):
    """Per-macro masks freeze individual slices of a stacked leaf."""
    p = {"blk": jnp.ones((4, 3, 2))}           # 4 stacked layers
    mask = {"blk": jnp.asarray([1.0, 0.0, 1.0, 0.0])}
    st = adam_init(p)
    g = {"blk": jnp.full((4, 3, 2), 1.0)}
    p2, _ = adam_step(g, st, p, lr=1e-2, mask=mask)
    moved = np.abs(np.asarray(p2["blk"]) - 1.0).sum(axis=(1, 2))
    assert moved[0] > 0 and moved[2] > 0
    assert moved[1] == 0 and moved[3] == 0


def test_sgd_momentum(rng):
    p = {"w": jnp.zeros((3,))}
    st = sgd_init(p)
    g = {"w": jnp.ones((3,))}
    p, st = sgd_step(g, st, p, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(np.asarray(p["w"]), -0.1, rtol=1e-6)
    p, st = sgd_step(g, st, p, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(np.asarray(p["w"]), -0.1 - 0.19, rtol=1e-5)


def test_sgd_masked(rng):
    p = {"w": jnp.zeros((3,))}
    st = sgd_init(p)
    g = {"w": jnp.ones((3,))}
    p2, st2 = sgd_step(g, st, p, lr=0.1, mask={"w": jnp.zeros(())})
    np.testing.assert_array_equal(np.asarray(p2["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(st2.momentum["w"]), 0.0)
