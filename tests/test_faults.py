"""Fault-injection chaos axis (DESIGN.md §14): the fault registry and
spec parser, seeded determinism, the zero-rate bitwise-transparency
property on all three round paths (sync packed / buffered-async /
chunked-cohort), quarantine exactness against the injected corruption
plan, crash resample with bounded retry, permanent in-transit loss, and
the kill-at-any-boundary + auto-resume crash-restart harness."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.retry import Backoff, retry_call
from repro.core import (Checkpointer, FLConfig, Fault, Federation,
                        ServerHook, UnknownFaultError, get_fault,
                        parse_faults, register_fault, registered_faults,
                        run_with_restarts, unregister_fault)
from repro.core.faults import FaultInjector
from repro.data import FederatedLoader, iid_partition
from repro.models.toy import init_toy_mlp, toy_batches, toy_loss, toy_units

C = 4


def _setup():
    key = jax.random.PRNGKey(0)
    params = init_toy_mlp(key, n_blocks=6, d=16, hidden=32, out=4)
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1), n_clients=C,
                          steps=2, batch=2, d=16, out=4)
    return params, assign, batches


def _bf(batches):
    return lambda r, ids: jax.tree_util.tree_map(
        lambda x: x[np.asarray(ids)], batches)


def _leaves(fed):
    return [np.asarray(x)
            for x in jax.tree_util.tree_leaves(fed.server.params)]


def _assert_bitequal(a, b):
    for x, y in zip(_leaves(a), _leaves(b)):
        assert np.array_equal(x, y), "params diverged bitwise"


SYNC = FLConfig(n_clients=C, train_fraction=0.5, packed=True,
                fused_agg="off")
COHORT = dataclasses.replace(SYNC, cohort_chunk=2, n_registered=C)
ASYNC = dataclasses.replace(SYNC, async_buffer=C, staleness="constant",
                            client_delay_dist="none")


def _fed(fl, params, assign, **kw):
    return Federation(loss_fn=toy_loss, params=params, assign=assign,
                      fl=fl, seed=3, **kw)


def _run(fed, fl, batches, rounds=3):
    if fl.uses_cohort_engine():
        return fed.server.run(rounds, _bf(batches))
    return fed.server.run(rounds, lambda r: batches)


# -- registry + parser -----------------------------------------------------

def test_fault_registry_and_parser():
    assert {"crash", "nan", "inf", "bitflip", "scale", "duplicate",
            "torn", "kill"} <= set(registered_faults())
    faults = parse_faults("crash:0.1,nan:0.05,scale:0.02:512")
    assert [f.name for f in faults] == ["crash", "nan", "scale"]
    assert faults[0].prob == pytest.approx(0.1)
    assert faults[2].param == pytest.approx(512.0)
    with pytest.raises(UnknownFaultError) as e:
        get_fault("meteor")
    assert "registered" in str(e.value)
    with pytest.raises(ValueError):
        parse_faults("crash:1.5")
    with pytest.raises(ValueError):
        parse_faults("crash:oops")
    with pytest.raises(ValueError):
        parse_faults("crash")


def test_register_fault_plugin():
    @register_fault
    class Meteor(Fault):
        name = "meteor"
        seam = "crash"
    try:
        assert "meteor" in registered_faults()
        (f,) = parse_faults("meteor:0.5")
        assert isinstance(f, Meteor) and f.prob == 0.5
    finally:
        unregister_fault("meteor")
    assert "meteor" not in registered_faults()


def test_injector_determinism():
    a = FaultInjector("crash:0.3,nan:0.2", seed=7)
    b = FaultInjector("crash:0.3,nan:0.2", seed=7)
    assert [a.crashed(r, c) for r in range(5) for c in range(C)] == \
        [b.crashed(r, c) for r in range(5) for c in range(C)]
    pa, pb = a.corrupt_plan(2, range(C)), b.corrupt_plan(2, range(C))
    assert np.array_equal(pa["mode"], pb["mode"])
    other = FaultInjector("crash:0.3,nan:0.2", seed=8)
    grid = [(r, c) for r in range(20) for c in range(C)]
    assert [a.crashed(r, c) for r, c in grid] != \
        [other.crashed(r, c) for r, c in grid]


def test_flconfig_validates_fault_specs():
    with pytest.raises(ValueError):
        FLConfig(n_clients=C, faults="nan:0.1")      # delta needs packed
    with pytest.raises(ValueError):
        FLConfig(n_clients=C, packed=True, faults="duplicate:0.1")
    with pytest.raises(ValueError):
        FLConfig(n_clients=C, client_drop_prob=0.1)  # needs async_buffer
    with pytest.raises(ValueError):
        FLConfig(n_clients=C, faults="nan:0.1", packed=True,
                 topology="gossip")


# -- retry/backoff ---------------------------------------------------------

def test_backoff_deterministic_and_bounded():
    bo = Backoff(attempts=5, base=0.1, factor=2.0, max_delay=0.5,
                 jitter=0.5, seed=3)
    ds = [bo.delay(k, token=(1, 2)) for k in range(5)]
    assert ds == [bo.delay(k, token=(1, 2)) for k in range(5)]
    for k, d in enumerate(ds):
        cap = min(0.1 * 2.0 ** k, 0.5)
        assert 0.5 * cap <= d <= cap
    assert ds != [bo.delay(k, token=(1, 3)) for k in range(5)]


def test_retry_call_retries_then_raises():
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise OSError("transient")
        return "ok"

    assert retry_call(flaky, backoff=Backoff(attempts=3), sleep=None) \
        == "ok"
    assert calls == [0, 1, 2]
    with pytest.raises(OSError):
        retry_call(lambda k: (_ for _ in ()).throw(OSError("always")),
                   backoff=Backoff(attempts=2), sleep=None)


# -- zero-rate chaos is a bitwise no-op on every round path ----------------

@pytest.mark.parametrize("fl", [SYNC, COHORT, ASYNC],
                         ids=["sync", "cohort", "async"])
def test_zero_rate_faults_bitwise_noop(fl):
    """An enabled-but-untripped chaos config (every rate 0.0) must leave
    every round path bitwise identical to a run with no fault axis at
    all: the injected where-chains and the validation gate are exact
    identities when nothing fires."""
    params, assign, batches = _setup()
    ref = _fed(fl, params, assign)
    _run(ref, fl, batches)
    spec = "crash:0,nan:0,kill:0" if not fl.async_buffer \
        else "crash:0,nan:0,kill:0,duplicate:0,torn:0"
    z = _fed(dataclasses.replace(fl, faults=spec), params, assign)
    _run(z, fl, batches)
    _assert_bitequal(ref, z)
    for ra, rb in zip(ref.history, z.history):
        assert ra.loss == rb.loss
        assert rb.wasted_bytes == 0.0


# -- quarantine ------------------------------------------------------------

class _Capture(ServerHook):
    def __init__(self):
        self.quars = []

    def on_round_end(self, server, record, metrics):
        q = None if metrics is None else metrics.get("quarantined")
        self.quars.append(None if q is None
                          else np.asarray(q, np.float32))


@pytest.mark.parametrize("fl", [SYNC, COHORT], ids=["sync", "cohort"])
def test_quarantine_counts_match_injected_corruptions(fl):
    """Every NaN-corrupted upload — and ONLY those — must be quarantined
    by the validation gate, exactly matching the injector's deterministic
    corruption plan recomputed from the same seed."""
    params, assign, batches = _setup()
    cap = _Capture()
    fed = _fed(dataclasses.replace(fl, faults="nan:0.4"), params, assign,
               hooks=[cap])
    _run(fed, fl, batches, rounds=4)
    inj = fed.server.fault_injector
    assert inj.has_delta
    hit = 0
    for r, q in enumerate(cap.quars):
        want = (inj.corrupt_plan(r, range(C))["mode"] != 0)
        assert q is not None
        assert np.array_equal(q > 0, want), f"round {r}"
        hit += int(want.sum())
    assert hit > 0, "rate 0.4 over 16 draws fired nothing; seed broken?"
    for x in _leaves(fed):
        assert np.isfinite(x).all()
    assert sum(r.wasted_bytes for r in fed.history) > 0.0


def test_norm_gate_quarantines_scaled_deltas():
    """A magnitude-scaled (still finite) delta sails through the
    isfinite check and must be caught by the norm gate instead."""
    params, assign, batches = _setup()
    cap = _Capture()
    fl = dataclasses.replace(SYNC, faults="scale:0.4:4096",
                             max_delta_norm=100.0)
    fed = _fed(fl, params, assign, hooks=[cap])
    _run(fed, SYNC, batches, rounds=3)
    inj = fed.server.fault_injector
    for r, q in enumerate(cap.quars):
        want = (inj.corrupt_plan(r, range(C))["mode"] != 0)
        assert np.array_equal(q > 0, want), f"round {r}"
    for x in _leaves(fed):
        assert np.isfinite(x).all()


def test_chaos_run_completes_finite():
    """The acceptance mix — 10% crash + 5% NaN corruption — must fit to
    completion with finite params on the cohort path (crashed slots are
    resampled from the fleet, corrupted uploads quarantined)."""
    params, assign, batches8 = (_setup()[0], _setup()[1],
                                toy_batches(jax.random.PRNGKey(9),
                                            n_clients=8, steps=2,
                                            batch=2, d=16, out=4))
    fl = dataclasses.replace(COHORT, n_registered=8,
                             faults="crash:0.1,nan:0.05")
    fed = _fed(fl, params, assign)
    hist = fed.server.run(5, _bf(batches8))
    assert len(hist) == 5
    for x in _leaves(fed):
        assert np.isfinite(x).all()
    for r in hist:
        assert np.isfinite(r.loss)


# -- crash resample / dropped rounds ---------------------------------------

def test_cohort_crash_resample_replaces_dead_members():
    """With a fleet larger than the cohort and a moderate crash rate,
    the engine must resample live replacements (full participation) on
    at least some rounds where the original draw crashed."""
    params, assign, _ = _setup()
    batches8 = toy_batches(jax.random.PRNGKey(9), n_clients=8, steps=2,
                           batch=2, d=16, out=4)
    fl = dataclasses.replace(COHORT, n_registered=8, faults="crash:0.3")
    fed = _fed(fl, params, assign)
    eng = fed.server.cohort_engine
    inj = fed.server.fault_injector
    crashed_draws = 0
    for r in range(4):
        p = eng.begin_round()
        # whatever ids ended up in the cohort must be alive (or the
        # slot zero-weighted)
        w = np.asarray(p["w"], np.float32)
        for pos, cid in enumerate(p["ids"]):
            if w[pos] > 0:
                assert not inj.crashed(r, int(cid))
        crashed_draws += sum(inj.crashed(r, int(c)) for c in range(8))
        while p["chunk"] < eng.n_chunks:
            eng.step_chunk(_bf(batches8))
        eng.finish_round()
    assert crashed_draws > 0, "crash:0.3 never fired across 32 draws"


def test_all_crashed_round_degrades_to_dropped():
    """crash:1.0 kills every candidate including resamples: the round
    must degrade to a recorded skip (loss 0.0, dropped=True) rather
    than poisoning the params or raising."""
    params, assign, batches = _setup()
    fl = dataclasses.replace(COHORT, faults="crash:1.0", fault_retries=2)
    fed = _fed(fl, params, assign)
    hist = fed.server.run(2, _bf(batches))
    for rec in hist:
        assert rec.skipped and rec.dropped
        assert rec.loss == 0.0 and not np.isnan(rec.loss)
        assert rec.n_participants == 0
    _assert_bitequal(fed, _fed(fl, params, assign))  # params untouched


# -- async delivery faults -------------------------------------------------

def test_delay_scheduler_drop_prob_deterministic():
    from repro.core import DelayScheduler
    a = DelayScheduler("none", seed=4, drop_prob=0.3)
    b = DelayScheduler("none", seed=4, drop_prob=0.3)
    grid = [(c, s) for c in range(C) for s in range(16)]
    da = [a.dropped(c, s) for c, s in grid]
    assert da == [b.dropped(c, s) for c, s in grid]
    assert any(da) and not all(da)
    none = DelayScheduler("none", seed=4, drop_prob=0.0)
    assert not any(none.dropped(c, s) for c, s in grid)
    with pytest.raises(ValueError):
        DelayScheduler("none", drop_prob=1.0)


def test_async_chaos_completes_and_accounts_waste():
    """Duplicates, torn payloads, in-transit loss and async client
    crashes together: the run completes finite, and the wasted-bytes
    column records the lost traffic."""
    params, assign, batches = _setup()
    fl = dataclasses.replace(ASYNC, client_drop_prob=0.2,
                             faults="duplicate:0.3,torn:0.2,crash:0.1")
    fed = _fed(fl, params, assign)
    hist = fed.server.run(5, lambda r: batches)
    assert len(hist) == 5
    for x in _leaves(fed):
        assert np.isfinite(x).all()
    total = fed.comm_summary()["total_wasted_bytes"]
    assert total > 0.0
    assert total == pytest.approx(sum(r.wasted_bytes for r in hist))


def test_buffered_aggregator_rejects_duplicate_seq():
    from repro.core import BufferedUpdate
    from repro.core.async_agg import BufferedAggregator
    agg = BufferedAggregator(8, "constant", 0.5, lambda *a: a[0])
    upd = BufferedUpdate(client=1, seq=3, version=0, t_done=0.0,
                         weight=1.0, loss=0.0,
                         sel_row=np.zeros((2,), np.float32),
                         pdelta={}, rows=np.zeros((1,), np.int32),
                         valid=np.zeros((1,), np.float32))
    assert agg.push(upd)
    assert not agg.push(upd)                       # exact redelivery
    assert not agg.push(dataclasses.replace(upd, seq=2))   # stale seq
    assert agg.push(dataclasses.replace(upd, seq=4))
    assert agg.push(dataclasses.replace(upd, client=2, seq=3))


# -- kill + resume ---------------------------------------------------------

def _loader():
    rng = np.random.default_rng(0)
    data = {"x": rng.normal(0, 1, (64, 16)).astype(np.float32),
            "y": rng.normal(0, 1, (64, 4)).astype(np.float32)}
    shards = iid_partition(64, C, key=1)
    return FederatedLoader([{k: v[s] for k, v in data.items()}
                            for s in shards], batch_size=2,
                           steps_per_round=2, key=5)


@pytest.mark.parametrize("fl", [SYNC, COHORT, ASYNC],
                         ids=["sync", "cohort", "async"])
def test_kill_and_resume_bitwise_equals_uninterrupted(tmp_path, fl):
    """The crash-restart harness: inject server kills between end-of-
    round hooks, auto-resume from the last checkpoint, and require the
    stitched run to reproduce the uninterrupted fit bit-exactly —
    params, per-round losses and history length."""
    rounds = 5
    params, assign, _ = _setup()
    ref = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=dataclasses.replace(fl, faults=""),
                     loader=_loader(), seed=3)
    ref.fit(rounds)

    path = str(tmp_path / "ck")

    def make(inc):
        return Federation(loss_fn=toy_loss, params=params, assign=assign,
                          fl=dataclasses.replace(fl, faults="kill:0.5"),
                          loader=_loader(), seed=3, incarnation=inc,
                          hooks=[Checkpointer(path, every=1)])

    fed = run_with_restarts(make, rounds, path)
    assert fed.server.fault_injector.incarnation > 0, \
        "kill:0.5 over 5 rounds never fired; the harness proved nothing"
    _assert_bitequal(ref, fed)
    assert len(fed.history) == rounds
    for ra, rb in zip(ref.history, fed.history):
        assert ra.round == rb.round and ra.loss == rb.loss


def test_sync_all_dropped_round_records_zero_loss(capsys):
    """The all-dropped-round NaN leak (sync path): a round with no
    participants must record loss 0.0 + dropped=True and log an
    explicit SKIPPED line, never a NaN that poisons summaries."""
    from repro.core import RoundLogger
    params, assign, batches = _setup()
    fed = _fed(SYNC, params, assign)
    fed.server.hooks.append(RoundLogger(every=1))
    rec = fed.server.run_round(batches, weights=jnp.zeros((C,)))
    assert rec.skipped and rec.dropped and rec.n_participants == 0
    assert rec.loss == 0.0 and not np.isnan(rec.loss)
    assert "SKIPPED" in capsys.readouterr().out
