"""Pallas rwkv6 chunked WKV scan vs per-token oracle + chunked jnp form."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.rwkv6_scan.ops import wkv
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
from repro.models.linear_scan import chunked_linear_scan


def _inputs(key, b, s, h, dk, dv, decay_scale=1.0):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    ld = -jnp.abs(jax.random.normal(ks[3], (b, s, h, dk))) * decay_scale
    u = jax.random.normal(ks[4], (h, dk)) * 0.1
    return r, k, v, ld, u


@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (1, 64, 2, 32, 32, 16), (2, 128, 3, 64, 64, 16),
    (1, 64, 1, 16, 48, 32), (2, 48, 2, 64, 64, 8)])
def test_kernel_matches_per_token_oracle(b, s, h, dk, dv, chunk, rng):
    r, k, v, ld, u = _inputs(rng, b, s, h, dk, dv)
    o, st = wkv(r, k, v, ld, u, chunk=chunk)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, -1)

    o_ref, st_ref = rwkv6_scan_ref(
        fold(r), fold(k), fold(v), fold(ld),
        jnp.broadcast_to(u, (b, h, dk)).reshape(b * h, dk))
    assert float(jnp.abs(fold(o) - o_ref).max()) < 1e-3
    assert float(jnp.abs(st.reshape(b * h, dk, dv) - st_ref).max()) < 1e-3


def test_kernel_matches_model_substrate(rng):
    """The kernel and models/linear_scan agree (same math, same floor)."""
    b, s, h, dk, dv = 2, 64, 2, 32, 32
    r, k, v, ld, u = _inputs(rng, b, s, h, dk, dv)
    o_k, st_k = wkv(r, k, v, ld, u, chunk=16)
    o_c, st_c = chunked_linear_scan(r, k, v, ld, decay_on="k", bonus=u,
                                    chunk=16)
    assert float(jnp.abs(o_k - o_c).max()) < 1e-4
    assert float(jnp.abs(st_k - st_c).max()) < 1e-4


def test_strong_decay_stability(rng):
    """Extreme data-dependent decays stay finite (log-floor behaviour)."""
    b, s, h, dk, dv = 1, 64, 1, 16, 16
    r, k, v, _, u = _inputs(rng, b, s, h, dk, dv)
    ld = jnp.full((b, s, h, dk), -50.0)          # saturating decay
    o, st = wkv(r, k, v, ld, u, chunk=16)
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(st).all())


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype, rng):
    b, s, h, dk, dv = 1, 32, 2, 16, 16
    r, k, v, ld, u = _inputs(rng, b, s, h, dk, dv)
    o, st = wkv(r.astype(dtype), k.astype(dtype), v.astype(dtype),
                ld.astype(dtype), u.astype(dtype), chunk=16)
    assert o.dtype == dtype
    o32, _ = wkv(r, k, v, ld, u, chunk=16)
    # bf16 inputs round r/k/v/decay before the fp32 internal math; the
    # recurrence amplifies that input quantization (~0.1 abs here)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    assert float(jnp.abs(o.astype(jnp.float32) - o32).max()) < tol
