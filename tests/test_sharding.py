"""Sharding rules: full coverage, divisibility on the production mesh.

Mesh-dependent checks run in a subprocess (the 512-fake-device XLA flag
must not leak into this test process — dry-run contract)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import jax
from repro.configs.base import get_config, list_configs
from repro.launch.mesh import make_production_mesh, make_fl_mesh
from repro.launch import specs
from repro.sharding import params_specs, validate_specs, layout_for

out = {"archs": {}}
for multi in (False, True):
    mesh = make_production_mesh(multi_pod=multi)
    key = "multi" if multi else "single"
    out[key + "_shape"] = dict(mesh.shape)
for name in list_configs():
    cfg = get_config(name)
    mesh = make_production_mesh()
    params = specs.params_sds(cfg)
    layout = layout_for(cfg)
    sp = params_specs(params, layout, mesh)
    bad = validate_specs(params, sp, mesh)
    # TP coverage: fraction of params whose spec uses the model axis
    import numpy as np, jax.tree_util as jtu
    from repro.common import flatten_with_paths
    total = sharded = 0
    for (p, leaf), s in zip(flatten_with_paths(params), jtu.tree_leaves(
            sp, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))):
        n = int(np.prod(leaf.shape))
        total += n
        flat_axes = []
        for a in s:
            if isinstance(a, (tuple, list)):
                flat_axes += list(a)
            elif a is not None:
                flat_axes.append(a)
        if "model" in flat_axes or "data" in flat_axes:
            sharded += n
    out["archs"][name] = {"bad": [list(map(str, b)) for b in bad],
                          "sharded_frac": sharded / total,
                          "layout": layout}
fl = make_fl_mesh(16)
out["fl_shape"] = dict(fl.shape)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def mesh_report():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_production_mesh_shapes(mesh_report):
    assert mesh_report["single_shape"] == {"data": 16, "model": 16}
    assert mesh_report["multi_shape"] == {"pod": 2, "data": 16, "model": 16}
    assert mesh_report["fl_shape"] == {"client": 16, "data": 1, "model": 16}


def test_all_archs_specs_valid(mesh_report):
    for name, rec in mesh_report["archs"].items():
        assert rec["bad"] == [], f"{name}: invalid specs {rec['bad']}"


def test_big_archs_mostly_sharded(mesh_report):
    """>=90% of the params of every >=10B arch must actually shard."""
    for name in ("qwen2.5-14b", "gemma3-12b", "internvl2-26b",
                 "llama4-maverick-400b-a17b"):
        frac = mesh_report["archs"][name]["sharded_frac"]
        assert frac > 0.90, f"{name}: only {frac:.2%} of params sharded"


def test_rule_engine_basics():
    """Pure-python spec checks that need no real mesh: use a fake mesh."""
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    import jax
    devs = np.asarray(jax.devices() * 16)[:16].reshape(4, 4)
    mesh = Mesh(devs, ("data", "model"))
    from repro.sharding import spec_for
    # TP: heads dim gets the model axis
    s = spec_for("blocks/sub0/attn/wq", (8, 512, 16, 64), "tp", mesh)
    assert s == P(None, None, "model", None)
    # heads not divisible -> falls back to head_dim
    s = spec_for("blocks/sub0/attn/wq", (8, 512, 10, 64), "tp", mesh)
    assert s == P(None, None, None, "model")
    # fsdp_tp shards d_model over data
    s = spec_for("blocks/sub0/mlp/w_up", (8, 512, 2048), "fsdp_tp", mesh)
    assert s == P(None, "data", "model")
    # experts over model
    s = spec_for("blocks/sub1/moe/w_up", (8, 32, 512, 128), "tp", mesh)
    assert s == P(None, "model", None, None)
    # unknown path -> replicated
    s = spec_for("something/else", (4, 4), "tp", mesh)
    assert s == P()
