"""Per-arch smoke: reduced variant, one forward + one train step on CPU,
output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import ALL_ARCHS, reduced_cfg, tiny_batch
from repro.common import global_norm, tree_any_nan
from repro.models import get_model
from repro.optim.masked import adam_init, adam_step


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(name, rng):
    cfg = reduced_cfg(name)
    m = get_model(cfg)
    params = m.init_params(rng)
    b, s = 2, 32
    batch = tiny_batch(cfg, rng, b, s)
    kw = {}
    if cfg.family == "vlm":
        kw["patches"] = batch["patches"]
    if cfg.family == "audio":
        kw["frames"] = batch["frames"]
    logits, aux, _ = m.forward(params, batch["tokens"], **kw)
    s_out = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, s_out, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_no_nans(name, rng):
    cfg = reduced_cfg(name)
    m = get_model(cfg)
    params = m.init_params(rng)
    batch = tiny_batch(cfg, rng)

    def step(params, opt):
        (loss, _), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(
            params, batch)
        params, opt = adam_step(grads, opt, params, lr=1e-3)
        return params, opt, loss

    params2, opt, loss = jax.jit(step)(params, adam_init(params))
    assert jnp.isfinite(loss), f"{name}: loss {loss}"
    assert not tree_any_nan(params2), f"{name}: NaN params after step"
    # the step actually changed the params
    assert float(global_norm(jax.tree_util.tree_map(
        jnp.subtract, params2, params))) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_two_steps_reduce_loss(name, rng):
    """Two steps on the same batch must reduce loss (learnability)."""
    cfg = reduced_cfg(name)
    m = get_model(cfg)
    params = m.init_params(rng)
    batch = tiny_batch(cfg, rng)

    @jax.jit
    def step(params, opt):
        (loss, _), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(
            params, batch)
        params, opt = adam_step(grads, opt, params, lr=3e-3)
        return params, opt, loss

    opt = adam_init(params)
    losses = []
    for _ in range(3):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{name}: {losses}"
