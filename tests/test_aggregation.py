"""FedAvg / participation-weighted masked FedAvg math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.core.aggregation import fedavg, masked_fedavg
from repro.core.masking import build_units_zoo, build_units_flat
from repro.common import flatten_with_paths
from repro.models import get_model


def _stack_deltas(p, c, key):
    return jax.tree_util.tree_map(
        lambda x: jax.random.normal(
            jax.random.fold_in(key, abs(hash(str(x.shape))) % 10_000),
            (c,) + x.shape) * 0.1, p)


def test_fedavg_weighted_mean(rng):
    p = {"a": jnp.zeros((3,)), "b": {"c": jnp.ones((2, 2))}}
    deltas = {"a": jnp.stack([jnp.ones(3), 3 * jnp.ones(3)]),
              "b": {"c": jnp.stack([jnp.zeros((2, 2)), jnp.ones((2, 2))])}}
    w = jnp.asarray([1.0, 3.0])
    out = fedavg(p, deltas, w)
    np.testing.assert_allclose(out["a"], 2.5 * np.ones(3), rtol=1e-6)
    np.testing.assert_allclose(out["b"]["c"], 1 + 0.75 * np.ones((2, 2)),
                               rtol=1e-6)


def test_masked_fedavg_reduces_to_fedavg_when_all_selected(rng):
    cfg = reduced_cfg("qwen3-1.7b")
    m = get_model(cfg)
    p = m.init_params(rng)
    a = build_units_zoo(cfg, p)
    c = 3
    deltas = _stack_deltas(p, c, rng)
    w = jnp.asarray([1.0, 2.0, 3.0])
    sel = jnp.ones((c, a.n_units))
    got = masked_fedavg(p, deltas, sel, w, a)
    want = fedavg(p, deltas, w)
    for (path, x), (_, y) in zip(flatten_with_paths(got),
                                 flatten_with_paths(want)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5,
                                   err_msg=path)


def test_masked_fedavg_untrained_units_keep_global(rng):
    cfg = reduced_cfg("qwen3-1.7b")
    m = get_model(cfg)
    p = m.init_params(rng)
    a = build_units_zoo(cfg, p)
    c = 4
    deltas = _stack_deltas(p, c, rng)
    sel = jnp.ones((c, a.n_units)).at[:, 1].set(0.0)  # nobody trains unit 1
    out = masked_fedavg(p, deltas, sel, jnp.ones(c), a)
    # layer0 is unit 1 -> its stacked index 0 must be identical to global
    for key in ("ln1", "attn", "ln2", "mlp"):
        got = jax.tree_util.tree_leaves(out["blocks"]["sub0"][key])
        ref = jax.tree_util.tree_leaves(p["blocks"]["sub0"][key])
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g[0]), np.asarray(r[0]))
            # other layers (trained) moved
            assert not np.allclose(np.asarray(g[1]), np.asarray(r[1]))


def test_masked_fedavg_single_participant_unit(rng):
    """A unit trained by exactly one client takes that client's full delta."""
    cfg = reduced_cfg("qwen3-1.7b")
    m = get_model(cfg)
    p = m.init_params(rng)
    a = build_units_zoo(cfg, p)
    c = 3
    deltas = _stack_deltas(p, c, rng)
    sel = jnp.zeros((c, a.n_units)).at[1, 0].set(1.0)  # only client1, unit0
    out = masked_fedavg(p, deltas, sel, jnp.asarray([5., 7., 9.]), a)
    got = np.asarray(out["embed"]["table"])
    want = np.asarray(p["embed"]["table"]) + np.asarray(
        deltas["embed"]["table"][1])
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


def test_weights_zero_client_excluded(rng):
    """Dropout/straggler: weight-0 clients contribute nothing."""
    p = {"a": jnp.zeros((4,))}
    a = build_units_flat(p, ["a"])
    deltas = {"a": jnp.stack([jnp.ones(4) * 100, jnp.ones(4)])}
    sel = jnp.ones((2, 1))
    out = masked_fedavg(p, deltas, sel, jnp.asarray([0.0, 2.0]), a)
    np.testing.assert_allclose(np.asarray(out["a"]), np.ones(4), rtol=1e-6)
