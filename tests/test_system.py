"""End-to-end system behaviour.

1. A full federated experiment through the public API: config -> model ->
   units -> server -> rounds -> checkpoint -> resume -> comm summary.
2. A reduced-scale dry-run (lower+compile with sharding) in a subprocess
   with fake devices, exercising launch/dryrun end to end.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.ckpt import restore_server_state, save_server_state
from repro.core import (FLConfig, build_round_step, build_units_zoo)
from repro.core.server import Server
from repro.data import FederatedLoader, iid_partition, lm_batch
from repro.models import get_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_full_federated_experiment(tmp_path, rng):
    cfg = reduced_cfg("qwen3-1.7b")
    m = get_model(cfg)
    params = m.init_params(rng)
    assign = build_units_zoo(cfg, params)
    data = lm_batch(64, 32, cfg.vocab, key=0)
    shards = iid_partition(64, 4, key=1)
    loader = FederatedLoader(
        [{k: v[s] for k, v in data.items()} for s in shards],
        batch_size=4, steps_per_round=2)
    fl = FLConfig(n_clients=4,
                  n_train_units=max(1, assign.n_units // 2), lr=2e-3)
    srv = Server(build_round_step(m.loss_fn, assign, fl,
                                  loss_kwargs={"attn_impl": "reference"}),
                 assign, fl, params,
                 eval_fn=lambda p: m.loss_fn(
                     p, jax.tree_util.tree_map(jnp.asarray, data),
                     attn_impl="reference")[0])
    hist = srv.run(4, lambda r: jax.tree_util.tree_map(
        jnp.asarray, loader.round_batches(r)))
    assert hist[-1].loss < hist[0].loss
    assert hist[-1].eval_metric is not None

    # checkpoint + resume mid-run
    path = str(tmp_path / "state")
    save_server_state(path, srv)
    srv2 = Server(build_round_step(m.loss_fn, assign, fl,
                                   loss_kwargs={"attn_impl": "reference"}),
                  assign, fl, m.init_params(jax.random.fold_in(rng, 5)))
    meta = restore_server_state(path, srv2)
    assert meta["round"] == 4
    rec = srv2.run_round(jax.tree_util.tree_map(
        jnp.asarray, loader.round_batches(4)))
    assert np.isfinite(rec.loss)

    summ = srv.comm_summary()
    assert 0.2 < summ["reduction_vs_full"] < 0.8


@pytest.mark.slow
def test_dryrun_reduced_subprocess():
    """launch/dryrun machinery end-to-end (lower+compile on 256 fake
    devices) for one representative pair."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_dryrun
rec = run_dryrun("qwen3-1.7b", "decode_32k", verbose=False)
print(json.dumps({"fits": rec["fits_hbm_16gb"],
                  "dominant": rec["roofline"]["dominant"],
                  "chips": rec["chips"],
                  "flops": rec["cost_analysis"]["flops_per_device"]}))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.loads(r.stdout.strip().splitlines()[-1])
    assert rec["chips"] == 256
    assert rec["flops"] > 0
