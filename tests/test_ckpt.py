"""Checkpoint store: roundtrip, manifest, server state resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.ckpt import (load_metadata, load_pytree, save_pytree)
from repro.common import tree_allclose
from repro.models import get_model


def test_roundtrip_model_params(tmp_path, rng):
    cfg = reduced_cfg("gemma3-12b")
    m = get_model(cfg)
    p = m.init_params(rng)
    path = str(tmp_path / "ck")
    save_pytree(path, p, metadata={"round": 7})
    p2 = load_pytree(path, p)
    assert tree_allclose(p, p2)
    assert load_metadata(path)["round"] == 7


def test_manifest_contents(tmp_path):
    p = {"a": jnp.ones((2, 3)), "b": {"c": jnp.zeros((4,), jnp.int32)}}
    path = str(tmp_path / "x")
    save_pytree(path, p)
    with open(path + ".json") as f:
        man = json.load(f)
    assert set(man["paths"]) == {"a", "b/c"}
    assert man["shapes"]["a"] == [2, 3]
    assert man["dtypes"]["b/c"] == "int32"


def test_shape_mismatch_rejected(tmp_path):
    p = {"a": jnp.ones((2, 3))}
    path = str(tmp_path / "x")
    save_pytree(path, p)
    with pytest.raises(ValueError, match="shape"):
        load_pytree(path, {"a": jnp.ones((3, 2))})


def test_scored_server_state_manifest_carries_sel_state(tmp_path):
    """Scored runs persist the SelectionState pytree alongside the
    params (DESIGN.md §11) — visible in the manifest as sel_state/*
    paths — and plain runs keep the legacy flat-params layout."""
    import jax
    from repro.core import FLConfig, Federation
    from repro.ckpt import save_server_state
    from repro.models.toy import (init_toy_mlp, toy_batches, toy_loss,
                                  toy_units)
    p = init_toy_mlp(jax.random.PRNGKey(0), n_blocks=4, d=8, hidden=16,
                     out=4)
    assign = toy_units(p)
    batches = toy_batches(jax.random.PRNGKey(1), n_clients=2, steps=1,
                          batch=2, d=8, out=4)
    for strategy, scored in (("score_weighted", True), ("uniform", False)):
        fl = FLConfig(n_clients=2, train_fraction=0.5, strategy=strategy,
                      fused_agg="off")
        fed = Federation(loss_fn=toy_loss, params=p, assign=assign,
                         fl=fl, seed=0)
        fed.server.run(1, lambda r: batches)
        path = str(tmp_path / strategy)
        save_server_state(path, fed.server)
        with open(path + ".json") as f:
            man = json.load(f)
        has_state = any(k.startswith("sel_state/") for k in man["paths"])
        assert has_state == scored
        assert man["metadata"].get("sel_state", False) == scored
        if scored:
            assert {"sel_state/scores", "sel_state/counts",
                    "sel_state/round"} <= set(man["paths"])


def test_server_state_roundtrip(tmp_path, rng):
    from repro.ckpt import restore_server_state, save_server_state
    from repro.core import FLConfig, build_round_step, build_units_flat
    from repro.models import paper_models as pm

    p = pm.init_vgg16(rng, width_mult=0.125)
    assign = build_units_flat(p, pm.vgg16_units(p))

    def loss_fn(params, batch):
        return pm.xent_loss(pm.vgg16_apply(params, batch["x"]),
                            batch["y"]), {}

    fl = FLConfig(n_clients=2, n_train_units=3, lr=1e-3)
    from repro.core.server import Server
    srv = Server(build_round_step(loss_fn, assign, fl), assign, fl, p)
    batch = {"x": jnp.zeros((2, 1, 2, 32, 32, 3)),
             "y": jnp.zeros((2, 1, 2), jnp.int32)}
    srv.run_round(batch)
    path = str(tmp_path / "srv")
    save_server_state(path, srv)
    srv2 = Server(build_round_step(loss_fn, assign, fl), assign, fl,
                  pm.init_vgg16(jax.random.fold_in(rng, 1),
                                width_mult=0.125))
    meta = restore_server_state(path, srv2)
    assert meta["round"] == 1
    assert tree_allclose(srv.params, srv2.params)


# -- adversarial checkpoint files (DESIGN.md §14) --------------------------

def _save_small(tmp_path, name="adv"):
    from repro.ckpt import save_pytree
    p = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
         "b": {"c": jnp.ones((5,), jnp.int32)}}
    path = str(tmp_path / name)
    save_pytree(path, p, metadata={"round": 3})
    return path, p


def test_truncated_npz_raises_typed_error(tmp_path):
    from repro.ckpt import CorruptCheckpointError
    path, p = _save_small(tmp_path)
    with open(path + ".npz", "rb") as f:
        data = f.read()
    with open(path + ".npz", "wb") as f:
        f.write(data[:len(data) // 2])
    with pytest.raises(CorruptCheckpointError, match="truncated|CRC32"):
        load_pytree(path, p)


def test_bitflipped_npz_raises_typed_error(tmp_path):
    from repro.ckpt import CorruptCheckpointError
    path, p = _save_small(tmp_path)
    with open(path + ".npz", "rb") as f:
        data = bytearray(f.read())
    data[len(data) // 2] ^= 0x40
    with open(path + ".npz", "wb") as f:
        f.write(bytes(data))
    with pytest.raises(CorruptCheckpointError, match="CRC32"):
        load_pytree(path, p)


def test_version_mismatch_raises_typed_error(tmp_path):
    from repro.ckpt import CheckpointVersionError, FORMAT_VERSION
    path, p = _save_small(tmp_path)
    with open(path + ".json") as f:
        man = json.load(f)
    man["format_version"] = FORMAT_VERSION + 1
    with open(path + ".json", "w") as f:
        json.dump(man, f)
    with pytest.raises(CheckpointVersionError, match="format version"):
        load_pytree(path, p)
    with pytest.raises(CheckpointVersionError):
        load_metadata(path)


def test_torn_manifest_raises_typed_error(tmp_path):
    from repro.ckpt import CorruptCheckpointError
    path, p = _save_small(tmp_path)
    with open(path + ".json") as f:
        text = f.read()
    with open(path + ".json", "w") as f:
        f.write(text[:len(text) // 2])        # torn mid-write
    with pytest.raises(CorruptCheckpointError, match="JSON"):
        load_pytree(path, p)


def test_legacy_manifest_without_checksum_still_loads(tmp_path):
    """Pre-versioning checkpoints (no format_version / checksum keys)
    must keep loading — the verification is opt-in by presence."""
    from repro.common import tree_allclose as close
    path, p = _save_small(tmp_path)
    with open(path + ".json") as f:
        man = json.load(f)
    del man["format_version"], man["checksum"]
    with open(path + ".json", "w") as f:
        json.dump(man, f)
    assert close(p, load_pytree(path, p))
    assert load_metadata(path)["round"] == 3


def test_atomic_overwrite_keeps_last_good(tmp_path):
    """A crash mid-save must leave the previous complete checkpoint:
    writes stage to a .tmp path and os.replace over the target, so a
    torn temp file is never visible under the real name."""
    from repro.ckpt import save_pytree
    path, p = _save_small(tmp_path)
    # simulate a writer dying mid-stage: the tmp file exists, torn
    with open(path + ".npz.tmp", "wb") as f:
        f.write(b"torn partial bytes")
    p2 = load_pytree(path, p)          # last-good still loads
    from repro.common import tree_allclose as close
    assert close(p, p2)
    # a later successful save replaces cleanly and remains loadable
    save_pytree(path, p, metadata={"round": 4})
    assert load_metadata(path)["round"] == 4
    assert close(p, load_pytree(path, p))
