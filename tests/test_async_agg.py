"""Buffered semi-async aggregation (DESIGN.md §8): staleness registry,
delay scheduler determinism, FedBuff engine — zero-staleness flushes
bit-exact vs. the synchronous packed round step across topologies x
strategies (incl. stragglers and out-of-order arrival), stale-delta
reweighting, buffered byte accounting — plus the straggler-accounting
bugfixes (dropped clients not billed, rate-0 dropout key stream,
degenerate-round comm guards)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CommAccounting, FLConfig, Federation, Server,
                        ServerHook, StragglerDropout,
                        UnknownStalenessError, build_round_step, comm,
                        get_staleness, register_staleness,
                        registered_staleness, staleness_weights,
                        unregister_staleness)
from repro.core.async_agg import (DelayScheduler, _mixed_window_batches,
                                  parse_delay_dist)
from repro.models.toy import init_toy_mlp, toy_batches, toy_loss, toy_units

C = 4


def _setup(n_blocks=6, d=16, hidden=32, out=4, steps=2, batch=2):
    key = jax.random.PRNGKey(0)
    params = init_toy_mlp(key, n_blocks=n_blocks, d=d, hidden=hidden,
                          out=out)
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1), n_clients=C,
                          steps=steps, batch=batch, d=d, out=out)
    return params, assign, batches


def _assert_trees_bitexact(a, b):
    for pa, pb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), \
            "params diverged bitwise"


# -- staleness registry -----------------------------------------------------

def test_builtin_staleness_registered():
    assert {"polynomial", "constant"} <= set(registered_staleness())


def test_polynomial_staleness_values():
    poly = get_staleness("polynomial")
    s = np.array([0.0, 1.0, 3.0])
    w = poly(s, 0.5)
    assert w[0] == 1.0                       # zero staleness: exact 1
    assert np.all(np.diff(w) < 0)            # monotonically down-weighted
    np.testing.assert_allclose(w[1], 1 / np.sqrt(2))
    const = get_staleness("constant")
    assert np.all(const(s, 0.5) == 1.0)


def test_unknown_staleness_lists_registered():
    with pytest.raises(UnknownStalenessError, match="polynomial"):
        get_staleness("does_not_exist")


def test_custom_staleness_roundtrips():
    @register_staleness(name="_test_linear")
    def lin(s, alpha):
        return 1.0 / (1.0 + alpha * np.asarray(s, np.float64))

    try:
        assert "_test_linear" in registered_staleness()
        np.testing.assert_allclose(
            get_staleness("_test_linear")(np.array([2.0]), 1.0), [1 / 3])
    finally:
        unregister_staleness("_test_linear")
    assert "_test_linear" not in registered_staleness()


def test_zero_staleness_weights_pass_through_bitwise():
    w = np.array([0.1, 2.0, 0.0, 3.7], np.float32)
    eff = staleness_weights(w, np.zeros(4), "polynomial", 0.5)
    assert np.array_equal(eff, w)


# -- delay scheduler --------------------------------------------------------

def test_parse_delay_dist():
    assert parse_delay_dist("none") == ("none", 0.0)
    assert parse_delay_dist("pareto:1.2") == ("pareto", 1.2)
    assert parse_delay_dist("exponential") == ("exponential", 1.0)
    with pytest.raises(ValueError, match="client_delay_dist"):
        parse_delay_dist("cauchy")


@pytest.mark.parametrize("dist", ["none", "exponential", "lognormal:0.5",
                                  "pareto:1.5"])
def test_delay_scheduler_deterministic_and_positive(dist):
    a, b = DelayScheduler(dist, seed=3), DelayScheduler(dist, seed=3)
    draws = [(c, s) for c in range(3) for s in range(4)]
    da = [a.delay(c, s) for c, s in draws]
    assert da == [b.delay(c, s) for c, s in draws]   # stateless replay
    assert all(d > 0 for d in da)
    if dist != "none":
        assert DelayScheduler(dist, seed=4).delay(0, 0) != da[0]


def test_pareto_delays_heavy_tailed():
    sched = DelayScheduler("pareto:1.1", seed=0)
    d = np.array([sched.delay(c, s) for c in range(16) for s in range(16)])
    assert d.min() >= 1.0
    assert d.mean() > np.median(d) * 1.2     # long right tail


# -- zero-staleness flush == synchronous packed round (the anchor) ----------

class _PermutedDelays(DelayScheduler):
    """First dispatches complete in a shuffled order (one completion per
    client before the flush); later dispatches take forever."""

    def __init__(self, order):
        super().__init__("none", 0)
        self.order = order

    def delay(self, client, seq):
        return 100.0 if seq > 0 else 1.0 + 0.1 * self.order[client]


@pytest.mark.parametrize("topology", ["hub", "hierarchical"])
@pytest.mark.parametrize("strategy", ["uniform", "synchronized"])
@pytest.mark.parametrize("arrival", ["inorder", "shuffled"])
def test_flush_zero_staleness_bitexact_vs_sync_round(topology, strategy,
                                                     arrival):
    """B = C and a shared origin version: the first flush must equal one
    synchronous packed round bitwise — with a straggler-zeroed client in
    the weights, and regardless of arrival order (``shuffled`` permutes
    completions; the buffer drains in canonical client order)."""
    params, assign, batches = _setup()
    weights = jnp.asarray([1.0, 2.0, 0.0, 3.0])     # client 2 dropped
    sync_fl = FLConfig(n_clients=C, train_fraction=0.5, strategy=strategy,
                       topology=topology, packed=True, fused_agg="off")
    srv = Server(build_round_step(toy_loss, assign, sync_fl), assign,
                 sync_fl, params, seed=11)
    srv.run_round(batches, weights)

    async_fl = FLConfig(n_clients=C, train_fraction=0.5, strategy=strategy,
                        topology=topology, fused_agg="off",
                        async_buffer=C, client_delay_dist="none")
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=async_fl, seed=11)
    if arrival == "shuffled":
        fed.server.async_engine.scheduler = _PermutedDelays([2, 0, 3, 1])
    fed.server.run(1, lambda w: batches, weights=weights)

    _assert_trees_bitexact(srv.params, fed.params)
    rec = fed.history[0]
    assert rec.staleness_mean == 0.0 and rec.staleness_max == 0.0
    # the dropped client is no participant, same as the sync loop
    assert rec.n_participants == C - 1 == srv.history[0].n_participants
    # dropped client shipped nothing under either loop
    assert rec.uplink_bytes == pytest.approx(srv.history[0].uplink_bytes)


def test_staleness_reweighting_kicks_in_and_matters():
    params, assign, batches = _setup()

    def run(staleness):
        fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off",
                      async_buffer=2, staleness=staleness,
                      staleness_alpha=1.0, client_delay_dist="pareto:1.5")
        fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                         fl=fl, seed=5)
        fed.server.run(4, lambda w: batches)
        return fed

    poly = run("polynomial")
    stale = [r.staleness_mean for r in poly.history]
    assert max(stale) > 0.0                  # in-flight work went stale
    times = [r.sim_time for r in poly.history]
    assert all(b >= a for a, b in zip(times, times[1:]))
    const = run("constant")
    # same schedule, same deltas — only the reweighting differs
    assert [r.staleness_mean for r in const.history] == stale
    leaves_p = jax.tree_util.tree_leaves(poly.params)
    leaves_c = jax.tree_util.tree_leaves(const.params)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(leaves_p, leaves_c))


def test_client_can_contribute_twice_per_flush():
    """A fast client may cycle twice before the buffer fills (B > C):
    both its updates aggregate, tagged with their own round keys."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off",
                  async_buffer=C + 2, client_delay_dist="pareto:1.1")
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=2)
    fed.server.run(2, lambda w: batches)
    for rec, clients in zip(fed.history,
                            fed.server.async_engine.flush_clients):
        assert len(clients) == C + 2
        assert rec.n_participants <= C       # distinct clients only
        assert len(clients) > len(np.unique(clients))


def test_async_rejects_dense_and_gossip():
    params, assign, _ = _setup()
    with pytest.raises(ValueError, match="pack"):
        Federation(loss_fn=toy_loss, params=params, assign=assign,
                   fl=FLConfig(n_clients=C, strategy="full",
                               n_train_units=assign.n_units,
                               async_buffer=2))
    with pytest.raises(ValueError, match="buffered-async"):
        Federation(loss_fn=toy_loss, params=params, assign=assign,
                   fl=FLConfig(n_clients=C, train_fraction=0.5,
                               topology="gossip", async_buffer=2))


def test_mixed_window_batches_routes_per_client():
    per_window = {w: {"x": np.arange(C * 2).reshape(C, 2) + 100 * w}
                  for w in range(3)}
    out = _mixed_window_batches(lambda w: per_window[w], [0, 2, 1, 2])
    np.testing.assert_array_equal(
        np.asarray(out["x"]),
        np.stack([per_window[0]["x"][0], per_window[2]["x"][1],
                  per_window[1]["x"][2], per_window[2]["x"][3]]))


# -- buffered byte accounting ----------------------------------------------

def test_buffered_hub_bytes_closed_form():
    ub = np.array([10.0, 20.0, 40.0])
    entry_sel = np.array([[1, 0, 0], [0, 1, 1], [1, 0, 0]], np.float32)
    d = comm.buffered_hub_round_bytes(entry_sel, ub)
    assert d["uplink"] == 10 + 60 + 10       # one upload per entry
    assert d["downlink"] == 70 * 3           # one re-pull per entry
    assert d["uplink_frac"] == pytest.approx(80 / (70 * 3))
    empty = comm.buffered_hub_round_bytes(np.zeros((0, 3)), ub,
                                          downlink="selected")
    assert empty["uplink"] == 0.0 and empty["uplink_frac"] == 0.0


def test_buffered_hierarchical_bytes_only_flushed_cross_wan():
    ub = np.array([10.0, 20.0, 40.0])
    mem = comm.edge_membership(4, 2)         # edges {0,1} {2,3}
    # clients 0 and 1 (same edge) both trained unit 0; entry for client
    # 2 trained unit 2 — edge 0's two buffered updates cross the WAN as
    # ONE partial for unit 0
    entry_sel = np.array([[1, 0, 0], [1, 0, 0], [0, 0, 1]], np.float32)
    clients = np.array([0, 1, 2])
    d = comm.buffered_hierarchical_round_bytes(entry_sel, clients, ub, mem)
    assert d["client_edge_uplink"] == 10 + 10 + 40
    assert d["edge_hub_uplink"] == 10 + 40 == d["uplink"]
    empty = comm.buffered_hierarchical_round_bytes(
        np.zeros((0, 3)), np.zeros((0,), np.int64), ub, mem)
    assert empty["uplink"] == 0.0 and empty["uplink_frac"] == 0.0


@pytest.mark.parametrize("topology", ["hub", "hierarchical"])
def test_async_records_match_buffered_accounting(topology):
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, topology=topology,
                  fused_agg="off", async_buffer=3,
                  client_delay_dist="pareto:1.5")
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=fl, seed=9)
    fed.server.run(3, lambda w: batches)
    ub = fed.server.unit_bytes()
    topo = fed.server.topology
    for rec, entry_sel, clients in zip(
            fed.history, fed.server.sel_history,
            fed.server.async_engine.flush_clients):
        expect = topo.buffered_round_bytes(entry_sel, clients, ub, fl)
        assert rec.uplink_bytes == pytest.approx(expect["uplink"])
    summ = fed.comm_summary()
    assert 0.0 < summ["reduction_vs_full"] < 1.0
    assert summ["sim_time"] > 0.0 and "avg_staleness" in summ


# -- scored selection under buffered rounds (DESIGN.md §11) -----------------

def test_async_scored_state_advances_and_decays_with_staleness():
    """score_weighted under buffered rounds: the state advances one
    step per flush, and stale entries' telemetry is weighted by the
    SAME staleness factor as their deltas — so the polynomial and
    constant rules accumulate different counts on the same schedule."""
    params, assign, batches = _setup()

    def run(staleness):
        fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off",
                      strategy="score_weighted", async_buffer=2,
                      staleness=staleness, staleness_alpha=1.0,
                      client_delay_dist="pareto:1.5")
        fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                         fl=fl, seed=5)
        fed.server.run(4, lambda w: batches)
        return fed

    poly = run("polynomial")
    st = poly.server.sel_state
    assert int(st.round) == 4
    assert float(np.asarray(st.scores).max()) > 0.0
    assert max(r.staleness_mean for r in poly.history) > 0.0
    const = run("constant")
    # same seeded schedule -> same entries; constant counts at full
    # weight, polynomial strictly less (staleness observed above)
    assert float(np.asarray(const.server.sel_state.counts).sum()) > \
        float(np.asarray(st.counts).sum())
    # entry budget: 4 flushes x buffer 2 x n_train 4, fully counted
    # only under the constant rule
    assert float(np.asarray(const.server.sel_state.counts).sum()) == \
        4 * 2 * 4


@pytest.mark.parametrize("topology", ["hub", "hierarchical"])
def test_async_scored_flush_zero_staleness_bitexact_vs_sync(topology):
    """The PR 4 anchor extended to the scored engine: with zero
    staleness a flush — including its score-state update — is bitwise
    one synchronous scored packed round."""
    params, assign, batches = _setup()
    weights = jnp.asarray([1.0, 2.0, 0.0, 3.0])
    sync_fl = FLConfig(n_clients=C, train_fraction=0.5,
                       strategy="score_weighted", topology=topology,
                       packed=True, fused_agg="off")
    srv = Server(build_round_step(toy_loss, assign, sync_fl), assign,
                 sync_fl, params, seed=11)
    srv.run_round(batches, weights)

    async_fl = FLConfig(n_clients=C, train_fraction=0.5,
                        strategy="score_weighted", topology=topology,
                        fused_agg="off", async_buffer=C,
                        client_delay_dist="none")
    fed = Federation(loss_fn=toy_loss, params=params, assign=assign,
                     fl=async_fl, seed=11)
    fed.server.run(1, lambda w: batches, weights=weights)
    _assert_trees_bitexact(srv.params, fed.params)
    _assert_trees_bitexact(srv.sel_state, fed.server.sel_state)


def test_async_scored_ckpt_restore_bitexact(tmp_path):
    """Satellite: kill/restore mid-fit with score_weighted under
    async_buffer rounds — buffer entries carry their telemetry, the
    SelectionState restores bitwise, and the resumed run equals the
    uninterrupted one."""
    from repro.ckpt import restore_server_state, save_server_state
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off",
                  strategy="score_weighted", topology="hierarchical",
                  n_edges=2, async_buffer=3,
                  client_delay_dist="pareto:1.5")
    path = str(tmp_path / "scored_async")

    f1 = Federation(loss_fn=toy_loss, params=params, assign=assign,
                    fl=fl, seed=3)
    f1.server.run(2, lambda w: batches)
    save_server_state(path, f1.server)
    f1.server.run(2, lambda w: batches)

    f2 = Federation(loss_fn=toy_loss, params=params, assign=assign,
                    fl=fl, seed=3)
    meta = restore_server_state(path, f2.server)
    assert meta["async"]["scored"]
    for u in f2.server.async_engine.buffer.entries:
        assert u.unit_sqnorm is not None and u.unit_sqnorm.shape == \
            (assign.n_units,)
    f2.server.run(2, lambda w: batches)
    _assert_trees_bitexact(f1.params, f2.params)
    _assert_trees_bitexact(f1.server.sel_state, f2.server.sel_state)
    assert [r.sim_time for r in f2.history] == \
        [r.sim_time for r in f1.history]


# -- satellite bugfixes -----------------------------------------------------

def test_degenerate_comm_rounds_report_zero_frac():
    ub = np.array([10.0, 20.0, 40.0])
    for sel in (np.zeros((0, 3), np.float32),          # no clients
                np.zeros((4, 3), np.float32)):         # empty selection
        for downlink in ("full", "selected"):
            d = comm.hub_round_bytes(sel, ub, downlink=downlink)
            assert d["uplink"] == 0.0 and d["uplink_frac"] == 0.0
            assert np.isfinite(d["downlink"])
            h = comm.hierarchical_round_bytes(
                sel, ub, comm.edge_membership(max(sel.shape[0], 1), 1),
                downlink=downlink) if sel.shape[0] else None
            if h is not None:
                assert h["uplink"] == 0.0 and h["uplink_frac"] == 0.0
    # zero-byte model: frac guards, not NaN
    z = comm.hub_round_bytes(np.ones((2, 3), np.float32), np.zeros(3))
    assert z["uplink_frac"] == 0.0


def test_straggler_rate0_does_not_perturb_key_stream():
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off")

    def run(hooks):
        srv = Server(build_round_step(toy_loss, assign, fl), assign, fl,
                     params, seed=21, hooks=hooks)
        srv.run_round(batches)
        srv.run_round(batches)
        return srv

    plain = run(())
    rate0 = run((StragglerDropout(0.0),))
    _assert_trees_bitexact(plain.params, rate0.params)
    assert np.array_equal(
        np.asarray(jax.random.key_data(plain.key))
        if hasattr(jax.random, "key_data") else np.asarray(plain.key),
        np.asarray(jax.random.key_data(rate0.key))
        if hasattr(jax.random, "key_data") else np.asarray(rate0.key))


class _DropClients(ServerHook):
    def __init__(self, dropped):
        self.dropped = dropped

    def on_round_start(self, server, round_idx, weights):
        keep = np.ones(server.fl.n_clients, np.float32)
        keep[list(self.dropped)] = 0.0
        return weights * jnp.asarray(keep)


def test_comm_accounting_ignores_dropped_clients():
    """Clients zeroed by straggler dropout upload nothing: the record's
    byte math masks their selection rows, and the effective weights are
    threaded onto the RoundRecord for hooks to see."""
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off")
    srv = Server(build_round_step(toy_loss, assign, fl), assign, fl,
                 params, seed=3, hooks=(_DropClients({1, 3}),))
    rec = srv.run_round(batches)
    assert rec.effective_weights is not None
    assert rec.effective_weights[1] == 0.0 == rec.effective_weights[3]
    sel = srv.sel_history[0].copy()
    billed = sel * np.array([1, 0, 1, 0], np.float32)[:, None]
    ub = srv.unit_bytes()
    assert rec.uplink_bytes == pytest.approx(
        comm.hub_round_bytes(billed, ub)["uplink"])
    assert rec.uplink_bytes < comm.hub_round_bytes(sel, ub)["uplink"]
    counts = comm.unit_param_counts(assign, srv.global_params())
    assert rec.trained_params == pytest.approx(
        float(np.einsum("cu,u->", billed, counts)))
    # run summary agrees with the per-round records
    summ = srv.comm_summary()
    assert summ["avg_uplink_bytes"] == pytest.approx(rec.uplink_bytes)


def test_comm_accounting_masks_legacy_pseudo_unit_rounds():
    rec_sel = np.ones((C, 1), np.float32)    # legacy (C, 1) shim shape
    params, assign, batches = _setup()
    fl = FLConfig(n_clients=C, train_fraction=0.5, fused_agg="off")
    srv = Server(build_round_step(toy_loss, assign, fl), assign, fl,
                 params, seed=3)
    from repro.core.server import RoundRecord
    rec = RoundRecord(0, 0.0, None, 0.0, 0.0, 0.0,
                      effective_weights=[1.0, 0.0, 1.0, 0.0])
    CommAccounting().on_round_end(srv, rec, {"sel": rec_sel})
    assert rec.uplink_bytes == pytest.approx(
        float(srv.unit_bytes().sum()) * 2)   # 2 surviving clients
