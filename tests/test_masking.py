"""Freeze-unit masking invariants (the paper's central mechanic).

THE property: a client's local update leaves every frozen unit's params
bit-exactly unchanged — and its optimizer state too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import reduced_cfg, tiny_batch
from repro.common import flatten_with_paths
from repro.core.client import local_update
from repro.core.masking import (build_units_flat, build_units_zoo, mask_tree,
                                apply_mask, unit_param_counts)
from repro.models import get_model, paper_models as pm


def test_unit_count_transformer(rng):
    cfg = reduced_cfg("qwen3-1.7b")
    m = get_model(cfg)
    p = m.init_params(rng)
    a = build_units_zoo(cfg, p)
    assert a.n_units == cfg.n_layers + 2           # embed + layers + head
    counts = unit_param_counts(a, p)
    assert counts.sum() == sum(int(np.prod(x.shape))
                               for _, x in flatten_with_paths(p))
    assert (counts > 0).all()


def test_unit_count_encdec(rng):
    cfg = reduced_cfg("whisper-medium")
    m = get_model(cfg)
    p = m.init_params(rng)
    a = build_units_zoo(cfg, p)
    assert a.n_units == cfg.n_enc_layers + cfg.n_layers + 2


def test_unit_count_vgg(rng):
    p = pm.init_vgg16(rng, width_mult=0.25)
    a = build_units_flat(p, pm.vgg16_units(p))
    assert a.n_units == 14                         # the paper's count
    counts = unit_param_counts(a, p)
    assert counts.sum() == sum(int(np.prod(x.shape))
                               for _, x in flatten_with_paths(p))


def test_mask_tree_broadcast_shapes(rng):
    cfg = reduced_cfg("gemma3-12b")               # macro-block layout
    m = get_model(cfg)
    p = m.init_params(rng)
    a = build_units_zoo(cfg, p)
    sel = jnp.ones(a.n_units)
    mask = mask_tree(a, sel, p)
    masked = apply_mask(mask, p)
    for (path, x), (_, y) in zip(flatten_with_paths(p),
                                 flatten_with_paths(masked)):
        assert x.shape == y.shape, path


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-3b", "hymba-1.5b",
                                  "granite-moe-1b-a400m", "whisper-medium"])
def test_frozen_units_bitexact_after_local_update(arch, rng):
    """Alg. 2: frozen layers are untouched by the client update."""
    cfg = reduced_cfg(arch)
    m = get_model(cfg)
    p = m.init_params(rng)
    a = build_units_zoo(cfg, p)
    sel = jnp.zeros(a.n_units).at[jnp.asarray([0, a.n_units - 1])].set(1.0)
    mask = mask_tree(a, sel, p)
    batch = tiny_batch(cfg, rng)
    batches = jax.tree_util.tree_map(lambda x: x[None].repeat(2, 0), batch)
    delta, _ = jax.jit(lambda p_: local_update(
        m.loss_fn, p_, mask, batches, lr=1e-2))(p)
    bmask = jax.tree_util.tree_map(
        lambda x, k: np.broadcast_to(
            np.reshape(np.asarray(k), np.shape(k) + (1,) *
                       (x.ndim - np.ndim(k))), x.shape), p, mask)
    frozen_changed, trained_changed = 0, 0
    for (path, d), (_, km) in zip(flatten_with_paths(delta),
                                  flatten_with_paths(bmask)):
        d = np.asarray(d)
        frozen = d[km == 0]
        trained = d[km == 1]
        assert (frozen == 0).all(), f"{arch} {path}: frozen moved"
        if trained.size:
            trained_changed += (trained != 0).any()
    assert trained_changed > 0, "nothing trained at all"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), n_train=st.integers(1, 4))
def test_property_vgg_frozen_invariance(seed, n_train):
    """Property over random selections on the paper's own model family."""
    key = jax.random.PRNGKey(seed)
    p = pm.init_vgg16(key, width_mult=0.125)
    a = build_units_flat(p, pm.vgg16_units(p))
    from repro.core.freezing import select_uniform
    sel = select_uniform(key, a.n_units, n_train)
    mask = mask_tree(a, sel, p)

    def loss_fn(params, batch):
        return pm.xent_loss(pm.vgg16_apply(params, batch["x"]),
                            batch["y"]), {}

    x = jax.random.normal(key, (2, 4, 32, 32, 3))
    y = jax.random.randint(key, (2, 4), 0, 10)
    delta, _ = local_update(loss_fn, p, mask, {"x": x, "y": y}, lr=1e-2)
    sel_np = np.asarray(sel)
    for ui, unit in enumerate(pm.vgg16_units(p)):
        leaves = jax.tree_util.tree_leaves(delta[unit])
        moved = any(bool((np.asarray(l) != 0).any()) for l in leaves)
        if sel_np[ui] == 0:
            assert not moved, f"frozen unit {unit} moved"
