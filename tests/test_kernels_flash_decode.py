"""Pallas flash decode vs the decode oracle: valid-len masking, GQA,
ring-buffer mode, dtype and block-size sweeps — plus the paged variant
(page-table indirection via scalar prefetch, DESIGN.md §12)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.kernel import flash_decode, flash_decode_paged
from repro.kernels.flash_decode.ops import decode_attention
from repro.kernels.flash_decode.ref import flash_decode_paged_ref
from repro.models.attention import decode_attend, decode_attend_ring


@pytest.mark.parametrize("b,s,h,hkv,hd,blk", [
    (2, 512, 4, 4, 64, 128), (2, 512, 4, 2, 64, 256),
    (1, 1024, 8, 1, 32, 128), (4, 256, 2, 2, 128, 64)])
def test_decode_matches_oracle(b, s, h, hkv, hd, blk, rng):
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    valid = jax.random.randint(ks[3], (b,), 1, s + 1)
    o = decode_attention(q, k, v, valid, blk_k=blk)
    ref = decode_attend(q, k, v, valid)
    assert float(jnp.abs(o - ref).max()) < 2e-5


def test_partial_block_validity(rng):
    """valid_len cutting through the middle of a KV block."""
    b, s, h, hd = 1, 512, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    for valid in (1, 127, 129, 300, 512):
        vl = jnp.asarray([valid], jnp.int32)
        o = decode_attention(q, k, v, vl, blk_k=128)
        ref = decode_attend(q, k, v, vl)
        assert float(jnp.abs(o - ref).max()) < 2e-5, valid


def test_ring_mode(rng):
    b, s, h, hd = 2, 256, 4, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    step = jnp.asarray([400, 90], jnp.int32)          # one wrapped, one not
    o = decode_attention(q, k, v, step, window=s, blk_k=64)
    ref = decode_attend_ring(q, k, v, step, window=s)
    assert float(jnp.abs(o - ref).max()) < 2e-5


# ---------------------------------------------------------------------------
# paged variant
# ---------------------------------------------------------------------------

def _paged_setup(rng, b=3, h=4, hkv=2, hd=64, n_pages=16, ps=16):
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (b * h, 1, hd))
    k_pool = jax.random.normal(ks[1], (hkv, n_pages, ps, hd))
    v_pool = jax.random.normal(ks[2], (hkv, n_pages, ps, hd))
    return q, k_pool, v_pool


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
def test_paged_matches_ref(h, hkv, rng):
    """Interpret-mode paged kernel vs the gather-then-dense oracle, with
    scattered pages, GQA, and valid_len cutting mid-page."""
    b, hd, ps = 3, 64, 16
    q, k_pool, v_pool = _paged_setup(rng, b=b, h=h, hkv=hkv, hd=hd, ps=ps)
    pt = jnp.asarray([[5, 2, 9, 0], [11, 7, 0, 0], [3, 14, 8, 1]], jnp.int32)
    valid = jnp.repeat(jnp.asarray([40, 17, 64], jnp.int32), h)
    o = flash_decode_paged(q, k_pool, v_pool, pt, valid, interpret=True)
    ref = flash_decode_paged_ref(q, k_pool, v_pool, pt, valid)
    assert float(jnp.abs(o - ref).max()) < 2e-5


def test_paged_identity_table_bitwise_dense(rng):
    """With contiguous per-sequence pages the paged kernel streams the
    same blocks as the dense kernel — outputs are bitwise equal."""
    b, h, hkv, hd, ps, mp = 2, 4, 2, 64, 16, 4
    q, k_pool, v_pool = _paged_setup(rng, b=b, h=h, hkv=hkv, hd=hd,
                                     n_pages=1 + b * mp, ps=ps)
    pt = (1 + jnp.arange(b * mp, dtype=jnp.int32)).reshape(b, mp)
    valid = jnp.repeat(jnp.asarray([mp * ps, 37], jnp.int32), h)
    kd = k_pool[:, pt]                      # (Hkv,B,MP,ps,hd)
    kd = jnp.moveaxis(kd, 0, 1).reshape(b * hkv, mp * ps, hd)
    vd = jnp.moveaxis(v_pool[:, pt], 0, 1).reshape(b * hkv, mp * ps, hd)
    o_paged = flash_decode_paged(q, k_pool, v_pool, pt, valid,
                                 interpret=True)
    o_dense = flash_decode(q, kd, vd, valid, blk_k=ps, interpret=True)
    assert jnp.array_equal(o_paged, o_dense)


def test_paged_trash_page_never_leaks(rng):
    """NaNs in the trash page (0) and in unowned pages must not reach the
    output: unallocated entries sit past valid_len and their grid steps
    are skipped."""
    b, h, hkv, hd, ps = 2, 2, 2, 32, 16
    q, k_pool, v_pool = _paged_setup(rng, b=b, h=h, hkv=hkv, hd=hd,
                                     n_pages=8, ps=ps)
    k_pool = k_pool.at[:, 0].set(jnp.nan).at[:, 5].set(jnp.nan)
    v_pool = v_pool.at[:, 0].set(jnp.nan).at[:, 5].set(jnp.nan)
    pt = jnp.asarray([[2, 3, 0], [4, 0, 0]], jnp.int32)   # page 5 unowned
    valid = jnp.repeat(jnp.asarray([2 * ps, ps - 3], jnp.int32), h)
    o = flash_decode_paged(q, k_pool, v_pool, pt, valid, interpret=True)
    assert bool(jnp.isfinite(o).all())
    ref = flash_decode_paged_ref(q, k_pool, v_pool,
                                 jnp.asarray([[2, 3, 1], [4, 1, 1]]),
                                 valid)     # same owned pages, clean filler
    assert float(jnp.abs(o - ref).max()) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_dtypes(dtype, rng):
    b, s, h, hd = 2, 256, 4, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, hd)).astype(dtype)
    vl = jnp.full((b,), s, jnp.int32)
    o = decode_attention(q, k, v, vl, blk_k=128)
    assert o.dtype == dtype
    ref = decode_attend(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), vl)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.abs(o.astype(jnp.float32) - ref).max()) < tol
