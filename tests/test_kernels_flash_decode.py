"""Pallas flash decode vs the decode oracle: valid-len masking, GQA,
ring-buffer mode, dtype and block-size sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.ops import decode_attention
from repro.models.attention import decode_attend, decode_attend_ring


@pytest.mark.parametrize("b,s,h,hkv,hd,blk", [
    (2, 512, 4, 4, 64, 128), (2, 512, 4, 2, 64, 256),
    (1, 1024, 8, 1, 32, 128), (4, 256, 2, 2, 128, 64)])
def test_decode_matches_oracle(b, s, h, hkv, hd, blk, rng):
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    valid = jax.random.randint(ks[3], (b,), 1, s + 1)
    o = decode_attention(q, k, v, valid, blk_k=blk)
    ref = decode_attend(q, k, v, valid)
    assert float(jnp.abs(o - ref).max()) < 2e-5


def test_partial_block_validity(rng):
    """valid_len cutting through the middle of a KV block."""
    b, s, h, hd = 1, 512, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    for valid in (1, 127, 129, 300, 512):
        vl = jnp.asarray([valid], jnp.int32)
        o = decode_attention(q, k, v, vl, blk_k=128)
        ref = decode_attend(q, k, v, vl)
        assert float(jnp.abs(o - ref).max()) < 2e-5, valid


def test_ring_mode(rng):
    b, s, h, hd = 2, 256, 4, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    step = jnp.asarray([400, 90], jnp.int32)          # one wrapped, one not
    o = decode_attention(q, k, v, step, window=s, blk_k=64)
    ref = decode_attend_ring(q, k, v, step, window=s)
    assert float(jnp.abs(o - ref).max()) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_dtypes(dtype, rng):
    b, s, h, hd = 2, 256, 4, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, hd)).astype(dtype)
    vl = jnp.full((b,), s, jnp.int32)
    o = decode_attention(q, k, v, vl, blk_k=128)
    assert o.dtype == dtype
    ref = decode_attend(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), vl)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.abs(o.astype(jnp.float32) - ref).max()) < tol
