"""Serving engine acceptance: paged continuous batching vs the dense
static loop (DESIGN.md §12).

The headline gates:

* greedy decode through the paged engine is **bitwise-equal** (logits
  included) to the static-batch loop, per family — attention KV
  (gemma3 ring + global), SSM state (rwkv6), hybrid (hymba);
* continuous batching over multiple admission waves reproduces each
  wave's static run stream-for-stream, and mixed-length workloads match
  per-request solo runs — including through recompute-preemption;
* the jitted decode step compiles exactly once across admit / evict /
  preempt (the recompile-free contract);
* sampling at temperature > 0 is reproducible from the seed and
  identical between engines (per-(request, token) keys).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro.models import get_model
from repro.serve.engine import DecodeEngine, ServeConfig, static_generate
from repro.serve.paged_cache import PageAllocator, PagedTables, build_layout
from repro.serve.scheduler import Request, Scheduler

SERVE_ARCHS = ("gemma3-12b", "rwkv6-3b", "hymba-1.5b")


def _setup(arch, n_prompts=3, prompt_len=24, seed=0):
    cfg = reduced_cfg(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key)
    prompts = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1), (n_prompts, prompt_len), 0, cfg.vocab))
    return cfg, params, prompts


# ---------------------------------------------------------------------------
# bitwise equality: paged vs dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_paged_greedy_bitwise_equals_static(arch):
    """One uniform batch filling every slot: the engine's token streams
    AND per-step logits rows must be bit-for-bit the static loop's."""
    cfg, params, prompts = _setup(arch)
    gen = 6
    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=3, max_len=32, page_size=16, record_logits=True))
    for i in range(3):
        eng.submit(prompts[i], gen)
    res = eng.run()

    out, rows = static_generate(cfg, params, jnp.asarray(prompts), gen,
                                max_len=eng.layout.max_len,
                                collect_logits=True)
    for i in range(3):
        assert np.array_equal(res[i], out[i]), f"tokens diverge for seq {i}"
        assert np.array_equal(np.stack(eng.logits_rows[i]),
                              np.stack([r[i] for r in rows])), \
            f"logits diverge for seq {i}"
    assert eng.decode_cache_size == 1


@pytest.mark.parametrize("arch", ("gemma3-12b", "hymba-1.5b"))
def test_paged_ring_wrap_bitwise(arch):
    """max_len past the reduced sliding window, so ring pages wrap."""
    cfg, params, prompts = _setup(arch)
    assert cfg.sliding_window and cfg.sliding_window < 96
    gen = 8
    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=3, max_len=96, page_size=16, record_logits=True))
    assert any(s.ring for s in eng.layout.subs)
    for i in range(3):
        eng.submit(prompts[i], gen)
    res = eng.run()
    out, rows = static_generate(cfg, params, jnp.asarray(prompts), gen,
                                max_len=eng.layout.max_len,
                                collect_logits=True)
    for i in range(3):
        assert np.array_equal(res[i], out[i])
        assert np.array_equal(np.stack(eng.logits_rows[i]),
                              np.stack([r[i] for r in rows]))


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

def test_multiwave_continuous_matches_static_waves():
    """6 uniform requests over 3 slots: the second wave admits after the
    first finishes; each wave must match its own static-batch run."""
    cfg, params, prompts = _setup("gemma3-12b", n_prompts=6)
    gen = 6
    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=3, max_len=32, page_size=16))
    for i in range(6):
        eng.submit(prompts[i], gen)
    res = eng.run()
    for w in range(2):
        ids = list(range(3 * w, 3 * w + 3))
        out = static_generate(cfg, params, jnp.asarray(prompts[ids]), gen,
                              max_len=eng.layout.max_len,
                              rids=np.asarray(ids))
        for j, rid in enumerate(ids):
            assert np.array_equal(res[rid], out[j]), f"request {rid}"
    assert eng.decode_cache_size == 1
    assert eng.allocator.n_free == eng.allocator.n_pages - 1  # all returned


def test_mixed_lengths_match_solo_runs():
    """Mixed prompt/gen lengths admitted mid-flight: every request's
    stream equals a solo static run of that request."""
    cfg, params, prompts = _setup("gemma3-12b", n_prompts=6)
    specs = [(16, 8), (24, 4), (8, 10), (16, 3), (24, 6), (8, 5)]
    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=3, max_len=32, page_size=16))
    for i, (pl, g) in enumerate(specs):
        eng.submit(prompts[i][:pl], g)
    res = eng.run()
    for i, (pl, g) in enumerate(specs):
        solo = static_generate(cfg, params, jnp.asarray(prompts[i][:pl])[None],
                               g, max_len=eng.layout.max_len,
                               rids=np.asarray([i]))
        assert np.array_equal(res[i], solo[0]), f"request {i}"
    assert eng.decode_cache_size == 1


def test_preemption_recovers_streams():
    """A pool sized for ~2 full sequences under 3 slots forces recompute
    preemption; preempted requests must still finish with the exact
    stream of an undisturbed solo run."""
    cfg, params, prompts = _setup("gemma3-12b", n_prompts=6)
    specs = [(16, 10), (24, 6), (8, 12), (16, 4), (24, 8), (8, 6)]
    lay = build_layout(cfg, 16, 32)
    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=3, max_len=32, page_size=16,
        n_pages=2 * lay.pages_per_seq + 2))
    for i, (pl, g) in enumerate(specs):
        eng.submit(prompts[i][:pl], g)
    res = eng.run()
    assert eng.scheduler.n_preemptions > 0
    for i, (pl, g) in enumerate(specs):
        solo = static_generate(cfg, params, jnp.asarray(prompts[i][:pl])[None],
                               g, max_len=eng.layout.max_len,
                               rids=np.asarray([i]))
        assert np.array_equal(res[i], solo[0]), f"request {i}"
    assert eng.decode_cache_size == 1  # preemption never recompiles


def test_eos_frees_slot_early():
    cfg, params, prompts = _setup("rwkv6-3b", n_prompts=4)
    # run once to learn what token request 0 emits at step 2
    probe = DecodeEngine(cfg, params, ServeConfig(
        n_slots=2, max_len=32, page_size=16))
    for i in range(2):
        probe.submit(prompts[i], 6)
    eos = int(probe.run()[0][2])

    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=2, max_len=32, page_size=16, eos_id=eos))
    for i in range(4):
        eng.submit(prompts[i], 6)
    res = eng.run()
    assert res[0][-1] == eos and len(res[0]) == 3      # stopped at EOS
    assert all(len(res[i]) <= 6 for i in range(4))
    assert eng.decode_cache_size == 1


# ---------------------------------------------------------------------------
# sampling (the launcher first-token bug)
# ---------------------------------------------------------------------------

def test_sampled_first_token_reproducible_and_not_argmax():
    """Regression for the old launcher bug: at temperature > 0 the FIRST
    token was always argmax.  Now every token is sampled, reproducibly
    from the seed."""
    cfg, params, prompts = _setup("rwkv6-3b", n_prompts=4)
    kw = dict(max_len=32, temperature=0.9)
    a = static_generate(cfg, params, jnp.asarray(prompts), 4, seed=7, **kw)
    b = static_generate(cfg, params, jnp.asarray(prompts), 4, seed=7, **kw)
    c = static_generate(cfg, params, jnp.asarray(prompts), 4, seed=8, **kw)
    greedy = static_generate(cfg, params, jnp.asarray(prompts), 4,
                             max_len=32, temperature=0.0)
    assert np.array_equal(a, b)                       # same seed, same stream
    assert not np.array_equal(a, c)                   # seed changes stream
    # first column is sampled, not argmax'd (4 rows x 2 seeds: the odds
    # of all 8 draws landing on the mode are negligible at vocab ~512)
    assert (not np.array_equal(a[:, 0], greedy[:, 0])
            or not np.array_equal(c[:, 0], greedy[:, 0]))


def test_temperature_continuous_matches_static():
    """Per-(request, token) sampling keys make the continuous engine's
    streams identical to the static loop's at temperature > 0."""
    cfg, params, prompts = _setup("rwkv6-3b", n_prompts=3)
    gen = 5
    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=3, max_len=32, page_size=16, temperature=0.9, seed=3))
    for i in range(3):
        eng.submit(prompts[i], gen)
    res = eng.run()
    out = static_generate(cfg, params, jnp.asarray(prompts), gen,
                          max_len=eng.layout.max_len, temperature=0.9,
                          seed=3)
    for i in range(3):
        assert np.array_equal(res[i], out[i])


# ---------------------------------------------------------------------------
# paged_cache / scheduler units (no device work)
# ---------------------------------------------------------------------------

def test_allocator_all_or_nothing_and_reuse():
    al = PageAllocator(6)                  # pages 1..5 usable
    assert al.n_free == 5
    a = al.alloc(3)
    assert a is not None and len(a) == 3 and 0 not in a
    assert al.alloc(3) is None             # only 2 left: nothing taken
    assert al.n_free == 2
    b = al.alloc(2)
    assert al.n_free == 0 and al.peak_in_use == 5
    al.free(a)
    assert al.n_free == 3
    c = al.alloc(3)
    assert sorted(c) == sorted(a)          # freed pages recycle
    al.free(b + c)
    with pytest.raises(ValueError):
        al.free([0])                       # trash page is never freeable


def test_tables_trash_page_and_release():
    cfg = reduced_cfg("gemma3-12b")
    lay = build_layout(cfg, 16, 32)
    al = PageAllocator(1 + 2 * lay.pages_per_seq)
    tb = PagedTables(lay, n_slots=2, allocator=al)
    assert all((t == 0).all() for t in tb.tables.values())
    assert tb.admit(0, prompt_len=20)
    held = tb.pages_held(0)
    assert held > 0 and al.n_in_use == held
    # grow to a fresh page, then release returns everything
    assert tb.grow(0, step=31)
    tb.release(0)
    assert al.n_in_use == 0
    assert all((t == 0).all() for t in tb.tables.values())


def test_layout_validation():
    with pytest.raises(ValueError, match="vlm|audio|family"):
        build_layout(reduced_cfg("internvl2-26b"), 16, 32)
    with pytest.raises(ValueError, match="page-aligned|multiple"):
        build_layout(reduced_cfg("gemma3-12b"), 24, 96)  # window 64 % 24 != 0
    lay = build_layout(reduced_cfg("qwen3-1.7b"), 16, 30)
    assert lay.max_len == 32               # rounded up to a page multiple


def test_scheduler_validates_submissions():
    cfg = reduced_cfg("qwen3-1.7b")
    lay = build_layout(cfg, 16, 32)
    al = PageAllocator(1 + lay.pages_per_seq)
    sched = Scheduler(lay, PagedTables(lay, 2, al), 2)
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(Request(rid=0, prompt=np.zeros(30, np.int32),
                             max_gen=10))
    small = PageAllocator(2)               # cannot hold one full sequence
    sched2 = Scheduler(lay, PagedTables(lay, 2, small), 2)
    with pytest.raises(ValueError, match="pool"):
        sched2.submit(Request(rid=0, prompt=np.zeros(8, np.int32),
                              max_gen=4))


def test_scheduler_preempts_most_recent_and_requeues_front():
    cfg = reduced_cfg("qwen3-1.7b")
    lay = build_layout(cfg, 16, 32)
    al = PageAllocator(1 + 3 * lay.pages_per_seq)
    tb = PagedTables(lay, 3, al)
    sched = Scheduler(lay, tb, 3)
    for rid in range(3):
        sched.submit(Request(rid=rid, prompt=np.zeros(16, np.int32),
                             max_gen=8))
    group = sched.admit_group()
    assert [r.rid for _, r in group] == [0, 1, 2]
    # simulate progress, then preempt the most recently admitted
    for slot, req in group:
        req.generated = [11, 22]
        sched.slots[slot].step += 2
    sched.preempt(2)
    victim = sched.queue[0]
    assert victim.rid == 2 and victim.resume_pending == 22
    assert list(victim.prefill_tokens) == [0] * 16 + [11]
    assert tb.pages_held(2) == 0           # pages went back to the pool
