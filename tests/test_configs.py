"""Config registry: every assigned architecture, exact assigned hparams."""
import pytest

from repro.configs.base import get_config, list_configs

# (name, n_layers, d_model, n_heads, n_kv_heads, d_ff, vocab)
ASSIGNED = [
    ("stablelm-3b", 32, 2560, 32, 32, 6912, 50304),
    ("qwen2.5-14b", 48, 5120, 40, 8, 13824, 152064),
    ("llama4-maverick-400b-a17b", 48, 5120, 40, 8, None, 202048),
    ("gemma3-12b", 48, 3840, 16, 8, 15360, 262144),
    ("rwkv6-3b", 32, 2560, None, None, 8960, 65536),
    ("hymba-1.5b", 32, 1600, 25, 5, 5504, 32001),
    ("internvl2-26b", 48, 6144, 48, 8, 16384, 92553),
    ("qwen3-1.7b", 28, 2048, 16, 8, 6144, 151936),
    ("whisper-medium", 24, 1024, 16, 16, 4096, 51865),
    ("granite-moe-1b-a400m", 24, 1024, 16, 8, None, 49155),
]


def test_all_ten_registered():
    assert len(list_configs()) == 10


@pytest.mark.parametrize("name,L,d,H,Hkv,ff,V", ASSIGNED)
def test_assigned_hparams(name, L, d, H, Hkv, ff, V):
    cfg = get_config(name)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    if H is not None:
        assert cfg.n_heads == H
        assert cfg.n_kv_heads == Hkv
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab == V
    assert cfg.source  # every config must cite its source


def test_moe_assignments():
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.moe.num_experts == 128 and l4.moe.top_k == 1
    assert l4.moe.expert_d_ff == 8192
    gr = get_config("granite-moe-1b-a400m")
    assert gr.moe.num_experts == 32 and gr.moe.top_k == 8
    assert gr.moe.expert_d_ff == 512


def test_flavours():
    assert get_config("qwen2.5-14b").qkv_bias
    assert get_config("qwen3-1.7b").qk_norm
    g = get_config("gemma3-12b")
    assert g.global_every == 6 and g.sliding_window > 0   # 5:1 local:global
    assert get_config("stablelm-3b").rope_pct == 0.25
    assert get_config("hymba-1.5b").ssm.state_dim == 16
    w = get_config("whisper-medium")
    assert w.n_enc_layers == 24 and not w.glu and w.norm == "layernorm"


@pytest.mark.parametrize("name", [a[0] for a in ASSIGNED])
def test_reduced_invariants(name):
    cfg = get_config(name)
    r = cfg.reduced()
    assert r.n_layers <= 4
    assert r.d_model <= 512
    if r.moe is not None:
        assert r.moe.num_experts <= 4
    assert r.family == cfg.family
    # flavour preserved
    assert r.qk_norm == cfg.qk_norm
    assert r.qkv_bias == cfg.qkv_bias
    assert (r.moe is None) == (cfg.moe is None)
    assert (r.ssm is None) == (cfg.ssm is None)


def test_param_scale_sanity():
    """Full-config param counts are in the advertised ballpark."""
    import jax
    from repro.launch.specs import params_sds
    from repro.common import param_count
    expect = {"qwen3-1.7b": (1.4e9, 2.4e9), "stablelm-3b": (2.5e9, 4e9),
              "rwkv6-3b": (2.5e9, 4.2e9), "hymba-1.5b": (1.1e9, 2.2e9),
              "granite-moe-1b-a400m": (0.9e9, 1.7e9),
              "whisper-medium": (0.5e9, 1.1e9),
              "gemma3-12b": (10e9, 15e9), "qwen2.5-14b": (12e9, 17e9),
              "internvl2-26b": (18e9, 28e9),
              "llama4-maverick-400b-a17b": (330e9, 480e9)}
    for name, (lo, hi) in expect.items():
        n = param_count(params_sds(get_config(name)))
        assert lo <= n <= hi, f"{name}: {n:,} outside [{lo:,.0f}, {hi:,.0f}]"
