"""Synthetic data + partitioning + federated loader."""
import numpy as np
import pytest

from repro.data import (FederatedLoader, casa_like, cifar_like,
                        dirichlet_partition, iid_partition, imdb_like,
                        lm_batch, lm_tokens)


def test_cifar_like_shapes():
    x, y = cifar_like(100, key=0)
    assert x.shape == (100, 32, 32, 3) and y.shape == (100,)
    assert set(np.unique(y)) <= set(range(10))


def test_imdb_like_shapes():
    x, y = imdb_like(50, key=0)
    assert x.shape == (50, 100) and x.dtype == np.int32
    assert x.max() < 20000 and set(np.unique(y)) <= {0, 1}


def test_casa_like_non_iid():
    homes = casa_like(8, key=0)
    assert len(homes) == 8
    sizes = [len(y) for _, y in homes]
    assert len(set(sizes)) > 1                     # sizes vary
    mixes = [np.bincount(y, minlength=10) / len(y) for _, y in homes]
    assert np.std([m[0] for m in mixes]) > 0.02    # label mixes vary


def test_lm_tokens_learnable_structure():
    x = lm_tokens(20, 64, 512, key=0)
    assert x.shape == (20, 64) and x.max() < 512
    b = lm_batch(4, 16, 512, key=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_iid_partition_disjoint_equal():
    shards = iid_partition(1000, 10, key=0)
    assert all(len(s) == 100 for s in shards)
    allidx = np.concatenate(shards)
    assert len(np.unique(allidx)) == 1000


def test_dirichlet_partition_skewed():
    labels = np.random.default_rng(0).integers(0, 10, 2000)
    shards = dirichlet_partition(labels, 8, alpha=0.2, key=0)
    assert all(len(s) >= 8 for s in shards)
    # skew: per-client label distributions differ materially
    dists = np.stack([np.bincount(labels[s], minlength=10) / len(s)
                      for s in shards])
    assert dists.std(axis=0).mean() > 0.05


def test_loader_shapes_and_determinism():
    x, y = cifar_like(400, key=0)
    shards = iid_partition(400, 4, key=1)
    loader = FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                             batch_size=8, steps_per_round=3, key=5)
    b1 = loader.round_batches(0)
    b2 = loader.round_batches(0)
    assert b1["x"].shape == (4, 3, 8, 32, 32, 3)
    np.testing.assert_array_equal(b1["y"], b2["y"])    # deterministic
    b3 = loader.round_batches(1)
    assert not np.array_equal(b1["y"], b3["y"])        # reshuffled
    np.testing.assert_array_equal(loader.weights(), [100, 100, 100, 100])


def test_loader_small_shard_upsampling():
    data = [{"x": np.arange(5, dtype=np.float32)}]
    loader = FederatedLoader(data, batch_size=4, steps_per_round=3)
    b = loader.round_batches(0)
    assert b["x"].shape == (1, 3, 4)                  # upsampled past 5
