"""The redesigned federation API: strategy registry, Federation facade,
server hooks, legacy shims.

Covers the migration guarantees: the ``full`` registered strategy on the
unified path is bit-exact with the old dedicated full-model round step;
custom strategies round-trip through ``Federation.from_config``; unknown
names fail with the registered list; an all-dropped round is a recorded
no-op.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FLConfig, Federation, ModelSpec, SelectionStrategy,
                        Server, ServerHook, UnknownStrategyError,
                        build_fullmodel_round_step, build_round_step,
                        build_units_flat, get_strategy, register_strategy,
                        registered_strategies, unregister_strategy)
from repro.core.aggregation import fedavg
from repro.core.client import local_update
from repro.data import FederatedLoader, cifar_like, iid_partition
from repro.models import paper_models as pm


def vgg_loss(p, batch):
    return pm.xent_loss(pm.vgg16_apply(p, batch["x"]), batch["y"]), {}


def _vgg_setup(rng, c=3, steps=2, bs=4):
    params = pm.init_vgg16(rng, width_mult=0.125)
    assign = build_units_flat(params, pm.vgg16_units(params))
    x, y = cifar_like(c * steps * bs, key=0)
    batches = {
        "x": jnp.asarray(x).reshape(c, steps, bs, 32, 32, 3),
        "y": jnp.asarray(y).reshape(c, steps, bs),
    }
    return params, assign, batches


def _spec(width=0.125):
    return ModelSpec(
        name="vgg16",
        init_params=functools.partial(pm.init_vgg16, width_mult=width),
        loss_fn=vgg_loss, unit_order=pm.vgg16_units)


def _loader(c=3, n=96):
    x, y = cifar_like(n, key=0)
    shards = iid_partition(n, c, key=1)
    return FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                           batch_size=4, steps_per_round=2)


def _legacy_fullmodel_round_step(loss_fn, fl, loss_kwargs=None):
    """Verbatim re-implementation of the deleted dedicated full-model
    path (conventional FedAvg baseline) — the bit-exactness oracle."""

    def round_step(global_params, client_batches, weights, round_key):
        ones_mask = jax.tree_util.tree_map(
            lambda x: jnp.ones((), jnp.float32), global_params)

        def one_client(batches):
            return local_update(loss_fn, global_params, ones_mask, batches,
                                lr=fl.lr, optimizer=fl.optimizer,
                                loss_kwargs=loss_kwargs)

        deltas, metrics = jax.vmap(one_client)(client_batches)
        new_params = fedavg(global_params, deltas, weights)
        return new_params, {"loss_mean": metrics["loss_mean"].mean(),
                            "sel": jnp.ones((fl.n_clients, 1))}

    return round_step


def _assert_trees_bitexact(a, b):
    for pa, pb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), \
            "params diverged bitwise"


def test_full_strategy_bitexact_with_legacy_path(rng):
    params, assign, batches = _vgg_setup(rng)
    fl = FLConfig(n_clients=3, n_train_units=assign.n_units, lr=1e-3,
                  strategy="full")
    unified = jax.jit(build_round_step(vgg_loss, assign, fl))
    legacy = jax.jit(_legacy_fullmodel_round_step(vgg_loss, fl))
    w = jnp.asarray([1.0, 2.0, 1.0])
    key = jax.random.PRNGKey(7)
    p1, m1 = unified(params, batches, w, key)
    p2, m2 = legacy(params, batches, w, key)
    _assert_trees_bitexact(p1, p2)
    assert float(m1["loss_mean"]) == float(m2["loss_mean"])
    # unified path reports the full-width selection matrix
    assert m1["sel"].shape == (3, assign.n_units)
    assert float(jnp.asarray(m1["sel"]).min()) == 1.0


def test_fullmodel_shim_deprecated_and_equivalent(rng):
    params, assign, batches = _vgg_setup(rng)
    fl = FLConfig(n_clients=3, n_train_units=assign.n_units, lr=1e-3)
    with pytest.warns(DeprecationWarning):
        shim = jax.jit(build_fullmodel_round_step(vgg_loss, fl,
                                                  assign=assign))
    unified = jax.jit(build_round_step(
        vgg_loss, assign, dataclasses.replace(fl, strategy="full")))
    w = jnp.ones(3)
    key = jax.random.PRNGKey(3)
    p1, _ = shim(params, batches, w, key)
    p2, _ = unified(params, batches, w, key)
    _assert_trees_bitexact(p1, p2)


def test_custom_strategy_roundtrips_through_federation():
    @register_strategy
    class EveryOther(SelectionStrategy):
        name = "_test_every_other"
        stochastic = False

        def select_row(self, key, ctx):
            return (jnp.arange(ctx.n_units) % 2 == 0).astype(jnp.float32)

    try:
        assert "_test_every_other" in registered_strategies()
        fed = Federation.from_config(
            _spec(), FLConfig(n_clients=3, n_train_units=7, lr=1e-3,
                              strategy="_test_every_other"),
            data=_loader())
        fed.fit(2)
        assert len(fed.history) == 2
        expected = (np.arange(fed.assign.n_units) % 2 == 0).astype(float)
        for sel in fed.server.sel_history:
            assert np.array_equal(sel, np.tile(expected, (3, 1)))
    finally:
        unregister_strategy("_test_every_other")
    assert "_test_every_other" not in registered_strategies()


def test_unknown_strategy_lists_registered_names(rng):
    with pytest.raises(UnknownStrategyError, match="uniform"):
        get_strategy("does_not_exist")
    params, assign, _ = _vgg_setup(rng)
    with pytest.raises(UnknownStrategyError, match="fixed_last"):
        build_round_step(vgg_loss, assign,
                         FLConfig(n_clients=3, n_train_units=4,
                                  strategy="does_not_exist"))


def test_all_clients_dropped_is_recorded_noop(rng):
    params, assign, batches = _vgg_setup(rng)
    fl = FLConfig(n_clients=3, n_train_units=4, lr=1e-3)
    srv = Server(build_round_step(vgg_loss, assign, fl), assign, fl, params)
    before = jax.tree_util.tree_map(np.asarray, srv.params)
    rec = srv.run_round(batches, weights=jnp.zeros(3))
    assert rec.skipped and rec.n_participants == 0
    assert rec.uplink_bytes == 0.0 and rec.trained_params == 0.0
    _assert_trees_bitexact(srv.params, before)
    # the server recovers on the next (participating) round
    rec2 = srv.run_round(batches, weights=jnp.ones(3))
    assert not rec2.skipped and np.isfinite(rec2.loss)
    assert rec2.round == 1 and rec2.n_participants == 3


def test_federation_facade_end_to_end():
    loader = _loader()
    xt, yt = cifar_like(48, key=5)
    fed = Federation.from_config(
        _spec(), FLConfig(n_clients=3, train_fraction=0.5, lr=1e-3),
        data=loader,
        eval_fn=lambda p: pm.accuracy(pm.vgg16_apply(
            p, jnp.asarray(xt)), jnp.asarray(yt)))
    hist = fed.fit(3)
    assert len(hist) == 3
    assert all(r.n_participants == 3 for r in hist)
    assert fed.evaluate() is not None
    summ = fed.comm_summary()
    assert 0.0 < summ["reduction_vs_full"] < 1.0
    # 50% of 14 units selected per client per round
    assert all(s.sum(axis=1).max() == 7 for s in fed.server.sel_history)


def test_synchronized_registered_plugin():
    fed = Federation.from_config(
        _spec(), FLConfig(n_clients=4, n_train_units=5, lr=1e-3,
                          strategy="synchronized"),
        data=_loader(c=4))
    fed.fit(1)
    sel = fed.server.sel_history[0]
    assert np.ptp(sel, axis=0).max() == 0      # all clients share the row
    assert sel.sum(axis=1).max() == 5


def test_hooks_compose(rng):
    params, assign, batches = _vgg_setup(rng)
    calls = []

    class Recorder(ServerHook):
        def on_round_start(self, server, r, weights):
            calls.append(("start", r))
            return weights * 2.0                 # reweighting is honored

        def on_round_end(self, server, record, metrics):
            calls.append(("end", record.round, record.uplink_bytes > 0))

    fl = FLConfig(n_clients=3, n_train_units=4, lr=1e-3)
    srv = Server(build_round_step(vgg_loss, assign, fl), assign, fl,
                 params, hooks=[Recorder()])
    srv.run(2, lambda r: batches)
    assert calls == [("start", 0), ("end", 0, True),
                     ("start", 1), ("end", 1, True)]
