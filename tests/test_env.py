"""launch/env.py production profile: XLA-flag merge semantics (user
flags win), tcmalloc LD_PRELOAD gating on .so presence, the re-exec
guard, and a live-backend smoke that the flag set actually parses
(XLA aborts the process on unknown XLA_FLAGS entries)."""
import os
import subprocess
import sys

import pytest

from repro.launch import env as prod


def test_profile_flags_applied_to_empty_env():
    e = prod.production_env(base={}, tcmalloc=False)
    flags = e["XLA_FLAGS"].split()
    assert list(prod.PROD_XLA_FLAGS) == flags
    assert e["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert e[prod.GUARD_VAR] == "1"
    assert "LD_PRELOAD" not in e


def test_user_flags_not_clobbered():
    """An explicit operator value for a profile flag survives; profile
    flags the user did not set are appended."""
    user = "--xla_gpu_enable_latency_hiding_scheduler=false --xla_abc=1"
    e = prod.production_env(base={"XLA_FLAGS": user}, tcmalloc=False)
    flags = e["XLA_FLAGS"].split()
    assert "--xla_gpu_enable_latency_hiding_scheduler=false" in flags
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" not in flags
    assert "--xla_abc=1" in flags
    for f in prod.PROD_XLA_FLAGS[1:]:
        assert f in flags


def test_unrelated_env_preserved():
    e = prod.production_env(base={"PATH": "/bin", "HOME": "/root"},
                            tcmalloc=False)
    assert e["PATH"] == "/bin" and e["HOME"] == "/root"


def test_tcmalloc_preload_only_when_so_exists(tmp_path, monkeypatch):
    so = tmp_path / "libtcmalloc_minimal.so.4"
    # absent: no preload, no threshold
    monkeypatch.setattr(prod, "TCMALLOC_PATHS", (str(so),))
    e = prod.production_env(base={})
    assert "LD_PRELOAD" not in e
    assert "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" not in e
    # present: appended to an existing preload list, threshold set
    so.write_bytes(b"")
    e = prod.production_env(base={"LD_PRELOAD": "/lib/other.so"})
    assert e["LD_PRELOAD"] == f"/lib/other.so:{so}"
    assert e["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] == "60000000000"
    # idempotent: already preloaded -> not duplicated
    e2 = prod.production_env(base={"LD_PRELOAD": e["LD_PRELOAD"]})
    assert e2["LD_PRELOAD"].count(str(so)) == 1


def test_reexec_guard_is_a_noop(monkeypatch):
    called = []
    monkeypatch.setattr(os, "execve",
                        lambda *a, **k: called.append(a))
    monkeypatch.setenv(prod.GUARD_VAR, "1")
    prod.reexec_under_prod_env("repro.launch.train", ["--rounds", "1"])
    assert called == []


def test_reexec_builds_module_argv(monkeypatch):
    called = []
    monkeypatch.setattr(os, "execve",
                        lambda path, argv, e: called.append((path, argv, e)))
    monkeypatch.delenv(prod.GUARD_VAR, raising=False)
    prod.reexec_under_prod_env("repro.launch.train", ["--rounds", "1"],
                               tcmalloc=False)
    (path, argv, e), = called
    assert path == sys.executable
    assert argv == [sys.executable, "-m", "repro.launch.train",
                    "--rounds", "1"]
    assert e[prod.GUARD_VAR] == "1"
    for f in prod.PROD_XLA_FLAGS:
        assert f in e["XLA_FLAGS"]


@pytest.mark.slow
def test_prod_flags_parse_on_live_backend():
    """XLA LOG(FATAL)s on unknown XLA_FLAGS entries — a stale flag in
    PROD_XLA_FLAGS would kill every --prod-env launch at startup, so
    smoke the set against the real backend in a subprocess."""
    e = prod.production_env()
    e["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c",
         "import jax; print(int(jax.numpy.arange(4).sum()))"],
        env=e, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip().endswith("6")
