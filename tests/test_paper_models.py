"""The paper's own models: exact/near-exact param counts + learnability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param_count
from repro.data import casa_like, cifar_like, imdb_like
from repro.models import paper_models as pm


def test_vgg16_exact_param_count(rng):
    assert param_count(pm.init_vgg16(rng)) == 14_736_714   # Table 1


def test_vgg16_unit_order(rng):
    p = pm.init_vgg16(rng)
    units = pm.vgg16_units(p)
    assert len(units) == 14 and units[-1] == "dense0"


def test_casa_param_count_close(rng):
    n = param_count(pm.init_casa(rng))
    assert abs(n - 68_884) / 68_884 < 0.005        # paper: 68,884 (~0.1%)


def test_imdb_structure(rng):
    p = pm.init_imdb(rng)
    assert p["embed_small"]["table"].shape == (20000, 128)
    assert p["lstm0"]["wh"].shape == (70, 280)
    assert pm.imdb_units(p) == ["embed_small", "conv0", "lstm0", "dense0"]


@pytest.mark.parametrize("model", ["vgg", "imdb", "casa"])
def test_learnability(model, rng):
    """A few SGD steps on the synthetic stand-ins reduce loss."""
    if model == "vgg":
        p = pm.init_vgg16(rng, width_mult=0.125)
        x, y = cifar_like(64, key=1)
        fwd = pm.vgg16_apply
    elif model == "imdb":
        p = pm.init_imdb(rng)
        x, y = imdb_like(64, key=1)
        fwd = pm.imdb_apply
    else:
        p = pm.init_casa(rng)
        homes = casa_like(2, key=1)
        x, y = homes[0]
        x, y = x[:64], y[:64]
        fwd = pm.casa_apply
    x, y = jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(
            lambda p_: pm.xent_loss(fwd(p_, x), y))(p)
        return loss, jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)

    losses = []
    for _ in range(8):
        loss, p = step(p)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{model}: {losses}"


def test_accuracy_metric():
    logits = jnp.asarray([[2.0, 1.0], [0.0, 3.0], [5.0, 0.0]])
    labels = jnp.asarray([0, 1, 1])
    assert float(pm.accuracy(logits, labels)) == pytest.approx(2 / 3)
