"""Topology plugin layer (DESIGN.md §6): registry, hub bit-exactness vs
the pre-topology round step, hierarchical two-stage aggregation + exact
byte accounting, gossip mixing + convergence, and save/restore resume.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FLConfig, Federation, ModelSpec, RoundLogger,
                        Topology, UnknownTopologyError, build_round_step,
                        build_units_flat, comm, get_topology,
                        register_topology, registered_topologies,
                        resolve_strategy, ring_mixing_matrix,
                        unregister_topology)
from repro.core.aggregation import (hierarchical_masked_fedavg,
                                    masked_fedavg)
from repro.core.client import local_update
from repro.core.masking import mask_tree
from repro.core.strategies import SelectionContext
from repro.data import FederatedLoader, cifar_like, iid_partition
from repro.models import paper_models as pm


def vgg_loss(p, batch):
    return pm.xent_loss(pm.vgg16_apply(p, batch["x"]), batch["y"]), {}


def _vgg_setup(rng, c=4, steps=2, bs=4):
    params = pm.init_vgg16(rng, width_mult=0.125)
    assign = build_units_flat(params, pm.vgg16_units(params))
    x, y = cifar_like(c * steps * bs, key=0)
    batches = {
        "x": jnp.asarray(x).reshape(c, steps, bs, 32, 32, 3),
        "y": jnp.asarray(y).reshape(c, steps, bs),
    }
    return params, assign, batches


def _spec(width=0.125):
    return ModelSpec(
        name="vgg16",
        init_params=functools.partial(pm.init_vgg16, width_mult=width),
        loss_fn=vgg_loss, unit_order=pm.vgg16_units)


def _loader(c=4, n=96):
    x, y = cifar_like(n, key=0)
    shards = iid_partition(n, c, key=1)
    return FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                           batch_size=4, steps_per_round=2)


def _assert_trees_bitexact(a, b):
    for pa, pb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb)), \
            "params diverged bitwise"


# -- registry ---------------------------------------------------------------

def test_builtin_topologies_registered():
    assert {"hub", "hierarchical", "gossip"} <= set(registered_topologies())


def test_unknown_topology_lists_registered_names():
    with pytest.raises(UnknownTopologyError, match="hierarchical"):
        get_topology("does_not_exist")


def test_custom_topology_roundtrips():
    @register_topology
    class Echo(Topology):
        name = "_test_echo"

        def build_round_step(self, loss_fn, assign, fl, loss_kwargs=None,
                             *, strategy=None, scores=None):
            return get_topology("hub").build_round_step(
                loss_fn, assign, fl, loss_kwargs, strategy=strategy,
                scores=scores)

        def round_bytes(self, sel, ubytes, fl):
            return comm.hub_round_bytes(sel, ubytes)

    try:
        assert "_test_echo" in registered_topologies()
        fed = Federation.from_config(
            _spec(), FLConfig(n_clients=3, n_train_units=4, lr=1e-3,
                              topology="_test_echo"),
            data=_loader(c=3))
        fed.fit(1)
        assert fed.history[0].uplink_bytes > 0
    finally:
        unregister_topology("_test_echo")
    assert "_test_echo" not in registered_topologies()


# -- hub: bit-exact with the pre-topology path ------------------------------

def _pretopology_round_step(loss_fn, assign, fl, scores=None):
    """Verbatim re-implementation of the pre-topology masked round step
    (PR 1's build_round_step body) — the bit-exactness oracle."""
    strat = resolve_strategy(fl.strategy, fl.synchronized)
    n_train = fl.resolve_n_train(assign.n_units)
    ctx = SelectionContext(n_clients=fl.n_clients, n_units=assign.n_units,
                           n_train=n_train, scores=scores)

    def round_step(global_params, client_batches, weights, round_key):
        sel = strat.select(round_key, ctx)

        def one_client(sel_row, batches):
            mask = mask_tree(assign, sel_row, global_params)
            return local_update(loss_fn, global_params, mask, batches,
                                lr=fl.lr, optimizer=fl.optimizer,
                                prox_mu=fl.prox_mu)

        deltas, metrics = jax.vmap(one_client)(sel, client_batches)
        new_params = masked_fedavg(global_params, deltas, sel, weights,
                                   assign)
        return new_params, {"loss_mean": metrics["loss_mean"].mean(),
                            "sel": sel}

    return round_step


def test_hub_bitexact_with_pretopology_path(rng):
    params, assign, batches = _vgg_setup(rng)
    fl = FLConfig(n_clients=4, n_train_units=5, lr=1e-3)
    assert fl.topology == "hub"                      # the default
    unified = jax.jit(build_round_step(vgg_loss, assign, fl))
    oracle = jax.jit(_pretopology_round_step(vgg_loss, assign, fl))
    w = jnp.asarray([1.0, 2.0, 1.0, 3.0])
    p1, p2 = params, params
    for r in range(3):                               # multi-round drift check
        key = jax.random.PRNGKey(100 + r)
        p1, m1 = unified(p1, batches, w, key)
        p2, m2 = oracle(p2, batches, w, key)
    _assert_trees_bitexact(p1, p2)
    assert float(m1["loss_mean"]) == float(m2["loss_mean"])
    assert np.array_equal(np.asarray(m1["sel"]), np.asarray(m2["sel"]))


# -- hierarchical -----------------------------------------------------------

def test_hierarchical_two_stage_matches_flat_average(rng):
    """Partial weighted sums are associative: the two-stage edge->hub
    average agrees with the flat hub average to float tolerance."""
    params, assign, batches = _vgg_setup(rng)
    fl = FLConfig(n_clients=4, n_train_units=5, lr=1e-3)
    key = jax.random.PRNGKey(0)
    sel = resolve_strategy("uniform").select(
        key, SelectionContext(4, assign.n_units, 5))
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])

    def one_client(sel_row, b):
        mask = mask_tree(assign, sel_row, params)
        return local_update(vgg_loss, params, mask, b, lr=1e-3)

    deltas, _ = jax.vmap(one_client)(sel, batches)
    flat = masked_fedavg(params, deltas, sel, w, assign)
    mem = jnp.asarray(comm.edge_membership(4, 2))
    hier = hierarchical_masked_fedavg(params, deltas, sel, w, assign, mem)
    for a, b in zip(jax.tree_util.tree_leaves(flat),
                    jax.tree_util.tree_leaves(hier)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_hierarchical_bytes_closed_form():
    """Hand-built selection: byte accounting must equal the closed-form
    expectation exactly."""
    ub = np.array([10.0, 20.0, 40.0])                # 3 units
    mem = comm.edge_membership(4, 2)                 # edges {0,1} {2,3}
    sel = np.array([[1, 0, 0],                       # edge 0 union: u0,u1
                    [0, 1, 0],
                    [0, 1, 0],                       # edge 1 union: u1,u2
                    [0, 0, 1]], np.float32)
    d = comm.hierarchical_round_bytes(sel, ub, mem)
    assert d["client_edge_uplink"] == 10 + 20 + 20 + 40          # per client
    assert d["edge_hub_uplink"] == (10 + 20) + (20 + 40)         # per union
    assert d["uplink"] == d["edge_hub_uplink"]
    assert d["uplink_frac"] == pytest.approx(90 / (70 * 2))
    # full downlink: hub -> 2 edges + edges -> 4 clients, full model each
    assert d["downlink"] == 70 * (2 + 4)
    # a unit double-trained inside one edge crosses the WAN once
    sel2 = np.array([[1, 0, 0], [1, 0, 0],
                     [0, 0, 0], [0, 0, 0]], np.float32)
    d2 = comm.hierarchical_round_bytes(sel2, ub, mem)
    assert d2["client_edge_uplink"] == 20 and d2["edge_hub_uplink"] == 10


def test_hierarchical_wan_below_flat_hub_at_paper_settings():
    """The acceptance bound: edge->hub WAN strictly below flat-hub
    uplink for the paper's 25% (4/14) and 50% (7/14) settings."""
    from repro.core import freezing
    ub = np.ones(14) * 4e6
    mem = comm.edge_membership(10, 2)
    for n in (4, 7):
        flat = wan = 0.0
        for r in range(50):
            sel = np.asarray(freezing.select_clients(
                jax.random.PRNGKey(r), 10, 14, n))
            flat += comm.hub_round_bytes(sel, ub)["uplink"]
            wan += comm.hierarchical_round_bytes(sel, ub,
                                                 mem)["edge_hub_uplink"]
        assert wan < flat


def test_hierarchical_federation_end_to_end():
    fed = Federation.from_config(
        _spec(), FLConfig(n_clients=4, n_train_units=7, lr=1e-3,
                          topology="hierarchical", n_edges=2),
        data=_loader())
    hist = fed.fit(2)
    assert len(hist) == 2 and all(np.isfinite(r.loss) for r in hist)
    ub = comm.unit_bytes(fed.assign, fed.params)
    mem = comm.edge_membership(4, 2)
    for rec, sel in zip(hist, fed.server.sel_history):
        expect = comm.hierarchical_round_bytes(sel, ub, mem)["uplink"]
        assert rec.uplink_bytes == pytest.approx(expect)
    summ = fed.comm_summary()
    assert 0.0 < summ["reduction_vs_full"] < 1.0


def test_bad_n_edges_rejected():
    with pytest.raises(ValueError, match="n_edges"):
        FLConfig(n_clients=4, n_train_units=2, n_edges=9,
                 topology="hierarchical").resolve_n_edges()


# -- gossip -----------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 4, 7])
def test_ring_mixing_matrix_doubly_stochastic(n):
    w = ring_mixing_matrix(n)
    assert w.shape == (n, n) and (w >= 0).all()
    np.testing.assert_allclose(w.sum(axis=1), np.ones(n), atol=1e-6)
    np.testing.assert_allclose(w.sum(axis=0), np.ones(n), atol=1e-6)


def test_gossip_converges_quickstart_scale():
    fed = Federation.from_config(
        _spec(), FLConfig(n_clients=4, n_train_units=7, lr=3e-3,
                          topology="gossip"),
        data=_loader())
    hist = fed.fit(5)
    # state is a stacked replica tree; params is the mean-replica view
    for leaf, ref in zip(jax.tree_util.tree_leaves(fed.state),
                         jax.tree_util.tree_leaves(fed.params)):
        assert leaf.shape == (4,) + ref.shape
    assert hist[-1].loss < hist[0].loss
    # peer traffic is full replicas: no reduction from freezing
    assert fed.comm_summary()["reduction_vs_full"] == 0.0


def test_gossip_mixing_preserves_replica_mean(rng):
    """Doubly-stochastic mixing keeps the uniform replica average
    invariant: a round with zero active clients (weights 0) must leave
    the mean replica numerically unchanged."""
    params, assign, batches = _vgg_setup(rng)
    fl = FLConfig(n_clients=4, n_train_units=5, lr=1e-3,
                  topology="gossip")
    topo = get_topology("gossip")
    state = topo.init_state(params, fl)
    # perturb replicas so mixing actually moves them
    state = jax.tree_util.tree_map(
        lambda x: x * (1.0 + 0.01 * jnp.arange(4.0).reshape(
            (4,) + (1,) * (x.ndim - 1))), state)
    before = topo.global_params(state, fl)
    step = jax.jit(build_round_step(vgg_loss, assign, fl))
    new_state, _ = step(state, batches, jnp.zeros(4), jax.random.PRNGKey(0))
    after = topo.global_params(new_state, fl)
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(after)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# -- save / restore mid-fit -------------------------------------------------

def test_federation_save_restore_roundtrip_midfit(tmp_path):
    path = str(tmp_path / "mid")
    fl = FLConfig(n_clients=4, n_train_units=5, lr=1e-3)
    fed = Federation.from_config(_spec(), fl, data=_loader(), seed=3)
    fed.fit(2)
    fed.save(path)
    fed.fit(2)
    p_straight = jax.tree_util.tree_map(np.asarray, fed.params)

    fed2 = Federation.from_config(_spec(), fl, data=_loader(), seed=3)
    meta = fed2.restore(path)
    assert meta["round"] == 2 and len(fed2.history) == 2
    assert len(fed2.server.sel_history) == 2
    fed2.fit(2)                       # resumes rounds 2..3 bit-exactly
    _assert_trees_bitexact(p_straight, fed2.params)
    assert [r.round for r in fed2.history] == [0, 1, 2, 3]


def test_async_save_restore_rebuilds_buffer_midfit(tmp_path):
    """Buffered-async resume (DESIGN.md §8): ``Federation.restore``
    mid-fit must rebuild the update buffer, per-client round tags and
    the delay-scheduler's in-flight work bit-exactly — the restored run
    continues identically to the uninterrupted one."""
    path = str(tmp_path / "async_mid")
    fl = FLConfig(n_clients=4, n_train_units=5, lr=1e-3, fused_agg="off",
                  topology="hierarchical", n_edges=2, async_buffer=3,
                  client_delay_dist="pareto:1.5")
    fed = Federation.from_config(_spec(), fl, data=_loader(), seed=3)
    fed.fit(2)
    fed.save(path)
    eng = fed.server.async_engine
    saved_buffer = [(u.client, u.seq, u.version) for u in
                    sorted(eng.buffer.entries,
                           key=lambda u: (u.client, u.seq))]
    saved_seq = eng.seq.copy()
    fed.fit(2)
    p_straight = jax.tree_util.tree_map(np.asarray, fed.params)

    fed2 = Federation.from_config(_spec(), fl, data=_loader(), seed=3)
    meta = fed2.restore(path)
    eng2 = fed2.server.async_engine
    assert meta["round"] == 2 and len(fed2.history) == 2
    # buffer contents, per-client round tags and in-flight work rebuilt
    assert [(u.client, u.seq, u.version) for u in
            sorted(eng2.buffer.entries,
                   key=lambda u: (u.client, u.seq))] == saved_buffer
    assert np.array_equal(eng2.seq, saved_seq)
    assert eng2.version == 2 and eng2.started
    assert sorted(eng2.pending) == sorted(
        (u.t_done, u.client, u.seq) for u in eng2.inflight.values())
    for u in eng2.buffer.entries:
        assert np.asarray(u.sel_row).shape == (fed2.assign.n_units,)
    fed2.fit(2)                      # resumes flushes 2..3 bit-exactly
    _assert_trees_bitexact(p_straight, fed2.params)
    assert [r.round for r in fed2.history] == [0, 1, 2, 3]
    assert [r.sim_time for r in fed2.history] == \
        [r.sim_time for r in fed.history]
    assert [r.staleness_mean for r in fed2.history] == \
        [r.staleness_mean for r in fed.history]


def test_gossip_save_restore_roundtrip(tmp_path):
    path = str(tmp_path / "gos")
    fl = FLConfig(n_clients=3, n_train_units=5, lr=1e-3,
                  topology="gossip")
    fed = Federation.from_config(_spec(), fl, data=_loader(c=3), seed=0)
    fed.fit(1)
    fed.save(path)
    fed2 = Federation.from_config(_spec(), fl, data=_loader(c=3), seed=0)
    fed2.restore(path)
    _assert_trees_bitexact(fed.state, fed2.state)    # full replica state


# -- hub downlink accounting + resumed logging cadence ----------------------

def test_hub_downlink_selected_mode():
    ub = np.array([10.0, 20.0, 40.0])
    sel = np.array([[1, 1, 0], [1, 1, 0]], np.float32)   # synchronized row
    full = comm.hub_round_bytes(sel, ub, downlink="full")
    assert full["downlink"] == 70 * 2
    seld = comm.hub_round_bytes(sel, ub, downlink="selected")
    assert seld["downlink"] == 30 * 2 == seld["uplink"]
    with pytest.raises(ValueError, match="downlink"):
        comm.hub_round_bytes(sel, ub, downlink="nope")


def test_round_logger_resumed_cadence(capsys):
    from repro.core import RoundRecord
    log = RoundLogger(every=2, total=8, base=3)
    for r in range(3, 8):
        rec = RoundRecord(round=r, loss=1.0, eval_metric=None,
                          seconds=0.0, uplink_bytes=0.0,
                          trained_params=0.0, n_participants=1)
        log.on_round_end(None, rec, {})
    rounds = [int(l.split()[1]) for l in
              capsys.readouterr().out.strip().splitlines()]
    # cadence anchored at the resume base, final round always printed
    assert rounds == [3, 5, 7]
