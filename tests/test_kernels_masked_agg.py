"""Fused masked-FedAvg Pallas kernel vs core.aggregation oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import reduced_cfg
from repro.core.aggregation import masked_fedavg
from repro.core.masking import build_units_flat, build_units_zoo
from repro.kernels.masked_agg.ops import masked_fedavg_fused
from repro.common import flatten_with_paths
from repro.models import get_model, paper_models as pm


def _compare(p, assign, c, sel, w, tile, rng):
    deltas = jax.tree_util.tree_map(
        lambda x: jax.random.normal(
            jax.random.fold_in(rng, abs(hash(str(x.shape))) % 9999),
            (c,) + x.shape) * 0.05, p)
    ref = masked_fedavg(p, deltas, sel, w, assign)
    got = masked_fedavg_fused(p, deltas, sel, w, assign, tile=tile)
    for (path, a), (_, b) in zip(flatten_with_paths(ref),
                                 flatten_with_paths(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5, err_msg=path)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-12b", "rwkv6-3b"])
@pytest.mark.parametrize("tile", [256, 1024])
def test_fused_equals_oracle_zoo(arch, tile, rng):
    cfg = reduced_cfg(arch)
    m = get_model(cfg)
    p = m.init_params(rng)
    assign = build_units_zoo(cfg, p)
    c = 4
    sel = jnp.asarray(np.random.default_rng(0).integers(
        0, 2, (c, assign.n_units)), jnp.float32)
    w = jnp.asarray([1.0, 2.0, 0.5, 3.0])
    _compare(p, assign, c, sel, w, tile, rng)


def test_fused_equals_oracle_vgg(rng):
    p = pm.init_vgg16(rng, width_mult=0.125)
    assign = build_units_flat(p, pm.vgg16_units(p))
    c = 10
    sel = jnp.asarray(np.random.default_rng(1).integers(
        0, 2, (c, assign.n_units)), jnp.float32)
    _compare(p, assign, c, sel, jnp.ones(c), 512, rng)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500), c=st.integers(2, 6))
def test_property_random_selections(seed, c):
    rng = jax.random.PRNGKey(seed)
    cfg = reduced_cfg("qwen3-1.7b")
    m = get_model(cfg)
    p = m.init_params(rng)
    assign = build_units_zoo(cfg, p)
    sel = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2, (c, assign.n_units)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(seed + 1)
                    .uniform(0.1, 3.0, c), jnp.float32)
    _compare(p, assign, c, sel, w, 512, rng)
