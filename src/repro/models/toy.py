"""Tiny stacked-block residual MLP for round-step tests and benches.

Not a paper model: its job is to exercise BOTH leaf kinds of the unit
assignment — scalar input/head leaves plus *stacked* block leaves
applied under ``lax.scan`` — at a size where dense-masked, packed and
fused round steps can be compared quickly on a CPU host.  Unit layout
mirrors the zoo models: unit 0 = input projection, units 1..n_blocks =
one per block, unit n_blocks+1 = head.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.masking import LeafUnit, UnitAssignment


def init_toy_mlp(key, *, n_blocks: int = 8, d: int = 32, hidden: int = 64,
                 out: int = 8) -> Dict:
    ks = jax.random.split(key, 5)
    return {
        "inp": {"w": jax.random.normal(ks[0], (d, d)) / jnp.sqrt(d)},
        "blocks": {
            "w1": jax.random.normal(ks[1], (n_blocks, d, hidden))
            / jnp.sqrt(d),
            "b1": jnp.zeros((n_blocks, hidden)),
            "w2": jax.random.normal(ks[2], (n_blocks, hidden, d))
            / jnp.sqrt(hidden),
        },
        "head": {"w": jax.random.normal(ks[3], (d, out)) / jnp.sqrt(d),
                 "b": jnp.zeros((out,))},
    }


def toy_units(params) -> UnitAssignment:
    """One unit per block (stacked) + scalar input / head units."""
    n_blocks = params["blocks"]["w1"].shape[0]
    head_unit = n_blocks + 1
    leaf_units = {
        "inp": {"w": LeafUnit("scalar", 0, 0)},
        "blocks": {k: LeafUnit("stacked", 1, 1) for k in params["blocks"]},
        "head": {k: LeafUnit("scalar", head_unit, 0)
                 for k in params["head"]},
    }
    names = (("inp",) + tuple(f"block{i}" for i in range(n_blocks))
             + ("head",))
    return UnitAssignment(n_blocks + 2, leaf_units, names)


def toy_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ params["inp"]["w"]

    def blk(h, wb):
        w1, b1, w2 = wb
        return h + jnp.tanh(h @ w1 + b1) @ w2, None

    h, _ = jax.lax.scan(blk, h, (params["blocks"]["w1"],
                                 params["blocks"]["b1"],
                                 params["blocks"]["w2"]))
    return h @ params["head"]["w"] + params["head"]["b"]


def toy_loss(params, batch) -> Tuple[jnp.ndarray, Dict]:
    pred = toy_apply(params, batch["x"])
    return jnp.mean(jnp.square(pred - batch["y"])), {}


def toy_batches(key, *, n_clients: int, steps: int, batch: int, d: int,
                out: int):
    """(C, steps, b, ...) synthetic regression batches."""
    kx, ky = jax.random.split(key)
    return {"x": jax.random.normal(kx, (n_clients, steps, batch, d)),
            "y": jax.random.normal(ky, (n_clients, steps, batch, out))}
