"""Attention computation variants.

Three implementations share one signature; models pick per-layer:

  * ``reference``  — materializes the (S, S) score matrix.  Smoke tests.
  * ``chunked``    — flash-structured pure-JAX: outer scan over Q blocks,
                     inner scan over KV blocks with online softmax.  This
                     is the lowering-safe path for 32k prefill (the score
                     matrix never materializes).
  * ``windowed``   — sliding-window attention via a gathered KV slab of
                     width (window + q_chunk) per Q block: sub-quadratic
                     and lowering-safe for gemma3/hymba local layers.

Decode-time single-token attention lives in ``decode_attend`` (full cache),
``decode_attend_ring`` (ring-buffer sliding-window cache), and
``decode_attend_paged`` (page-table indirection over a shared block pool —
the serving engine's cache, DESIGN.md §12).

The Pallas TPU kernels in ``repro.kernels.flash_attention`` /
``flash_decode`` implement the same contracts; ``kernels/*/ref.py``
delegate here so every kernel has a pure-jnp oracle.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    """(B,S,Hkv,hd) -> (B,S,Hkv*n_rep,hd) for GQA."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
              .reshape(b, s, h * n_rep, d)


# ---------------------------------------------------------------------------
# reference
# ---------------------------------------------------------------------------

def attend_reference(q, k, v, *, causal: bool = True, window: int = 0,
                     q_offset: int = 0):
    """q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd) -> (B,Sq,H,hd).

    ``q_offset`` is the absolute position of q[0] relative to k[0]
    (used at decode: Sq=1, offset=cache_len-1).
    """
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


# ---------------------------------------------------------------------------
# chunked (flash-structured, pure JAX)
# ---------------------------------------------------------------------------

def _fit_chunk(s: int, c: int) -> int:
    """Largest divisor of s that is <= c (handles 1500-frame encoders)."""
    c = min(c, s)
    while s % c:
        c -= 1
    return c


def attend_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                   q_chunk: int = 1024, kv_chunk: int = 1024,
                   q_offset: int = 0):
    """Online-softmax blockwise attention; O(S·chunk) live memory.

    Baseline iterates ALL (Qi, Kj) block pairs and masks — the causal
    upper triangle is computed-then-discarded (2x attention FLOPs).  The
    §Perf hillclimb replaces this with the Pallas kernel's block-skip on
    TPU; see EXPERIMENTS.md.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    q_chunk = _fit_chunk(sq, q_chunk)
    kv_chunk = _fit_chunk(sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    n_rep = h // hkv
    scale = 1.0 / math.sqrt(hd)

    kc = k.reshape(b, nk, kv_chunk, hkv, hd)
    vc = v.reshape(b, nk, kv_chunk, hkv, hd)
    qc = q.reshape(b, nq, q_chunk, h, hd)

    def q_block(qi, q_blk):
        # q_blk (B, qc, H, hd)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, k_blk, v_blk = inputs
            k_r = _repeat_kv(k_blk, n_rep)       # (B, kc, H, hd)
            v_r = _repeat_kv(v_blk, n_rep)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_r).astype(jnp.float32)
            s = s * scale
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            msk = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(q.dtype), v_r).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        # checkpoint the inner body: without it, AD saves the (qc, kc)
        # probability block for EVERY block pair = the full S^2 score
        # matrix in f32 — exactly what flash attention exists to avoid.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.swapaxes(1, 2).astype(q.dtype)       # (B, qc, H, hd)

    def scan_q(_, inputs):
        qi, q_blk = inputs
        return None, q_block(qi, q_blk)

    _, outs = jax.lax.scan(jax.checkpoint(scan_q), None,
                           (jnp.arange(nq), qc.swapaxes(0, 1)))
    return outs.swapaxes(0, 1).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# sliding window via KV slab gather (sub-quadratic)
# ---------------------------------------------------------------------------

def attend_windowed(q, k, v, *, window: int, q_chunk: int = 1024,
                    q_offset: int = 0):
    """Causal sliding-window attention in O(S · window).

    For each Q block, gather the KV slab [qstart - window, qstart + qc)
    (clamped) and run dense attention against it.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    n_rep = h // hkv
    q_chunk = _fit_chunk(sq, q_chunk)
    nq = sq // q_chunk
    slab = window + q_chunk
    scale = 1.0 / math.sqrt(hd)
    qc = q.reshape(b, nq, q_chunk, h, hd)

    def q_block(qi, q_blk):
        qstart = q_offset + qi * q_chunk
        start = jnp.clip(qstart - window, 0, max(sk - slab, 0))
        k_s = jax.lax.dynamic_slice_in_dim(k, start, min(slab, sk), axis=1)
        v_s = jax.lax.dynamic_slice_in_dim(v, start, min(slab, sk), axis=1)
        k_r = _repeat_kv(k_s, n_rep)
        v_r = _repeat_kv(v_s, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_r).astype(jnp.float32) * scale
        qpos = qstart + jnp.arange(q_chunk)
        kpos = start + jnp.arange(k_s.shape[1])
        msk = (kpos[None, :] <= qpos[:, None]) & \
              (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(msk[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", w, v_r)

    def scan_q(_, inputs):
        qi, q_blk = inputs
        return None, q_block(qi, q_blk)

    _, outs = jax.lax.scan(jax.checkpoint(scan_q), None,
                           (jnp.arange(nq), qc.swapaxes(0, 1)))
    return outs.swapaxes(0, 1).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# decode (single query token against a cache)
# ---------------------------------------------------------------------------

def cache_token_update(cache, new, pos):
    """Write one token into a KV cache WITHOUT dynamic_update_slice.

    cache (B, A, Hkv, hd); new (B, 1, Hkv, hd); pos scalar int.  A DUS at
    a traced index on a sequence-sharded cache forces GSPMD to all-gather
    the whole cache (observed: 60 GB/device on decode_32k); the masked
    select keeps the write shard-local.
    """
    a = cache.shape[1]
    mask = (jnp.arange(a) == pos)[None, :, None, None]
    return jnp.where(mask, new.astype(cache.dtype), cache)

def decode_attend(q, k_cache, v_cache, valid_len, *, window: int = 0):
    """q (B,1,H,hd) against caches (B,S,Hkv,hd); positions >= valid_len
    are masked.  Returns (B,1,H,hd)."""
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    k = _repeat_kv(k_cache, h // hkv)
    v = _repeat_kv(v_cache, h // hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    kpos = jnp.arange(s)
    msk = kpos[None, :] < valid_len[:, None]                 # (B,S)
    if window > 0:
        msk &= kpos[None, :] >= valid_len[:, None] - window
    scores = jnp.where(msk[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def paged_gather(pool, page_table):
    """Materialize a sequence's cache view from a shared page pool.

    pool (P, ps, Hkv, hd) — physical pages; page_table (B, MP) int32 maps
    each sequence's logical page j to a physical page id.  Returns
    (B, MP·ps, Hkv, hd) — the same dense layout ``decode_attend`` reads,
    so a paged decode is bitwise-equal to the dense one (unallocated
    entries point at the reserved trash page 0 and are masked by
    ``valid_len`` before the softmax).
    """
    b, mp = page_table.shape
    _, ps, hkv, hd = pool.shape
    return pool[page_table].reshape(b, mp * ps, hkv, hd)


def paged_token_update(pool, new, pages, offs):
    """Write one token per sequence into its current page.

    pool (P, ps, Hkv, hd); new (B, 1, Hkv, hd); pages/offs (B,) int32 —
    physical page id and in-page offset per sequence.  Distinct active
    sequences own distinct pages so the scatter never collides; inactive
    slots target the trash page 0 (never read unmasked).
    """
    return pool.at[pages, offs].set(new[:, 0].astype(pool.dtype))


def decode_attend_paged(q, k_pool, v_pool, page_table, valid_len):
    """Single-token attention through a page table (pure-jnp reference).

    q (B,1,H,hd); pools (P, ps, Hkv, hd); page_table (B, MP);
    valid_len (B,).  Ring (sliding-window) callers pre-clamp valid_len to
    the ring allocation — slot order does not matter to softmax(QK)V.
    """
    k = paged_gather(k_pool, page_table)
    v = paged_gather(v_pool, page_table)
    return decode_attend(q, k, v, valid_len)


def decode_attend_ring(q, k_ring, v_ring, step, *, window: int):
    """Sliding-window decode against a ring buffer of size ``window``.

    ``step`` (B,) int — number of tokens already written (ring slot of the
    newest entry is (step-1) % window).  All slots < min(step, window) are
    valid; ring order does not matter for softmax(QK)V.
    """
    b, _, h, hd = q.shape
    hkv = k_ring.shape[2]
    k = _repeat_kv(k_ring, h // hkv)
    v = _repeat_kv(v_ring, h // hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    slot = jnp.arange(window)
    valid = slot[None, :] < jnp.minimum(step, window)[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def attend(q, k, v, *, impl: str = "chunked", causal: bool = True,
           window: int = 0, q_offset: int = 0, q_chunk: int = 1024,
           kv_chunk: int = 1024):
    """Dispatch by impl name (training/prefill path)."""
    if impl == "reference" or q.shape[1] <= max(q_chunk, 256) // 2:
        return attend_reference(q, k, v, causal=causal, window=window,
                                q_offset=q_offset)
    if window > 0 and impl != "reference":
        return attend_windowed(q, k, v, window=window, q_chunk=q_chunk,
                               q_offset=q_offset)
    if impl == "chunked":
        return attend_chunked(q, k, v, causal=causal, window=window,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              q_offset=q_offset)
    raise ValueError(f"unknown attention impl {impl!r}")
