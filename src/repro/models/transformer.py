"""Decoder-only transformer covering the dense / MoE / VLM families.

Architectures are expressed as a repeated **macro-block** scanned with
``lax.scan``: the macro is the smallest statically-heterogeneous repeat
unit (gemma3: 5 sliding-window layers + 1 global = macro of 6; llama4:
dense block + MoE block = macro of 2; plain dense: macro of 1).  Block
params are stacked along the leading macro dim so the HLO stays compact
for 48-layer configs and freeze-unit masks broadcast per layer
(core/masking.py).

Covers: stablelm-3b, qwen2.5-14b, qwen3-1.7b, gemma3-12b,
llama4-maverick-400b-a17b, granite-moe-1b-a400m, internvl2-26b (VLM:
patch embeddings from the stub frontend are projected and prepended).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as M
from ..kernels.flash_decode.ops import paged_decode_attention
from .attention import (attend, cache_token_update, decode_attend,
                        decode_attend_ring, paged_token_update)


@dataclasses.dataclass(frozen=True)
class SubSpec:
    window: int      # 0 = full causal attention
    moe: bool        # MoE MLP instead of dense MLP


def block_layout(cfg) -> Tuple[SubSpec, ...]:
    if cfg.moe is not None and cfg.moe.interleave > 1:
        macro = cfg.moe.interleave
        # dense blocks first, the MoE block closes the macro (llama4 style)
        return tuple(SubSpec(window=cfg.sliding_window if cfg.global_every else 0,
                             moe=(i == macro - 1)) for i in range(macro))
    if cfg.global_every:
        macro = cfg.global_every
        # L ... L G — the last layer of each macro is global
        return tuple(SubSpec(window=0 if i == macro - 1 else cfg.sliding_window,
                             moe=cfg.moe is not None) for i in range(macro))
    if cfg.sliding_window:
        return (SubSpec(window=cfg.sliding_window, moe=cfg.moe is not None),)
    return (SubSpec(window=0, moe=cfg.moe is not None),)


def n_macro(cfg) -> int:
    macro = len(block_layout(cfg))
    if cfg.n_layers % macro:
        raise ValueError(f"{cfg.name}: n_layers {cfg.n_layers} % macro {macro}")
    return cfg.n_layers // macro


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_sub(cfg, key, spec: SubSpec, dtype):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ln2": L.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if spec.moe:
        p["moe"] = M.init_moe(ks[1], cfg.d_model, cfg.moe, dtype)
        if cfg.moe.shared_d_ff:
            p["shared"] = L.init_mlp(ks[2], cfg.d_model, cfg.moe.shared_d_ff,
                                     dtype, glu=cfg.glu)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype, glu=cfg.glu)
    return p


def init_params(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    layout = block_layout(cfg)
    nm = n_macro(cfg)
    k_embed, k_blocks, k_head, k_proj = jax.random.split(key, 4)

    blocks = {}
    for si, spec in enumerate(layout):
        keys = jax.random.split(jax.random.fold_in(k_blocks, si), nm)
        blocks[f"sub{si}"] = jax.vmap(
            lambda k: _init_sub(cfg, k, spec, dtype))(keys)

    params: Dict[str, Any] = {
        "embed": L.init_embed(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab),
                                            dtype)}
    if cfg.n_patches:  # VLM projector: stub ViT feature width -> d_model
        params["projector"] = {
            "w": L.dense_init(k_proj, (vit_width(cfg), cfg.d_model), dtype),
            "b": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


def vit_width(cfg) -> int:
    """Feature width fed by the stub vision frontend (DESIGN.md §7)."""
    return min(1024, cfg.d_model)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _apply_sub(cfg, p, spec: SubSpec, x, positions, rope, attn_impl,
               q_chunk: int, moe_mesh=None):
    h = L.apply_norm(p["ln1"], x)
    q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope)
    o = attend(q, k, v, impl=attn_impl, causal=True, window=spec.window,
               q_chunk=q_chunk)
    x = x + L.out_project(p["attn"], o)
    h = L.apply_norm(p["ln2"], x)
    if spec.moe:
        if moe_mesh is not None:   # explicit TP dispatch (shard_map)
            y, aux = M.apply_moe_sharded(p["moe"], h, cfg.moe, act=cfg.act,
                                         mesh=moe_mesh)
        else:
            y, aux = M.apply_moe(p["moe"], h, cfg.moe, act=cfg.act)
        if "shared" in p:
            y = y + L.apply_mlp(p["shared"], h, cfg.act)
    else:
        y, aux = L.apply_mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + y, aux, (k, v)


def _embed_inputs(cfg, params, tokens, patches):
    x = L.embed_tokens(params["embed"], tokens)
    if cfg.n_patches:
        if patches is None:
            raise ValueError(f"{cfg.name} requires patch embeddings")
        px = patches @ params["projector"]["w"] + params["projector"]["b"]
        x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
    return x


def forward(cfg, params, tokens, *, patches=None, attn_impl="chunked",
            q_chunk: int = 1024, build_cache: bool = False,
            cache_len: int = 0, remat: bool = False,
            last_only: bool = False, unroll: bool = False, moe_mesh=None):
    """tokens (B, S_text) [+ patches (B, n_patches, vit_width)] -> logits.

    Returns (logits (B,S,V), aux_loss, cache_or_None).
    ``remat=True`` checkpoints each macro-block (activation recompute in
    the backward scan — the standard memory/compute trade).
    """
    layout = block_layout(cfg)
    rope = L.rope_freqs(cfg.head_dim, cfg.rope_pct, cfg.rope_theta)
    x = _embed_inputs(cfg, params, tokens, patches)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, blk):
        x = carry
        auxes = []
        cache_out = {}
        for si, spec in enumerate(layout):
            x, aux, (k, v) = _apply_sub(cfg, blk[f"sub{si}"], spec, x,
                                        positions, rope, attn_impl, q_chunk,
                                        moe_mesh=moe_mesh)
            auxes.append(aux)
            if build_cache:
                cache_out[f"sub{si}"] = _cache_from_prefill(
                    spec, k, v, s, cache_len)
        return x, (jnp.stack(auxes).sum(), cache_out if build_cache else 0)

    if remat:
        body = jax.checkpoint(body)
    x, (auxes, caches) = jax.lax.scan(body, x, params["blocks"],
                                      unroll=n_macro(cfg) if unroll else 1)
    if last_only:
        x = x[:, -1:]
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, cfg.tie_embeddings)
    cache = None
    if build_cache:
        cache = {"step": jnp.asarray(s, jnp.int32), "subs": caches}
    return logits, auxes.sum(), cache


def loss_fn(cfg, params, batch, *, attn_impl="chunked", q_chunk: int = 1024,
            remat: bool = False, unroll: bool = False, moe_mesh=None):
    logits, aux, _ = forward(cfg, params, batch["tokens"],
                             patches=batch.get("patches"),
                             attn_impl=attn_impl, q_chunk=q_chunk,
                             remat=remat, unroll=unroll, moe_mesh=moe_mesh)
    labels = batch["labels"]
    if cfg.n_patches:  # loss only on text positions
        logits = logits[:, cfg.n_patches:]
    loss = L.softmax_xent(logits, labels, batch.get("loss_mask"))
    return loss + aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def cache_alloc(cfg, spec: SubSpec, max_len: int) -> int:
    return min(spec.window, max_len) if spec.window > 0 else max_len


def init_cache(cfg, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    layout = block_layout(cfg)
    nm = n_macro(cfg)
    subs = {}
    for si, spec in enumerate(layout):
        a = cache_alloc(cfg, spec, max_len)
        subs[f"sub{si}"] = {
            "k": jnp.zeros((nm, batch_size, a, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((nm, batch_size, a, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
        }
    return {"step": jnp.zeros((), jnp.int32), "subs": subs}


def _cache_from_prefill(spec: SubSpec, k, v, s: int, cache_len: int):
    """Build a cache slab from prefill K/V (B,S,Hkv,hd)."""
    a = min(spec.window, cache_len) if spec.window > 0 else cache_len
    b, _, hkv, hd = k.shape
    if spec.window > 0 and s >= a:
        # ring layout: ring[(s + j) % a] = kv[s - a + j]
        slots = (s + jnp.arange(a)) % a
        kr = jnp.zeros((b, a, hkv, hd), k.dtype).at[:, slots].set(k[:, s - a:])
        vr = jnp.zeros((b, a, hkv, hd), v.dtype).at[:, slots].set(v[:, s - a:])
        return {"k": kr, "v": vr}
    pad = a - s
    if pad < 0:
        raise ValueError(f"cache_len {cache_len} < prefill len {s}")
    kr = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vr = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": kr, "v": vr}


def prefill(cfg, params, tokens, *, patches=None, max_len: int,
            attn_impl="chunked", q_chunk: int = 1024,
            last_only: bool = False, unroll: bool = False, **_):
    logits, aux, cache = forward(cfg, params, tokens, patches=patches,
                                 attn_impl=attn_impl, q_chunk=q_chunk,
                                 build_cache=True, cache_len=max_len,
                                 last_only=last_only, unroll=unroll)
    return logits, cache


def init_paged_cache(cfg, n_slots: int, n_pages: int, page_size: int,
                     dtype=None):
    """Shared physical KV page pool for the serving engine (DESIGN.md §12).

    One pool serves every sub-layer stack (all subs share n_kv_heads and
    head_dim; a page covers ``page_size`` tokens across all ``n_macro``
    layers of one sub) — the free list spans the whole pool so ring and
    full allocations draw from the same memory.  Page 0 is the reserved
    trash page: unallocated page-table entries point at it and inactive
    slots write there.
    """
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    nm = n_macro(cfg)
    shape = (nm, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    return {"pool": {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}}


def commit_prefill(cfg, paged, cache, slots, page_tables, *, page_size: int):
    """Scatter a dense prefill cache into the admitted sequences' pages.

    ``cache`` is ``prefill``'s output for a group of g sequences (ring
    layout on sliding-window subs, left-aligned on full subs) — the page
    pool ends up holding exactly the dense slabs, page by page, so
    subsequent paged decode is bitwise-equal to the dense loop.
    ``page_tables[sub] (g, MP_sub)`` rows are the admitted slots' tables;
    unallocated entries (0) land in the trash page.
    """
    layout = block_layout(cfg)
    k_pool, v_pool = paged["pool"]["k"], paged["pool"]["v"]
    ps = page_size
    for si in range(len(layout)):
        c = cache["subs"][f"sub{si}"]
        pt = page_tables[f"sub{si}"]
        nm, g, a, hkv, hd = c["k"].shape
        slab_k = c["k"].reshape(nm, g, a // ps, ps, hkv, hd)
        slab_v = c["v"].reshape(nm, g, a // ps, ps, hkv, hd)
        k_pool = k_pool.at[:, pt].set(slab_k.astype(k_pool.dtype))
        v_pool = v_pool.at[:, pt].set(slab_v.astype(v_pool.dtype))
    return {"pool": {"k": k_pool, "v": v_pool}}


def decode_step_paged(cfg, params, paged, token, steps, page_tables, *,
                      page_size: int, unroll: bool = False):
    """One continuous-batching decode step over the paged pool.

    token (B,1) int32 — B is the engine's static slot count; steps (B,)
    int32 — per-slot token counts (traced, so admit/evict never
    recompiles); page_tables {sub: (B, MP_sub) int32}.  Returns
    (logits, new_paged).  Mirrors ``decode_step`` op-for-op — only the
    cache addressing differs — so greedy decode through the pool is
    bitwise-equal to the dense loop (tests/test_serve_engine.py).
    """
    layout = block_layout(cfg)
    rope = L.rope_freqs(cfg.head_dim, cfg.rope_pct, cfg.rope_theta)
    x = L.embed_tokens(params["embed"], token)          # (B,1,d)
    b = x.shape[0]
    positions = steps[:, None]
    ps = page_size

    def body(carry, xs):
        x = carry
        blk, pool_m = xs
        kp, vp = pool_m["k"], pool_m["v"]
        for si, spec in enumerate(layout):
            p = blk[f"sub{si}"]
            pt = page_tables[f"sub{si}"]
            a = pt.shape[1] * ps
            h = L.apply_norm(p["ln1"], x)
            q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope)
            if spec.window > 0:
                pos = steps % a                      # ring slot per seq
                valid = jnp.minimum(steps + 1, a)
            else:
                pos = steps
                valid = steps + 1
            page = jnp.take_along_axis(pt, (pos // ps)[:, None], 1)[:, 0]
            kp = paged_token_update(kp, k, page, pos % ps)
            vp = paged_token_update(vp, v, page, pos % ps)
            o = paged_decode_attention(q, kp, vp, pt, valid)
            x = x + L.out_project(p["attn"], o)
            h = L.apply_norm(p["ln2"], x)
            if spec.moe:
                y, _ = M.apply_moe(p["moe"], h, cfg.moe, act=cfg.act)
                if "shared" in p:
                    y = y + L.apply_mlp(p["shared"], h, cfg.act)
            else:
                y = L.apply_mlp(p["mlp"], h, cfg.act)
            x = x + y
        return x, {"k": kp, "v": vp}

    x, pool = jax.lax.scan(body, x, (params["blocks"], paged["pool"]),
                           unroll=n_macro(cfg) if unroll else 1)
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, cfg.tie_embeddings)
    return logits, {"pool": pool}


def decode_step(cfg, params, cache, token, *, unroll: bool = False):
    """One decode step.  token (B, 1) int32; cache from init_cache/prefill.

    Writes K/V at position ``cache['step']`` and attends over everything
    written so far (ring semantics for sliding-window layers).
    """
    layout = block_layout(cfg)
    rope = L.rope_freqs(cfg.head_dim, cfg.rope_pct, cfg.rope_theta)
    step = cache["step"]
    x = L.embed_tokens(params["embed"], token)          # (B,1,d)
    b = x.shape[0]
    # cache['step'] counts every cached position (incl. VLM patches)
    positions = jnp.broadcast_to(step, (b, 1))

    def body(carry, xs):
        x = carry
        blk, csubs = xs
        new_csubs = {}
        for si, spec in enumerate(layout):
            p = blk[f"sub{si}"]
            c = csubs[f"sub{si}"]
            h = L.apply_norm(p["ln1"], x)
            q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope)
            a = c["k"].shape[1]
            if spec.window > 0:
                slot = step % a
                kc = cache_token_update(c["k"], k, slot)
                vc = cache_token_update(c["v"], v, slot)
                o = decode_attend_ring(
                    q, kc, vc, jnp.broadcast_to(step + 1, (b,)), window=a)
            else:
                kc = cache_token_update(c["k"], k, step)
                vc = cache_token_update(c["v"], v, step)
                o = decode_attend(q, kc, vc,
                                  jnp.broadcast_to(step + 1, (b,)))
            x = x + L.out_project(p["attn"], o)
            h = L.apply_norm(p["ln2"], x)
            if spec.moe:
                y, _ = M.apply_moe(p["moe"], h, cfg.moe, act=cfg.act)
                if "shared" in p:
                    y = y + L.apply_mlp(p["shared"], h, cfg.act)
            else:
                y = L.apply_mlp(p["mlp"], h, cfg.act)
            x = x + y
            new_csubs[f"sub{si}"] = {"k": kc, "v": vc}
        return x, new_csubs

    x, new_subs = jax.lax.scan(body, x, (params["blocks"], cache["subs"]),
                               unroll=n_macro(cfg) if unroll else 1)
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, cfg.tie_embeddings)
    return logits, {"step": step + 1, "subs": new_subs}
