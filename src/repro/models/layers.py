"""Shared layer primitives for the model zoo.

Everything is pure-functional: ``init_*`` builds a param dict, ``apply``
style functions consume ``(params, x)``.  Block params are stacked along a
leading macro dimension by the model builders and applied under
``lax.scan`` (see transformer.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if len(shape) >= 3:  # (d, H, hd) style fused projections
        fan_in = shape[0]
    s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * s).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"w": ones((d,), dtype)}
    return {"w": ones((d,), dtype), "b": zeros((d,), dtype)}


def apply_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "b" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["w"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_heads(w, x, eps: float = 1e-6):
    """Per-head RMSNorm over the trailing head_dim (qk_norm)."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rope_pct: float, theta: float):
    """Inverse frequencies for the rotated slice of the head dim."""
    rot = int(head_dim * rope_pct)
    rot -= rot % 2
    if rot == 0:
        return None, 0
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return jnp.asarray(inv, jnp.float32), rot


def apply_rope(x, positions, inv_freq, rot: int):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if inv_freq is None or rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (...,S,rot/2)
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated or 2-matrix)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype, glu: bool = True, bias: bool = False):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, ff), dtype),
         "w_down": dense_init(ks[1], (ff, d), dtype)}
    if glu:
        p["w_gate"] = dense_init(ks[2], (d, ff), dtype)
    if bias:
        p["b_up"] = zeros((ff,), dtype)
        p["b_down"] = zeros((d,), dtype)
    return p


def apply_mlp(p, x, act: str = "silu"):
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = x @ p["w_up"]
    if "b_up" in p:
        h = h + p["b_up"]
    if "w_gate" in p:
        h = a(x @ p["w_gate"]) * h
    else:
        h = a(h)
    out = h @ p["w_down"]
    if "b_down" in p:
        out = out + p["b_down"]
    return out


# ---------------------------------------------------------------------------
# attention projections
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype, cross: bool = False):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), dtype),
        "wk": dense_init(ks[1], (d, Hkv, hd), dtype),
        "wv": dense_init(ks[2], (d, Hkv, hd), dtype),
        "wo": dense_init(ks[3], (H, hd, d), dtype, scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((H, hd), dtype)
        p["bk"] = zeros((Hkv, hd), dtype)
        p["bv"] = zeros((Hkv, hd), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = ones((hd,), dtype)
        p["k_norm"] = ones((hd,), dtype)
    return p


def qkv_project(p, x, cfg, positions, rope):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,Hkv,hd), rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = rms_norm_heads(p["q_norm"], q)
        k = rms_norm_heads(p["k_norm"], k)
    inv_freq, rot = rope
    q = apply_rope(q, positions, inv_freq, rot)
    k = apply_rope(k, positions, inv_freq, rot)
    return q, k, v


def out_project(p, o):
    """o (B,S,H,hd) -> (B,S,d)."""
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if "bo" in p:
        out = out + p["bo"]
    return out


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype, max_position: int = 0):
    p = {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}
    if max_position:
        p["pos"] = (jax.random.normal(
            jax.random.fold_in(key, 1), (max_position, d)) * 0.02).astype(dtype)
    return p


def embed_tokens(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


# Optional logits sharding constraint, set by launch code before tracing.
# GSPMD sometimes drops the vocab sharding on the logits -> a full f32
# logits all-gather (observed 388 GB for internvl2); an explicit
# with_sharding_constraint pins it.  None (default: CPU tests) is a no-op.
_LOGITS_PSPEC = None


def set_logits_partition(spec) -> None:
    global _LOGITS_PSPEC
    _LOGITS_PSPEC = spec


def _constrain_logits(h):
    if _LOGITS_PSPEC is not None:
        h = jax.lax.with_sharding_constraint(h, _LOGITS_PSPEC)
    return h


def logits_head(params, x, tie: bool):
    if tie:
        return _constrain_logits(x @ params["embed"]["table"].T)
    h = x @ params["head"]["w"]
    if "b" in params["head"]:
        h = h + params["head"]["b"]
    return _constrain_logits(h)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Mean token cross-entropy; logits (B,S,V), labels (B,S) int.

    The label logit is picked with an iota==label masked reduce instead of
    take_along_axis: a gather across the vocab dim would force GSPMD to
    all-gather the vocab-sharded logits; the masked reduce stays local and
    psums a scalar per token.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                 axis=-1)
    nll = logz - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
