"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free RNN LM.

Data-dependent per-channel decay via a LoRA on the shifted input (the
paper's headline mechanism) drives the WKV state recurrence implemented
by ``linear_scan.chunked_linear_scan`` (Pallas TPU variant:
``kernels/rwkv6_scan``).  Token-shift interpolation uses static per-
projection mu vectors (RWKV-5 style; the full DDLerp LoRA on all five
projections is orthogonal to the recurrence and omitted — DESIGN.md §7).

Decode state is O(1) in sequence length: per layer the last input token
(for the shifts) plus the (H, dk, dv) WKV state — this is why rwkv6-3b
is a long_500k architecture.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import layers as L
from .linear_scan import chunked_linear_scan, linear_scan_decode

DECAY_LORA = 64


def _init_block(cfg, key, dtype):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ff = cfg.d_ff
    ks = jax.random.split(key, 10)
    s = 1.0 / math.sqrt(d)
    wkv = {
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "wr": (jax.random.normal(ks[0], (d, h, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, h, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, h, hd)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[3], (d, h, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[4], (h, hd, d)) *
               (1.0 / math.sqrt(h * hd))).astype(dtype),
        # data-dependent decay: log_w = -exp(base + tanh(x w1) w2)
        "decay_base": jnp.zeros((h, hd), dtype),
        "decay_w1": (jax.random.normal(ks[5], (d, DECAY_LORA)) * s).astype(dtype),
        "decay_w2": (jax.random.normal(ks[6], (DECAY_LORA, h, hd)) *
                     (1.0 / math.sqrt(DECAY_LORA))).astype(dtype),
        "u": jnp.zeros((h, hd), dtype),
        "ln_w": jnp.ones((h, hd), dtype),     # per-head groupnorm on wkv out
        "ln_b": jnp.zeros((h, hd), dtype),
    }
    cmix = {
        "mu_k": jnp.full((d,), 0.5, dtype), "mu_r": jnp.full((d,), 0.5, dtype),
        "wr": (jax.random.normal(ks[7], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[8], (d, ff)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[9], (ff, d)) *
               (1.0 / math.sqrt(ff))).astype(dtype),
    }
    return {"ln1": L.init_norm(cfg.norm, d, dtype), "wkv": wkv,
            "ln2": L.init_norm(cfg.norm, d, dtype), "cmix": cmix}


def init_params(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = {"sub0": jax.vmap(lambda k: _init_block(cfg, k, dtype))(keys)}
    params = {
        "embed": L.init_embed(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab),
                                            dtype)}
    return params


def _head_groupnorm(p, o, eps=64e-5):
    of = o.astype(jnp.float32)
    mu = of.mean(-1, keepdims=True)
    var = ((of - mu) ** 2).mean(-1, keepdims=True)
    y = (of - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["ln_w"].astype(jnp.float32) +
            p["ln_b"].astype(jnp.float32)).astype(o.dtype)


def _shift(x, x_last=None):
    """x (B,S,d) -> previous token per position (zeros / carry at t=0)."""
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_last is not None:
        prev = prev.at[:, 0].set(x_last)
    return prev


def _time_mix_seq(cfg, p, x, state0=None, x_last=None, chunk=16):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    prev = _shift(x, x_last)
    def lerp(mu):
        return x + (prev - x) * mu
    r = jnp.einsum("bsd,dhk->bshk", lerp(p["mu_r"]), p["wr"])
    k = jnp.einsum("bsd,dhk->bshk", lerp(p["mu_k"]), p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", lerp(p["mu_v"]), p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", lerp(p["mu_g"]), p["wg"]))
    lora = jnp.tanh(lerp(p["mu_w"]) @ p["decay_w1"])
    log_w = -jnp.exp(p["decay_base"].astype(jnp.float32) +
                     jnp.einsum("bsl,lhk->bshk", lora,
                                p["decay_w2"]).astype(jnp.float32))
    o, state = chunked_linear_scan(r, k, v, log_w, decay_on="k",
                                   bonus=p["u"], state0=state0, chunk=chunk)
    o = _head_groupnorm(p, o) * g
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), state


def _channel_mix(p, x, x_last=None):
    prev = _shift(x, x_last)
    xk = x + (prev - x) * p["mu_k"]
    xr = x + (prev - x) * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])


def forward(cfg, params, tokens, *, chunk: int = 16, remat: bool = False,
            unroll: bool = False, **_):
    x = L.embed_tokens(params["embed"], tokens)

    def body(x, blk):
        p = blk["sub0"]
        h = L.apply_norm(p["ln1"], x)
        tm, _ = _time_mix_seq(cfg, p["wkv"], h, chunk=chunk)
        x = x + tm
        h = L.apply_norm(p["ln2"], x)
        x = x + _channel_mix(p["cmix"], h)
        return x, jnp.zeros((), jnp.float32)

    if remat:
        body = jax.checkpoint(body)
    x, aux = jax.lax.scan(body, x, params["blocks"],
                          unroll=cfg.n_layers if unroll else 1)
    x = L.apply_norm(params["final_norm"], x)
    return L.logits_head(params, x, cfg.tie_embeddings), aux.sum(), None


def loss_fn(cfg, params, batch, **kw):
    logits, aux, _ = forward(cfg, params, batch["tokens"],
                             chunk=kw.get("chunk", 16),
                             remat=kw.get("remat", False),
                             unroll=kw.get("unroll", False))
    loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# O(1) decode state
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int = 0, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    nm, d, h, hd = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.head_dim
    sub = {
        "x_tmix": jnp.zeros((nm, batch_size, d), dtype),
        "x_cmix": jnp.zeros((nm, batch_size, d), dtype),
        "wkv": jnp.zeros((nm, batch_size, h, hd, hd), jnp.float32),
    }
    return {"step": jnp.zeros((), jnp.int32), "subs": {"sub0": sub}}


def prefill(cfg, params, tokens, *, max_len: int = 0, chunk: int = 16,
            last_only: bool = False, unroll: bool = False, **_):
    x = L.embed_tokens(params["embed"], tokens)

    def body(x, blk):
        p = blk["sub0"]
        h = L.apply_norm(p["ln1"], x)
        tm, state = _time_mix_seq(cfg, p["wkv"], h, chunk=chunk)
        x_tmix = h[:, -1]
        x = x + tm
        h2 = L.apply_norm(p["ln2"], x)
        x = x + _channel_mix(p["cmix"], h2)
        return x, {"x_tmix": x_tmix, "x_cmix": h2[:, -1], "wkv": state}

    x, sub = jax.lax.scan(body, x, params["blocks"],
                          unroll=cfg.n_layers if unroll else 1)
    if last_only:
        x = x[:, -1:]
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, cfg.tie_embeddings)
    cache = {"step": jnp.asarray(tokens.shape[1], jnp.int32),
             "subs": {"sub0": sub}}
    return logits, cache


def init_paged_cache(cfg, n_slots: int, n_pages: int = 0,
                     page_size: int = 0, dtype=None):
    """Serving-engine state pool: the RWKV decode state is constant-size
    per sequence, so its 'pages' are slot rows — one implicit page per
    slot, page table the identity.  Admit/evict are row writes."""
    cache = init_cache(cfg, n_slots, dtype=dtype)
    return {"state": cache["subs"]}


def commit_prefill(cfg, paged, cache, slots, page_tables=None, *,
                   page_size: int = 0):
    """Write a prefill group's states into the admitted slot rows.
    ``slots`` (g,) int32."""
    sub = cache["subs"]["sub0"]
    st = paged["state"]["sub0"]
    new = {k: st[k].at[:, slots].set(sub[k].astype(st[k].dtype))
           for k in st}
    return {"state": {"sub0": new}}


def decode_step_paged(cfg, params, paged, token, steps=None,
                      page_tables=None, *, page_size: int = 0,
                      unroll: bool = False):
    """Continuous-batching decode step: identical math to ``decode_step``
    (the recurrence never reads the step counter), state slot-major."""
    x, subs = _decode_core(cfg, params, paged["state"], token,
                           unroll=unroll)
    logits = L.logits_head(params, x[:, None], cfg.tie_embeddings)
    return logits, {"state": subs}


def _decode_core(cfg, params, subs, token, *, unroll: bool = False):
    x = L.embed_tokens(params["embed"], token)[:, 0]     # (B,d)

    def body(x, xs):
        blk, c = xs
        p = blk["sub0"]
        cc = c["sub0"]
        h = L.apply_norm(p["ln1"], x)
        w = p["wkv"]
        prev = cc["x_tmix"]
        def lerp(mu):
            return h + (prev - h) * mu
        r = jnp.einsum("bd,dhk->bhk", lerp(w["mu_r"]), w["wr"])
        k = jnp.einsum("bd,dhk->bhk", lerp(w["mu_k"]), w["wk"])
        v = jnp.einsum("bd,dhk->bhk", lerp(w["mu_v"]), w["wv"])
        g = jax.nn.silu(jnp.einsum("bd,dhk->bhk", lerp(w["mu_g"]), w["wg"]))
        lora = jnp.tanh(lerp(w["mu_w"]) @ w["decay_w1"])
        log_w = -jnp.exp(w["decay_base"].astype(jnp.float32) +
                         jnp.einsum("bl,lhk->bhk", lora,
                                    w["decay_w2"]).astype(jnp.float32))
        o, wkv = linear_scan_decode(r, k, v, log_w, cc["wkv"],
                                    decay_on="k", bonus=w["u"])
        o = _head_groupnorm(w, o) * g
        x = x + jnp.einsum("bhk,hkd->bd", o, w["wo"])
        h2 = L.apply_norm(p["ln2"], x)
        cm = p["cmix"]
        prev2 = cc["x_cmix"]
        xk = h2 + (prev2 - h2) * cm["mu_k"]
        xr = h2 + (prev2 - h2) * cm["mu_r"]
        kk = jnp.square(jax.nn.relu(xk @ cm["wk"]))
        x = x + jax.nn.sigmoid(xr @ cm["wr"]) * (kk @ cm["wv"])
        return x, {"sub0": {"x_tmix": h, "x_cmix": h2, "wkv": wkv}}

    x, subs = jax.lax.scan(body, x, (params["blocks"], subs),
                           unroll=cfg.n_layers if unroll else 1)
    return L.apply_norm(params["final_norm"], x), subs


def decode_step(cfg, params, cache, token, *, unroll: bool = False):
    x, subs = _decode_core(cfg, params, cache["subs"], token, unroll=unroll)
    logits = L.logits_head(params, x[:, None], cfg.tie_embeddings)
    return logits, {"step": cache["step"] + 1, "subs": subs}
