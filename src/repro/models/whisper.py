"""Whisper-medium (arXiv:2212.04356) — encoder-decoder transformer.

The mel-spectrogram + conv1d frontend is STUBBED per the assignment
carve-out: ``batch["frames"]`` carries precomputed frame embeddings
(B, enc_seq, d_model).  Encoder: non-causal self-attention blocks over
the frames.  Decoder: causal self-attention + cross-attention + 2-matrix
GELU MLP, LayerNorm, learned absolute positions, tied embeddings.

Decode shapes beyond whisper's native 448-token decoder context clip the
learned-position lookup (shape-faithful to the assignment's mandated
input shapes; DESIGN.md §7).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import layers as L
from .attention import attend, cache_token_update, decode_attend


def _init_enc_block(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ln2": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, glu=cfg.glu,
                          bias=True),
    }


def _init_dec_block(cfg, key, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "lnx": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "xattn": L.init_attention(ks[1], cfg, dtype, cross=True),
        "ln2": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype, glu=cfg.glu,
                          bias=True),
    }


def init_params(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    k_embed, k_enc, k_dec, k_pos = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    params = {
        "embed": L.init_embed(k_embed, cfg.padded_vocab, cfg.d_model, dtype,
                              max_position=cfg.max_position),
        "enc_embed": {"pos": (jax.random.normal(k_pos, (cfg.enc_seq,
                                                        cfg.d_model))
                              * 0.02).astype(dtype)},
        "enc_blocks": {"sub0": jax.vmap(
            lambda k: _init_enc_block(cfg, k, dtype))(enc_keys)},
        "blocks": {"sub0": jax.vmap(
            lambda k: _init_dec_block(cfg, k, dtype))(dec_keys)},
        "enc_final_norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "final_norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    return params  # head is tied


def encode(cfg, params, frames, *, attn_impl="chunked", q_chunk=512,
           unroll: bool = False):
    x = frames + params["enc_embed"]["pos"][None, : frames.shape[1]]

    def body(x, blk):
        p = blk["sub0"]
        h = L.apply_norm(p["ln1"], x)
        q, k, v = L.qkv_project(p["attn"], h, cfg, positions=jnp.zeros(
            x.shape[:2], jnp.int32), rope=(None, 0))
        o = attend(q, k, v, impl=attn_impl, causal=False, q_chunk=q_chunk)
        x = x + L.out_project(p["attn"], o)
        h = L.apply_norm(p["ln2"], x)
        return x + L.apply_mlp(p["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=cfg.n_enc_layers if unroll else 1)
    return L.apply_norm(params["enc_final_norm"], x)


def _dec_positions(params, positions):
    table = params["embed"]["pos"]
    return jnp.take(table, jnp.clip(positions, 0, table.shape[0] - 1), axis=0)


def forward(cfg, params, tokens, *, frames, attn_impl="chunked",
            q_chunk=1024, remat: bool = False, unroll: bool = False, **_):
    enc = encode(cfg, params, frames, attn_impl=attn_impl, unroll=unroll)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = L.embed_tokens(params["embed"], tokens) + _dec_positions(
        params, positions)

    def body(x, blk):
        p = blk["sub0"]
        h = L.apply_norm(p["ln1"], x)
        q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope=(None, 0))
        o = attend(q, k, v, impl=attn_impl, causal=True, q_chunk=q_chunk)
        x = x + L.out_project(p["attn"], o)
        h = L.apply_norm(p["lnx"], x)
        q2, _, _ = L.qkv_project(p["xattn"], h, cfg,
                                 positions=positions, rope=(None, 0))
        ek = jnp.einsum("bsd,dhk->bshk", enc, p["xattn"]["wk"])
        ev = jnp.einsum("bsd,dhk->bshk", enc, p["xattn"]["wv"])
        o2 = attend(q2, ek, ev, impl=attn_impl, causal=False, q_chunk=q_chunk)
        x = x + L.out_project(p["xattn"], o2)
        h = L.apply_norm(p["ln2"], x)
        return x + L.apply_mlp(p["mlp"], h, cfg.act), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["blocks"],
                        unroll=cfg.n_layers if unroll else 1)
    x = L.apply_norm(params["final_norm"], x)
    return L.logits_head(params, x, tie=True), jnp.zeros((), jnp.float32), None


def loss_fn(cfg, params, batch, *, attn_impl="chunked", q_chunk=1024,
            remat: bool = False, unroll: bool = False, **_):
    logits, aux, _ = forward(cfg, params, batch["tokens"],
                             frames=batch["frames"], attn_impl=attn_impl,
                             q_chunk=q_chunk, remat=remat, unroll=unroll)
    loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    nm = cfg.n_layers
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    sub = {
        "k": jnp.zeros((nm, batch_size, max_len, hkv, hd), dtype),
        "v": jnp.zeros((nm, batch_size, max_len, hkv, hd), dtype),
        "xk": jnp.zeros((nm, batch_size, cfg.enc_seq, hkv, hd), dtype),
        "xv": jnp.zeros((nm, batch_size, cfg.enc_seq, hkv, hd), dtype),
    }
    return {"step": jnp.zeros((), jnp.int32), "subs": {"sub0": sub}}


def prefill(cfg, params, tokens, *, frames, max_len: int,
            attn_impl="chunked", q_chunk=1024, last_only: bool = False,
            unroll: bool = False, **_):
    """Encode + run the decoder prompt, building self- and cross-caches."""
    enc = encode(cfg, params, frames, attn_impl=attn_impl, unroll=unroll)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = L.embed_tokens(params["embed"], tokens) + _dec_positions(
        params, positions)

    def body(x, blk):
        p = blk["sub0"]
        h = L.apply_norm(p["ln1"], x)
        q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope=(None, 0))
        o = attend(q, k, v, impl=attn_impl, causal=True, q_chunk=q_chunk)
        x = x + L.out_project(p["attn"], o)
        h = L.apply_norm(p["lnx"], x)
        q2, _, _ = L.qkv_project(p["xattn"], h, cfg, positions, rope=(None, 0))
        ek = jnp.einsum("bsd,dhk->bshk", enc, p["xattn"]["wk"])
        ev = jnp.einsum("bsd,dhk->bshk", enc, p["xattn"]["wv"])
        o2 = attend(q2, ek, ev, impl=attn_impl, causal=False, q_chunk=q_chunk)
        x = x + L.out_project(p["xattn"], o2)
        h = L.apply_norm(p["ln2"], x)
        x = x + L.apply_mlp(p["mlp"], h, cfg.act)
        pad = max_len - s
        cache = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                 "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                 "xk": ek, "xv": ev}
        return x, cache

    x, sub = jax.lax.scan(body, x, params["blocks"],
                          unroll=cfg.n_layers if unroll else 1)
    if last_only:
        x = x[:, -1:]
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, tie=True)
    return logits, {"step": jnp.asarray(s, jnp.int32), "subs": {"sub0": sub}}


def decode_step(cfg, params, cache, token, *, unroll: bool = False):
    step = cache["step"]
    b = token.shape[0]
    positions = jnp.broadcast_to(step, (b, 1))
    x = L.embed_tokens(params["embed"], token) + _dec_positions(
        params, positions)

    def body(x, xs):
        blk, c = xs
        p = blk["sub0"]
        cc = c["sub0"]
        h = L.apply_norm(p["ln1"], x)
        q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope=(None, 0))
        kc = cache_token_update(cc["k"], k, step)
        vc = cache_token_update(cc["v"], v, step)
        o = decode_attend(q, kc, vc, jnp.broadcast_to(step + 1, (b,)))
        x = x + L.out_project(p["attn"], o)
        h = L.apply_norm(p["lnx"], x)
        q2, _, _ = L.qkv_project(p["xattn"], h, cfg, positions, rope=(None, 0))
        o2 = decode_attend(q2, cc["xk"], cc["xv"],
                           jnp.full((b,), cc["xk"].shape[1], jnp.int32))
        x = x + L.out_project(p["xattn"], o2)
        h = L.apply_norm(p["ln2"], x)
        x = x + L.apply_mlp(p["mlp"], h, cfg.act)
        return x, {"sub0": {"k": kc, "v": vc, "xk": cc["xk"], "xv": cc["xv"]}}

    x, subs = jax.lax.scan(body, x, (params["blocks"], cache["subs"]),
                           unroll=cfg.n_layers if unroll else 1)
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, tie=True)
    return logits, {"step": step + 1, "subs": subs}
