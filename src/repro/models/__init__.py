"""Model zoo — pure-functional JAX models, one API across families.

``get_model(cfg)`` dispatches on ``cfg.family``:
  dense | moe | vlm -> transformer (macro-block scan)
  ssm               -> rwkv6
  hybrid            -> hymba
  audio             -> whisper (enc-dec)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

from . import transformer, rwkv6, hymba, whisper
from . import layers, attention, linear_scan, moe, paper_models  # noqa: F401


class ModelApi(NamedTuple):
    init_params: Callable
    forward: Callable
    loss_fn: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    # paged serving contract (DESIGN.md §12) — None for families that do
    # not implement it (whisper enc-dec); the serving engine checks.
    init_paged_cache: Optional[Callable] = None
    commit_prefill: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None


_FAMILY = {
    "dense": transformer, "moe": transformer, "vlm": transformer,
    "ssm": rwkv6, "hybrid": hymba, "audio": whisper,
}


def get_model(cfg) -> ModelApi:
    mod = _FAMILY[cfg.family]
    prefill = getattr(mod, "prefill")
    paged = {}
    if hasattr(mod, "decode_step_paged"):
        paged = dict(
            init_paged_cache=lambda n_slots, n_pages, page_size, dtype=None:
                mod.init_paged_cache(cfg, n_slots, n_pages, page_size, dtype),
            commit_prefill=lambda paged_c, cache, slots, page_tables,
                page_size: mod.commit_prefill(
                    cfg, paged_c, cache, slots, page_tables,
                    page_size=page_size),
            decode_step_paged=lambda params, paged_c, token, steps,
                page_tables, page_size: mod.decode_step_paged(
                    cfg, params, paged_c, token, steps, page_tables,
                    page_size=page_size),
        )
    return ModelApi(
        init_params=lambda key, dtype=None: mod.init_params(cfg, key, dtype),
        forward=lambda params, tokens, **kw: mod.forward(
            cfg, params, tokens, **kw),
        loss_fn=lambda params, batch, **kw: mod.loss_fn(
            cfg, params, batch, **kw),
        init_cache=lambda batch_size, max_len, dtype=None: mod.init_cache(
            cfg, batch_size, max_len, dtype),
        prefill=lambda params, tokens, **kw: prefill(cfg, params, tokens, **kw),
        decode_step=lambda params, cache, token: mod.decode_step(
            cfg, params, cache, token),
        **paged,
    )
