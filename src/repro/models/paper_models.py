"""The paper's own three models, reproduced exactly.

* VGG16-CIFAR  (Table 1): 13 conv (+BN) + 1 dense = 14 trainable layers,
  **14,736,714 parameters exactly** (conv/dense weights+biases plus 4
  parameters per BN channel — keras counts the moving statistics).
* IMDB sentiment CNN-LSTM (Table 2): embedding(20000,128) -> conv1d(k5,
  f64) -> maxpool(4) -> LSTM(70) -> dense(2).
* CASA HAR LSTM: LSTM(100) + 4 dense + softmax(10) — 6 trainable layers,
  ~68.9k params (paper: 68,884).

These are the models the federated experiments (benchmarks/fig2, fig5,
table3/4/5) actually train; each conv/dense/LSTM layer is one freeze
unit, matching the paper's layer counting (the VGG16 BN belongs to its
conv's unit).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import layers as L

# (name, out_channels) per VGG16 stage; pools after each stage
VGG_STAGES: Tuple[Tuple[int, int], ...] = (
    (2, 64), (2, 128), (3, 256), (3, 512), (3, 512))


def _conv_init(key, cin, cout, dtype, k=3):
    s = 1.0 / math.sqrt(k * k * cin)
    return {
        "w": (jax.random.normal(key, (k, k, cin, cout)) * s).astype(dtype),
        "b": jnp.zeros((cout,), dtype),
        # BN: gamma, beta trainable; moving stats counted but frozen
        "bn_g": jnp.ones((cout,), dtype), "bn_b": jnp.zeros((cout,), dtype),
        "bn_mu": jnp.zeros((cout,), dtype), "bn_var": jnp.ones((cout,), dtype),
    }


def init_vgg16(key, num_classes: int = 10, dtype=jnp.float32,
               width_mult: float = 1.0):
    """width_mult=0.5 is the paper's Jetson-Nano 'lighter' variant."""
    params: Dict[str, Any] = {}
    cin = 3
    idx = 0
    keys = jax.random.split(key, 14)
    for n_convs, cout in VGG_STAGES:
        cout = max(8, int(cout * width_mult))
        for _ in range(n_convs):
            params[f"conv{idx}"] = _conv_init(keys[idx], cin, cout, dtype)
            cin = cout
            idx += 1
    params["dense0"] = {
        "w": (jax.random.normal(keys[13], (cin, num_classes)) *
              (1.0 / math.sqrt(cin))).astype(dtype),
        "b": jnp.zeros((num_classes,), dtype),
    }
    return params


def _bn(p, x, eps=1e-3):
    # batch-statistics BN (stateless): without live normalization the
    # 13-conv stack's activations collapse and nothing trains.  The
    # moving-stat leaves stay in the param tree for the paper-exact
    # 14,736,714 count (keras counts them) but are not consulted.
    mu = x.mean(axis=(0, 1, 2))
    var = x.var(axis=(0, 1, 2))
    inv = jax.lax.rsqrt(var + eps)
    return (x - mu) * inv * p["bn_g"] + p["bn_b"]


def vgg16_apply(params, images):
    """images (B, 32, 32, 3) -> logits (B, num_classes)."""
    x = images
    idx = 0
    for n_convs, _ in VGG_STAGES:
        for _ in range(n_convs):
            p = params[f"conv{idx}"]
            x = jax.lax.conv_general_dilated(
                x, p["w"], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(_bn(p, x + p["b"]))
            idx += 1
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.mean(axis=(1, 2))                          # global average pool
    p = params["dense0"]
    return x @ p["w"] + p["b"]


def vgg16_units(params) -> List[str]:
    """Freeze units in forward order: conv0..conv12, dense0 (14 units)."""
    return [k for k in sorted(params, key=_unit_order)]


def _unit_order(k: str) -> Tuple[int, int]:
    if k.startswith("conv"):
        return (0, int(k[4:]))
    return (1, 0)


# ---------------------------------------------------------------------------
# LSTM cell (shared by the IMDB and CASA models)
# ---------------------------------------------------------------------------

def _lstm_init(key, d_in, d_h, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "wx": (jax.random.normal(k1, (d_in, 4 * d_h)) *
               (1.0 / math.sqrt(d_in))).astype(dtype),
        "wh": (jax.random.normal(k2, (d_h, 4 * d_h)) *
               (1.0 / math.sqrt(d_h))).astype(dtype),
        "b": jnp.zeros((4 * d_h,), dtype),
    }


def lstm_apply(p, x):
    """x (B, S, d_in) -> last hidden state (B, d_h)."""
    d_h = p["wh"].shape[0]
    b = x.shape[0]

    def step(carry, xt):
        h, c = carry
        z = xt @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    init = (jnp.zeros((b, d_h), x.dtype), jnp.zeros((b, d_h), x.dtype))
    (h, _), _ = jax.lax.scan(step, init, x.swapaxes(0, 1))
    return h


# ---------------------------------------------------------------------------
# IMDB sentiment CNN-LSTM (Table 2)
# ---------------------------------------------------------------------------

IMDB_VOCAB, IMDB_MAXLEN, IMDB_EMBED = 20000, 100, 128


def init_imdb(key, dtype=jnp.float32, vocab: int = IMDB_VOCAB):
    ks = jax.random.split(key, 4)
    return {
        "embed_small": {"table": (jax.random.normal(ks[0], (vocab, IMDB_EMBED))
                                  * 0.05).astype(dtype)},
        "conv0": {"w": (jax.random.normal(ks[1], (5, IMDB_EMBED, 64)) *
                        (1.0 / math.sqrt(5 * IMDB_EMBED))).astype(dtype),
                  "b": jnp.zeros((64,), dtype)},
        "lstm0": _lstm_init(ks[2], 64, 70, dtype),
        "dense0": {"w": (jax.random.normal(ks[3], (70, 2)) *
                         (1.0 / math.sqrt(70))).astype(dtype),
                   "b": jnp.zeros((2,), dtype)},
    }


def imdb_apply(params, tokens):
    """tokens (B, 100) int -> logits (B, 2)."""
    x = jnp.take(params["embed_small"]["table"], tokens, axis=0)
    p = params["conv0"]
    x = jax.lax.conv_general_dilated(
        x, p["w"], (1,), "SAME", dimension_numbers=("NWC", "WIO", "NWC"))
    x = jax.nn.relu(x + p["b"])
    b, s, c = x.shape
    x = x[:, : (s // 4) * 4].reshape(b, s // 4, 4, c).max(axis=2)  # pool 4
    h = lstm_apply(params["lstm0"], x)
    p = params["dense0"]
    return h @ p["w"] + p["b"]


def imdb_units(params) -> List[str]:
    return ["embed_small", "conv0", "lstm0", "dense0"]


# ---------------------------------------------------------------------------
# CASA HAR LSTM (6 trainable layers, ~68.9k params)
# ---------------------------------------------------------------------------

CASA_FEATURES, CASA_SEQ, CASA_CLASSES = 36, 100, 10
_CASA_DENSE = (96, 32, 24, 16)


def init_casa(key, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {"lstm0": _lstm_init(ks[0], CASA_FEATURES, 100,
                                                  dtype)}
    d_in = 100
    for i, d in enumerate(_CASA_DENSE):
        params[f"dense{i}"] = {
            "w": (jax.random.normal(ks[i + 1], (d_in, d)) *
                  (1.0 / math.sqrt(d_in))).astype(dtype),
            "b": jnp.zeros((d,), dtype)}
        d_in = d
    params["dense4"] = {
        "w": (jax.random.normal(ks[5], (d_in, CASA_CLASSES)) *
              (1.0 / math.sqrt(d_in))).astype(dtype),
        "b": jnp.zeros((CASA_CLASSES,), dtype)}
    return params


def casa_apply(params, x):
    """x (B, 100, 36) float -> logits (B, 10)."""
    h = lstm_apply(params["lstm0"], x)
    for i in range(len(_CASA_DENSE)):
        p = params[f"dense{i}"]
        h = jax.nn.relu(h @ p["w"] + p["b"])
    p = params["dense4"]
    return h @ p["w"] + p["b"]


def casa_units(params) -> List[str]:
    return ["lstm0", "dense0", "dense1", "dense2", "dense3", "dense4"]


# ---------------------------------------------------------------------------
# classification loss / accuracy shared by the paper tasks
# ---------------------------------------------------------------------------

def xent_loss(logits, labels):
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             labels[:, None], axis=-1)[:, 0]
    return (logz - ll).mean()


def accuracy(logits, labels):
    return (logits.argmax(-1) == labels).mean()
