"""Chunked linear-recurrence (gated linear attention) substrate.

Shared by RWKV-6 (Finch) time-mix and the hymba SSM branch.  Both are
instances of the recurrence over a per-head state matrix ``S (dk, dv)``:

  k-decay (RWKV-6):  S_t = diag(w_t) S_{t-1} + k_t v_t^T
                     o_t = q_t^T S_{t-1} + (q_t . (u*k_t)) v_t
  v-decay (SSD/mamba-style):
                     S_t = S_{t-1} diag(w_t) + k_t v_t^T
                     o_t = q_t^T S_t

The chunked form processes ``chunk`` tokens with matmuls instead of a
per-token scan (MXU-friendly; this is the structure the Pallas
``rwkv6_scan`` kernel implements on TPU).

Numerical strategy: all decay work happens in log space, and BOTH sides
of the intra-chunk decay ratio exp(c_s - c_r) are normalized against the
chunk-final cumulative sum so every exponential argument is <= 0 (no
overflow).  Underflow only occurs when the *total* chunk decay passes
float32 range; we floor the per-token log-decay at ``LOG_DECAY_FLOOR``
(a token with log-decay -5 retains 0.7% after one step — below any
useful signal) and keep chunks short (16).  Documented in DESIGN.md.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

LOG_DECAY_FLOOR = -5.0


def _chunk(x, n, c):
    return x.reshape(x.shape[0], n, c, *x.shape[2:])


def chunked_linear_scan(q, k, v, log_decay, *, decay_on: str,
                        bonus: Optional[jnp.ndarray] = None,
                        state0: Optional[jnp.ndarray] = None,
                        chunk: int = 16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q,k (B,S,H,dk); v (B,S,H,dv); log_decay (B,S,H,dk|dv) (<=0).

    decay_on: "k" (RWKV) or "v" (mamba/SSD).  bonus: (H, dk) RWKV u-term
    (output includes current token via bonus; otherwise the v-decay
    variant includes the current token in the state first).
    Returns (outputs (B,S,H,dv), final_state (B,H,dk,dv)).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    while s % c:            # largest divisor <= chunk (odd prompt lengths)
        c -= 1
    n = s // c
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    ld = jnp.clip(log_decay.astype(f32), LOG_DECAY_FLOOR, 0.0)

    qc = _chunk(qf, n, c).swapaxes(0, 1)      # (n, B, c, H, dk)
    kc = _chunk(kf, n, c).swapaxes(0, 1)
    vc = _chunk(vf, n, c).swapaxes(0, 1)
    dc = _chunk(ld, n, c).swapaxes(0, 1)      # (n, B, c, H, ddim)

    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), f32)
    else:
        state0 = state0.astype(f32)

    causal_strict = jnp.tril(jnp.ones((c, c), f32), k=-1)
    causal_incl = jnp.tril(jnp.ones((c, c), f32))

    def body(state, xs):
        qb, kb, vb, db = xs                   # (B, c, H, ...)
        cum = jnp.cumsum(db, axis=1)          # c_r, r = 1..c
        total = cum[:, -1:, :, :]             # c_last
        if decay_on == "k":
            # q̂_s = q_s * exp(c_{s-1} - c_last); k̂_r = k_r * exp(c_last - c_r)
            cum_prev = cum - db               # c_{s-1}
            qh = qb * jnp.exp(cum_prev - total)
            kh = kb * jnp.exp(total - cum)
            att = jnp.einsum("bshi,brhi->bhsr", qh, kh)
            att = att * causal_strict[None, None]
            intra = jnp.einsum("bhsr,brhj->bshj", att, vb)
            if bonus is not None:
                diag = jnp.einsum("bshi,bshi->bsh",
                                  qb, bonus.astype(f32)[None, None] * kb)
                intra = intra + diag[..., None] * vb
            inter = jnp.einsum("bshi,bhij->bshj", qb * jnp.exp(cum_prev), state)
            out = inter + intra
            # S_c = diag(exp(c_last)) S_0 + sum_r diag(exp(c_last-c_r)) k_r v_r^T
            new_state = jnp.exp(total[:, 0, :, :, None]) * state + \
                jnp.einsum("brhi,brhj->bhij", kh, vb)
        elif decay_on == "v":
            # o_s = exp(c_s) * (q_s S_0) + exp(c_s - c_last)*... see module doc
            att = jnp.einsum("bshi,brhi->bhsr", qb, kb)
            att = att * causal_incl[None, None]
            vh = vb * jnp.exp(total - cum)          # v_r * exp(c_last - c_r)
            qs_decay = jnp.exp(cum - total)         # exp(c_s - c_last)
            intra = jnp.einsum("bhsr,brhj->bshj", att, vh) * qs_decay
            inter = jnp.einsum("bshi,bhij->bshj", qb, state) * jnp.exp(cum)
            out = inter + intra
            new_state = state * jnp.exp(total[:, 0, :, None, :]) + \
                jnp.einsum("brhi,brhj->bhij", kb, vh)
        else:
            raise ValueError(decay_on)
        return new_state, out

    state, outs = jax.lax.scan(body, state0, (qc, kc, vc, dc))
    outs = outs.swapaxes(0, 1).reshape(b, s, h, dv)
    return outs.astype(q.dtype), state


def linear_scan_decode(q, k, v, log_decay, state, *, decay_on: str,
                       bonus: Optional[jnp.ndarray] = None):
    """Single-token step.  q,k (B,H,dk), v (B,H,dv), log_decay (B,H,ddim),
    state (B,H,dk,dv) -> (out (B,H,dv), new_state)."""
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    ld = jnp.clip(log_decay.astype(f32), LOG_DECAY_FLOOR, 0.0)
    kv = jnp.einsum("bhi,bhj->bhij", kf, vf)
    if decay_on == "k":
        out = jnp.einsum("bhi,bhij->bhj", qf, state)
        if bonus is not None:
            out = out + jnp.einsum("bhi,bhi->bh", qf,
                                   bonus.astype(f32)[None] * kf)[..., None] * vf
        new_state = jnp.exp(ld)[..., None] * state + kv
    elif decay_on == "v":
        new_state = state * jnp.exp(ld)[:, :, None, :] + kv
        out = jnp.einsum("bhi,bhij->bhj", qf, new_state)
    else:
        raise ValueError(decay_on)
    return out.astype(q.dtype), new_state


def reference_linear_scan(q, k, v, log_decay, *, decay_on: str,
                          bonus=None, state0=None):
    """Per-token oracle (slow, exact) used by tests against the chunked form."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    state = jnp.zeros((b, h, dk, dv), jnp.float32) if state0 is None \
        else state0.astype(jnp.float32)
    ld = jnp.clip(log_decay.astype(jnp.float32), LOG_DECAY_FLOOR, 0.0)

    def step(state, xs):
        qt, kt, vt, dt = xs                   # (B,H,*)
        out, state = linear_scan_decode(qt, kt, vt, dt, state,
                                        decay_on=decay_on, bonus=bonus)
        return state, out

    xs = tuple(x.swapaxes(0, 1) for x in
               (q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), ld))
    state, outs = jax.lax.scan(step, state, xs)
    return outs.swapaxes(0, 1).astype(q.dtype), state
