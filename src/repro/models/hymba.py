"""Hymba-1.5B (arXiv:2411.13676) — hybrid parallel attention + SSM heads.

Every block runs a GQA attention branch and a Mamba-style SSM branch **in
parallel** on the same normed input; branch outputs are RMS-normed and
averaged with learnable per-branch scales (the paper's head-fusion).
Most layers use sliding-window attention; every ``global_every``-th layer
is global (paper layout).  The SSM branch uses the SSD (Mamba-2 style)
scalar-per-head data-dependent decay so it shares the chunked linear-scan
substrate with RWKV-6 (simplification vs Mamba-1's per-channel A —
DESIGN.md §7); meta-tokens and cross-layer KV sharing are omitted.

long_500k eligibility: SSM state is O(1); attention caches are O(window)
ring buffers except the 4 global layers (full cache) — sub-quadratic
overall.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import layers as L
from ..kernels.flash_decode.ops import paged_decode_attention
from .attention import (attend, cache_token_update, decode_attend,
                        decode_attend_ring, paged_token_update)
from .linear_scan import chunked_linear_scan, linear_scan_decode
from .transformer import SubSpec, block_layout, n_macro, cache_alloc, \
    _cache_from_prefill


def ssm_dims(cfg):
    h = cfg.n_heads
    d_inner = cfg.ssm.expand * cfg.d_model
    p = d_inner // h
    return h, p, cfg.ssm.state_dim, cfg.ssm.conv_width


def _init_ssm(cfg, key, dtype):
    h, p, n, w = ssm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "w_in": (jax.random.normal(ks[0], (d, h, p)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (h, p, w)) *
                   (1.0 / math.sqrt(w))).astype(dtype),
        "w_b": (jax.random.normal(ks[2], (h, p, n)) *
                (1.0 / math.sqrt(p))).astype(dtype),
        "w_c": (jax.random.normal(ks[3], (h, p, n)) *
                (1.0 / math.sqrt(p))).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (h, p)) *
                 (1.0 / math.sqrt(p))).astype(dtype),
        "dt_bias": jnp.full((h,), -2.0, dtype),
        "a_log": jnp.zeros((h,), dtype),          # A = -exp(a_log)
        "d_skip": jnp.ones((h, p), dtype) * 0.1,
        "w_out": (jax.random.normal(ks[5], (h, p, d)) *
                  (1.0 / math.sqrt(h * p))).astype(dtype),
    }


def _init_block(cfg, key, spec: SubSpec, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": L.init_norm(cfg.norm, d, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ssm": _init_ssm(cfg, ks[1], dtype),
        "attn_norm": L.init_norm("rmsnorm", d, dtype),
        "ssm_norm": L.init_norm("rmsnorm", d, dtype),
        "ln2": L.init_norm(cfg.norm, d, dtype),
        "mlp": L.init_mlp(ks[2], d, cfg.d_ff, dtype, glu=cfg.glu),
    }


def init_params(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    layout = block_layout(cfg)
    nm = n_macro(cfg)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    blocks = {}
    for si, spec in enumerate(layout):
        keys = jax.random.split(jax.random.fold_in(k_blocks, si), nm)
        blocks[f"sub{si}"] = jax.vmap(
            lambda k: _init_block(cfg, k, spec, dtype))(keys)
    params = {
        "embed": L.init_embed(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab),
                                            dtype)}
    return params


def _causal_conv(u, conv_w, conv_state=None):
    """Depthwise causal conv.  u (B,S,H,P), conv_w (H,P,W)."""
    w = conv_w.shape[-1]
    if conv_state is None:
        up = jnp.pad(u, ((0, 0), (w - 1, 0), (0, 0), (0, 0)))
    else:  # decode: conv_state (B, W-1, H, P) holds the previous inputs
        up = jnp.concatenate([conv_state, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * conv_w[None, None, :, :, i]
              for i in range(w))
    return jax.nn.silu(out), up[:, -(w - 1):]


def _ssm_branch_seq(cfg, p, x, conv_state=None, ssm_state=None, chunk=16):
    h, pp, n, w = ssm_dims(cfg)
    u = jnp.einsum("bsd,dhp->bshp", x, p["w_in"])
    u, new_conv = _causal_conv(u, p["conv_w"], conv_state)
    bb = jnp.einsum("bshp,hpn->bshn", u, p["w_b"])
    cc = jnp.einsum("bshp,hpn->bshn", u, p["w_c"])
    dt = jax.nn.softplus(jnp.einsum("bshp,hp->bsh", u, p["w_dt"]) +
                         p["dt_bias"].astype(jnp.float32))
    log_decay = (-jnp.exp(p["a_log"].astype(jnp.float32)) * dt)  # (B,S,H)
    v = u * dt[..., None].astype(u.dtype)
    ld = jnp.broadcast_to(log_decay[..., None], v.shape)
    y, state = chunked_linear_scan(cc, bb, v, ld, decay_on="v",
                                   state0=ssm_state, chunk=chunk)
    y = y + u * p["d_skip"][None, None]
    out = jnp.einsum("bshp,hpd->bsd", y, p["w_out"])
    return out, new_conv, state


def _apply_block(cfg, p, spec: SubSpec, x, positions, rope, attn_impl,
                 q_chunk, chunk=16):
    h = L.apply_norm(p["ln1"], x)
    # attention branch
    q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope)
    o = attend(q, k, v, impl=attn_impl, causal=True, window=spec.window,
               q_chunk=q_chunk)
    a_out = L.out_project(p["attn"], o)
    # ssm branch
    s_out, _, _ = _ssm_branch_seq(cfg, p["ssm"], h, chunk=chunk)
    fused = 0.5 * (L.apply_norm(p["attn_norm"], a_out) +
                   L.apply_norm(p["ssm_norm"], s_out))
    x = x + fused
    h2 = L.apply_norm(p["ln2"], x)
    return x + L.apply_mlp(p["mlp"], h2, cfg.act), (k, v)


def forward(cfg, params, tokens, *, attn_impl="chunked", q_chunk=1024,
            build_cache=False, cache_len=0, remat: bool = False,
            unroll: bool = False, **_):
    layout = block_layout(cfg)
    rope = L.rope_freqs(cfg.head_dim, cfg.rope_pct, cfg.rope_theta)
    x = L.embed_tokens(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, blk):
        cache_out = {}
        for si, spec in enumerate(layout):
            x, (k, v) = _apply_block(cfg, blk[f"sub{si}"], spec, x,
                                     positions, rope, attn_impl, q_chunk)
            if build_cache:
                cache_out[f"sub{si}"] = _cache_from_prefill(
                    spec, k, v, s, cache_len)
        return x, cache_out if build_cache else 0

    if remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["blocks"],
                             unroll=n_macro(cfg) if unroll else 1)
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, cfg.tie_embeddings)
    return logits, jnp.zeros((), jnp.float32), caches if build_cache else None


def loss_fn(cfg, params, batch, *, attn_impl="chunked", q_chunk=1024,
            remat: bool = False, unroll: bool = False, **_):
    logits, aux, _ = forward(cfg, params, batch["tokens"],
                             attn_impl=attn_impl, q_chunk=q_chunk,
                             remat=remat, unroll=unroll)
    loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss, "aux": aux}


def prefill(cfg, params, tokens, *, max_len: int, attn_impl="chunked",
            q_chunk=1024, chunk=16, last_only: bool = False,
            unroll: bool = False, **_):
    layout = block_layout(cfg)
    rope = L.rope_freqs(cfg.head_dim, cfg.rope_pct, cfg.rope_theta)
    x = L.embed_tokens(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, blk):
        cache_out = {}
        for si, spec in enumerate(layout):
            p = blk[f"sub{si}"]
            h = L.apply_norm(p["ln1"], x)
            q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope)
            o = attend(q, k, v, impl=attn_impl, causal=True,
                       window=spec.window, q_chunk=q_chunk)
            a_out = L.out_project(p["attn"], o)
            s_out, new_conv, new_ssm = _ssm_branch_seq(cfg, p["ssm"], h,
                                                       chunk=chunk)
            fused = 0.5 * (L.apply_norm(p["attn_norm"], a_out) +
                           L.apply_norm(p["ssm_norm"], s_out))
            x = x + fused
            h2 = L.apply_norm(p["ln2"], x)
            x = x + L.apply_mlp(p["mlp"], h2, cfg.act)
            slab = _cache_from_prefill(spec, k, v, s, max_len)
            slab["conv"] = new_conv
            slab["ssm"] = new_ssm
            cache_out[f"sub{si}"] = slab
        return x, cache_out

    x, subs = jax.lax.scan(body, x, params["blocks"],
                           unroll=n_macro(cfg) if unroll else 1)
    if last_only:
        x = x[:, -1:]
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, cfg.tie_embeddings)
    return logits, {"step": jnp.asarray(s, jnp.int32), "subs": subs}


# ---------------------------------------------------------------------------
# decode: ring/full KV per layout + O(1) conv & SSM state
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    layout = block_layout(cfg)
    nm = n_macro(cfg)
    h, p, n, w = ssm_dims(cfg)
    subs = {}
    for si, spec in enumerate(layout):
        a = cache_alloc(cfg, spec, max_len)
        subs[f"sub{si}"] = {
            "k": jnp.zeros((nm, batch_size, a, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((nm, batch_size, a, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "conv": jnp.zeros((nm, batch_size, w - 1, h, p), dtype),
            "ssm": jnp.zeros((nm, batch_size, h, n, p), jnp.float32),
        }
    return {"step": jnp.zeros((), jnp.int32), "subs": subs}


def init_paged_cache(cfg, n_slots: int, n_pages: int, page_size: int,
                     dtype=None):
    """Hybrid paging: attention KV lives in the shared page pool (ring
    pages for sliding-window layers, growing pages for the global ones);
    the O(1) conv and SSM states are slot rows — one implicit constant-
    size page per slot, like rwkv6."""
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    layout = block_layout(cfg)
    nm = n_macro(cfg)
    h, p, n, w = ssm_dims(cfg)
    shape = (nm, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    state = {}
    for si in range(len(layout)):
        state[f"sub{si}"] = {
            "conv": jnp.zeros((nm, n_slots, w - 1, h, p), dtype),
            "ssm": jnp.zeros((nm, n_slots, h, n, p), jnp.float32),
        }
    return {"pool": {"k": jnp.zeros(shape, dtype),
                     "v": jnp.zeros(shape, dtype)},
            "state": state}


def commit_prefill(cfg, paged, cache, slots, page_tables, *,
                   page_size: int):
    """KV slabs scatter into the admitted pages; conv/SSM states into the
    admitted slot rows."""
    layout = block_layout(cfg)
    k_pool, v_pool = paged["pool"]["k"], paged["pool"]["v"]
    ps = page_size
    state = {}
    for si in range(len(layout)):
        c = cache["subs"][f"sub{si}"]
        pt = page_tables[f"sub{si}"]
        nm, g, a, hkv, hd = c["k"].shape
        slab_k = c["k"].reshape(nm, g, a // ps, ps, hkv, hd)
        slab_v = c["v"].reshape(nm, g, a // ps, ps, hkv, hd)
        k_pool = k_pool.at[:, pt].set(slab_k.astype(k_pool.dtype))
        v_pool = v_pool.at[:, pt].set(slab_v.astype(v_pool.dtype))
        st = paged["state"][f"sub{si}"]
        state[f"sub{si}"] = {
            k: st[k].at[:, slots].set(c[k].astype(st[k].dtype))
            for k in st}
    return {"pool": {"k": k_pool, "v": v_pool}, "state": state}


def decode_step_paged(cfg, params, paged, token, steps, page_tables, *,
                      page_size: int, unroll: bool = False):
    """Continuous-batching decode step; mirrors ``decode_step`` op-for-op
    with paged KV addressing and per-slot step counters (traced — the
    engine admits/evicts without recompiling)."""
    layout = block_layout(cfg)
    rope = L.rope_freqs(cfg.head_dim, cfg.rope_pct, cfg.rope_theta)
    x = L.embed_tokens(params["embed"], token)            # (B,1,d)
    b = x.shape[0]
    positions = steps[:, None]
    ps = page_size

    def body(carry, xs):
        x = carry
        blk, (pool_m, st_m) = xs
        kp, vp = pool_m["k"], pool_m["v"]
        new_st = {}
        for si, spec in enumerate(layout):
            p = blk[f"sub{si}"]
            c = st_m[f"sub{si}"]
            pt = page_tables[f"sub{si}"]
            a = pt.shape[1] * ps
            h = L.apply_norm(p["ln1"], x)
            q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope)
            if spec.window > 0:
                pos = steps % a
                valid = jnp.minimum(steps + 1, a)
            else:
                pos = steps
                valid = steps + 1
            page = jnp.take_along_axis(pt, (pos // ps)[:, None], 1)[:, 0]
            kp = paged_token_update(kp, k, page, pos % ps)
            vp = paged_token_update(vp, v, page, pos % ps)
            o = paged_decode_attention(q, kp, vp, pt, valid)
            a_out = L.out_project(p["attn"], o)
            s_seq, new_conv, new_ssm = _ssm_branch_seq(
                cfg, p["ssm"], h, conv_state=c["conv"], ssm_state=c["ssm"],
                chunk=1)
            fused = 0.5 * (L.apply_norm(p["attn_norm"], a_out) +
                           L.apply_norm(p["ssm_norm"], s_seq))
            x = x + fused
            h2 = L.apply_norm(p["ln2"], x)
            x = x + L.apply_mlp(p["mlp"], h2, cfg.act)
            new_st[f"sub{si}"] = {"conv": new_conv, "ssm": new_ssm}
        return x, ({"k": kp, "v": vp}, new_st)

    x, (pool, state) = jax.lax.scan(
        body, x, (params["blocks"], (paged["pool"], paged["state"])),
        unroll=n_macro(cfg) if unroll else 1)
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, cfg.tie_embeddings)
    return logits, {"pool": pool, "state": state}


def decode_step(cfg, params, cache, token, *, unroll: bool = False):
    layout = block_layout(cfg)
    rope = L.rope_freqs(cfg.head_dim, cfg.rope_pct, cfg.rope_theta)
    step = cache["step"]
    x = L.embed_tokens(params["embed"], token)            # (B,1,d)
    b = x.shape[0]
    positions = jnp.broadcast_to(step, (b, 1))

    def body(x, xs):
        blk, csubs = xs
        new_subs = {}
        for si, spec in enumerate(layout):
            p = blk[f"sub{si}"]
            c = csubs[f"sub{si}"]
            h = L.apply_norm(p["ln1"], x)
            q, k, v = L.qkv_project(p["attn"], h, cfg, positions, rope)
            a = c["k"].shape[1]
            if spec.window > 0:
                slot = step % a
                kc = cache_token_update(c["k"], k, slot)
                vc = cache_token_update(c["v"], v, slot)
                o = decode_attend_ring(q, kc, vc,
                                       jnp.broadcast_to(step + 1, (b,)),
                                       window=a)
            else:
                kc = cache_token_update(c["k"], k, step)
                vc = cache_token_update(c["v"], v, step)
                o = decode_attend(q, kc, vc, jnp.broadcast_to(step + 1, (b,)))
            a_out = L.out_project(p["attn"], o)
            s_seq, new_conv, new_ssm = _ssm_branch_seq(
                cfg, p["ssm"], h, conv_state=c["conv"], ssm_state=c["ssm"],
                chunk=1)
            fused = 0.5 * (L.apply_norm(p["attn_norm"], a_out) +
                           L.apply_norm(p["ssm_norm"], s_seq))
            x = x + fused
            h2 = L.apply_norm(p["ln2"], x)
            x = x + L.apply_mlp(p["mlp"], h2, cfg.act)
            new_subs[f"sub{si}"] = {"k": kc, "v": vc, "conv": new_conv,
                                    "ssm": new_ssm}
        return x, new_subs

    x, subs = jax.lax.scan(body, x, (params["blocks"], cache["subs"]),
                           unroll=n_macro(cfg) if unroll else 1)
    x = L.apply_norm(params["final_norm"], x)
    logits = L.logits_head(params, x, cfg.tie_embeddings)
    return logits, {"step": step + 1, "subs": subs}
