"""Token-choice top-k MoE with capacity-bounded scatter dispatch.

Dispatch avoids the O(T·E·C) one-hot dispatch tensor: token copies are
scattered into an (E·C, d) expert buffer by flat slot id
(= expert·C + rank-within-expert), processed with per-expert batched
matmuls (MXU-friendly (E, C, d) x (E, d, ff)), and gathered back.  Over-
capacity copies fall into a discard row.  Experts shard over the
``model`` mesh axis (expert parallelism); tokens over ``data`` — the
scatter is the all-to-all boundary GSPMD materializes.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L


def capacity_for(num_tokens: int, num_experts: int, top_k: int,
                 capacity_factor: float = 1.25) -> int:
    c = math.ceil(num_tokens * top_k / num_experts * capacity_factor)
    return max(4 * math.ceil(c / 4), top_k)


def init_moe(key, d: int, mcfg, dtype):
    e, ff = mcfg.num_experts, mcfg.expert_d_ff
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * 0.02).astype(dtype),
        "w_gate": (jax.random.normal(ks[1], (e, d, ff)) * s).astype(dtype),
        "w_up":   (jax.random.normal(ks[2], (e, d, ff)) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, ff, d)) *
                   (1.0 / math.sqrt(ff))).astype(dtype),
    }
    return p


def apply_moe(p, x, mcfg, *, act: str = "silu",
              capacity_factor=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = mcfg.num_experts, mcfg.top_k
    t = b * s
    if capacity_factor is None:
        capacity_factor = getattr(mcfg, "capacity_factor", 1.25)
    cap = capacity_for(t, e, k, capacity_factor)
    xf = x.reshape(t, d)

    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                     # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (switch-style): E * sum_e f_e * P_e
    onehot_tok = jax.nn.one_hot(topi, e, dtype=jnp.float32)  # (T, k, E)
    f_e = onehot_tok.sum((0, 1)) / (t * k)
    p_e = probs.mean(0)
    aux = mcfg.load_balance_coef * e * jnp.sum(f_e * p_e)

    flat_e = topi.reshape(-1)                                # (T*k,)
    flat_w = topw.reshape(-1).astype(x.dtype)
    tok = jnp.repeat(jnp.arange(t), k)

    # rank-within-expert WITHOUT the (T·k, E) one-hot cumsum (537 GB for
    # llama4's 1M tokens x 128 experts): sort assignments by expert, rank
    # = position minus run start, unsort.  O(T·k log) time, O(T·k) memory.
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(n)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    rank_sorted = idx - run_start
    my_rank = jnp.zeros((n,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = my_rank < cap
    slot = jnp.where(keep, flat_e * cap + my_rank, e * cap)  # discard row

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xf[tok])
    eb = buf[: e * cap].reshape(e, cap, d)

    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = jnp.einsum("ecd,edf->ecf", eb, p["w_up"])
    gate = jnp.einsum("ecd,edf->ecf", eb, p["w_gate"])
    h = a(gate) * up
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E, C, d)

    flat_out = jnp.concatenate(
        [out_e.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], 0)
    y_tok = flat_out[slot] * (flat_w * keep.astype(x.dtype))[:, None]
    y = jax.ops.segment_sum(y_tok, tok, num_segments=t)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# TP-local dispatch under shard_map (beyond-paper perf variant)
#
# GSPMD lowers the scatter dispatch above into full token all-gathers
# (observed: 1.7 TB/device/step for granite train_4k).  The explicit
# schedule exploits that activations are replicated across the model
# axis under TP: each model rank already HOLDS every token of its data
# group, so it simply masks the assignments routed to its own expert
# shard, runs them, and the per-token combine is ONE psum over the model
# axis — the same collective an ordinary TP MLP pays.  No token data
# ever moves for dispatch.
# ---------------------------------------------------------------------------

def _rank_within(keys, n_keys):
    """rank of each element among equal keys (sort-based, O(n) memory)."""
    n = keys.shape[0]
    order = jnp.argsort(keys, stable=True)
    sorted_k = keys[order]
    idx = jnp.arange(n)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_k[1:] != sorted_k[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    return jnp.zeros((n,), jnp.int32).at[order].set(
        (idx - run_start).astype(jnp.int32))


def apply_moe_tp_local(p, x, mcfg, *, act: str = "silu",
                       capacity_factor=None,
                       axis_name: str = "model", data_axes=(),
                       data_shards: int = 1):
    """Runs INSIDE shard_map.  x (B_loc, S, d) replicated over axis_name;
    p['w_*'] (E_loc, d, ff) = this rank's expert shard; p['router'] (d, E)
    replicated.  Returns (y (B_loc,S,d) [psum-combined], aux scalar).

    ``data_shards`` is the static size of ``data_axes``: the per-expert
    capacity must be budgeted from the GLOBAL token count so a data
    shard never drops a token the unsharded reference keeps.  The cost
    is that dispatch buffers scale with the global (not local) batch —
    deliberate: equivalence with ``apply_moe`` over memory; pass an
    explicit ``capacity_factor`` to trade back."""
    b, s, d = x.shape
    e = mcfg.num_experts
    k = mcfg.top_k
    e_loc = p["w_up"].shape[0]
    t = b * s
    if capacity_factor is None:
        capacity_factor = getattr(mcfg, "capacity_factor", 1.25)
    cap = capacity_for(t * data_shards, e, k, capacity_factor)
    xf = x.reshape(t, d)

    logits = (xf @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    onehot_tok = jax.nn.one_hot(topi, e, dtype=jnp.float32)
    f_e = onehot_tok.sum((0, 1)) / (t * k)
    p_e = probs.mean(0)
    if data_axes:   # x is the local token shard: use GLOBAL f_e and p_e
        f_e = jax.lax.pmean(f_e, data_axes)
        p_e = jax.lax.pmean(p_e, data_axes)
    aux = mcfg.load_balance_coef * e * jnp.sum(f_e * p_e)

    m = jax.lax.axis_index(axis_name)
    base = m * e_loc
    flat_e = topi.reshape(-1)
    flat_w = topw.reshape(-1).astype(x.dtype)
    tok = jnp.repeat(jnp.arange(t), k)
    local_e = flat_e - base
    is_local = (local_e >= 0) & (local_e < e_loc)
    rank = _rank_within(jnp.where(is_local, local_e, e_loc), e_loc + 1)
    keep = is_local & (rank < cap)
    slot = jnp.where(keep, local_e * cap + rank, e_loc * cap)

    buf = jnp.zeros((e_loc * cap + 1, d), x.dtype).at[slot].set(
        jnp.where(keep[:, None], xf[tok], 0))
    eb = buf[: e_loc * cap].reshape(e_loc, cap, d)
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = a(jnp.einsum("ecd,edf->ecf", eb, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", eb, p["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    flat_out = jnp.concatenate(
        [out_e.reshape(e_loc * cap, d), jnp.zeros((1, d), x.dtype)], 0)
    y_tok = flat_out[slot] * (flat_w * keep.astype(x.dtype))[:, None]
    y = jax.ops.segment_sum(y_tok, tok, num_segments=t)
    y = jax.lax.psum(y, axis_name)
    return y.reshape(b, s, d), aux


def apply_moe_sharded(p, x, mcfg, *, act: str = "silu", mesh,
                      capacity_factor=None):
    """shard_map wrapper: expert-parallel dispatch over the 'model' axis,
    tokens stay put.  Falls back to apply_moe when mesh is None."""
    import functools
    from jax.sharding import PartitionSpec as P

    if mesh is None:
        return apply_moe(p, x, mcfg, act=act,
                         capacity_factor=capacity_factor)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    x_spec = P(dp if x.shape[0] % np.prod(
        [mesh.shape[a] for a in dp]) == 0 else None, None, None)
    p_specs = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    sharded_tokens = x_spec[0] is not None
    fn = functools.partial(apply_moe_tp_local, mcfg=mcfg, act=act,
                           capacity_factor=capacity_factor,
                           axis_name="model",
                           data_axes=dp if sharded_tokens else (),
                           data_shards=int(np.prod(
                               [mesh.shape[a] for a in dp]))
                           if sharded_tokens else 1)
    try:                                    # jax >= 0.6 top-level API
        _shard_map = jax.shard_map
        extra = {"check_vma": False}
    except AttributeError:                  # jax 0.4.x
        from jax.experimental.shard_map import shard_map as _shard_map
        extra = {"check_rep": False}
    mapped = _shard_map(
        lambda pp, xx: fn(pp, xx),
        mesh=mesh, in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()), **extra)
    return mapped(p, x)


def moe_param_count(d: int, mcfg) -> int:
    e, ff = mcfg.num_experts, mcfg.expert_d_ff
    return d * e + 3 * e * d * ff


def moe_active_param_count(d: int, mcfg) -> int:
    """Params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
    ff = mcfg.expert_d_ff
    return d * mcfg.num_experts + 3 * mcfg.top_k * d * ff
