from .rules import (  # noqa: F401
    spec_for, params_specs, params_shardings, batch_spec, layout_for,
    validate_specs,
)
