"""Partition-spec rules: map parameter paths to PartitionSpecs.

Three intra-client layouts (DESIGN.md §5):

  * ``tp``        — tensor parallel over the ``model`` axis only; params
                    otherwise replicated.  Used for ≤3B archs (one client
                    per data-axis group).
  * ``fsdp_tp``   — tensor parallel over ``model`` + fully-sharded params
                    over ``data`` on a second dimension.  12–26B archs.
  * ``replicated``— everything replicated (CPU tests / tiny models).

The rule engine is path-pattern based: the FIRST matching rule wins.  A
rule maps a regex over the parameter path to a tuple of logical axis
names per tensor dimension; logical axes are then resolved to mesh axes
per layout.  Unmatched params are replicated (with a strict-mode check
used by tests to guarantee full coverage).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..common import pytree as pt

# ---------------------------------------------------------------------------
# logical axes
#   "embed"   : d_model dim            -> never sharded (activations flow)
#   "vocab"   : vocabulary dim         -> model axis (TP)
#   "heads"   : attention heads dim    -> model axis (TP)
#   "kv_heads": kv heads dim           -> model axis if divisible else None
#   "ff"      : mlp hidden dim         -> model axis (TP)
#   "expert"  : MoE expert dim         -> model axis (expert parallel)
#   "fsdp"    : dim to shard over data in fsdp_tp layout
#   None      : replicated dim
# ---------------------------------------------------------------------------

# (path regex, logical spec per dim). Dims beyond the spec are replicated.
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # --- stacked transformer blocks: leading dim is the layer/macro dim ---
    # attention projections (L, d_model, heads, head_dim) / (L, heads, head_dim, d_model)
    # "hd" is the fallback TP dim: it receives the model axis only when the
    # heads dim is not divisible (rwkv 40H, hymba 25H) or in the *_hd decode
    # layouts (kv-heads < mesh width; cache must be hd-sharded).
    (r".*/(attn|xattn)/wq$",      (None, "fsdp", "heads", "hd")),
    (r".*/(attn|xattn)/wk$",      (None, "fsdp", "kv_heads", "hd")),
    (r".*/(attn|xattn)/wv$",      (None, "fsdp", "kv_heads", "hd")),
    (r".*/(attn|xattn)/wo$",      (None, "heads", "hd", "fsdp")),
    (r".*/(attn|xattn)/(bq|q_norm)$", (None, "heads", "hd")),
    (r".*/(attn|xattn)/(bk|bv|k_norm)$", (None, "kv_heads", "hd")),
    (r".*/(attn|xattn)/bo$",      (None, None)),
    # mlp (L, d_model, d_ff) and (L, d_ff, d_model)
    (r".*/mlp/w(_gate|_up|1|3)$", (None, "fsdp", "ff")),
    (r".*/mlp/w(_down|2)$",       (None, "ff", "fsdp")),
    (r".*/mlp/b(1|3|_gate|_up)$", (None, "ff")),
    (r".*/mlp/b(2|_down)$",       (None, None)),
    # MoE experts (L, E, d_model, d_ff) / (L, E, d_ff, d_model); router (L, d_model, E)
    (r".*/moe/w(_gate|_up)$",  (None, "expert", "fsdp", "ff_inner")),
    (r".*/moe/w_down$",        (None, "expert", "ff_inner", "fsdp")),
    (r".*/moe/router$",        (None, "fsdp", None)),
    (r".*/shared/w(_gate|_up)$", (None, "fsdp", "ff")),
    (r".*/shared/w_down$",       (None, "ff", "fsdp")),
    # rwkv6 time-mix / channel-mix (L, H, dk, dv) and friends
    (r".*/wkv/(wr|wk|wv|wg)$", (None, "fsdp", "heads", "hd")),
    (r".*/wkv/wo$",            (None, "heads", "hd", "fsdp")),
    (r".*/wkv/(decay_w1)$",    (None, "fsdp", None)),
    (r".*/wkv/(decay_w2)$",    (None, None, "heads", None)),
    (r".*/wkv/(tmix_w1)$",     (None, "fsdp", None, None)),
    (r".*/wkv/(tmix_w2)$",     (None, None, None, "fsdp")),
    (r".*/wkv/(u|ln_w|ln_b)$", (None, "heads", None)),
    (r".*/wkv/(mu_.*)$",       (None, None)),
    (r".*/cmix/wk$",           (None, "fsdp", "ff")),
    (r".*/cmix/wv$",           (None, "ff", "fsdp")),
    (r".*/cmix/(mu_.*)$",      (None, None)),
    # mamba/ssm branch (hymba)
    (r".*/ssm/w_in$",          (None, "fsdp", "heads", None)),
    (r".*/ssm/w_out$",         (None, "heads", None, "fsdp")),
    (r".*/ssm/(w_dt|w_b|w_c)$", (None, "heads", None, None)),
    (r".*/ssm/(a_log|dt_bias|d_skip)$", (None, "heads", None)),
    (r".*/ssm/conv_w$",        (None, "heads", None, None)),
    # norms / scalars inside blocks
    (r".*/(ln1|ln2|ln0|norm|pre_norm|post_norm|attn_norm|ssm_norm)/(w|b|scale|bias)$",
     (None, None)),
    # --- top-level ---
    (r"^embed/table$",     ("vocab", None)),
    (r"^embed/pos$",       (None, None)),
    (r"^head/w$",          (None, "vocab")),
    (r"^head/b$",          ("vocab",)),
    (r"^final_norm/(w|b)$", (None,)),
    # encoder stacks (whisper) reuse block rules via .*
    (r"^enc_embed/.*$",    (None, None)),
    # vlm projector
    (r"^projector/w$",     (None, "fsdp")),
    (r"^projector/b$",     (None,)),
    # --- paper models (VGG16 / LSTM / CNN): replicated (they are tiny) ---
    (r"^(conv|dense|lstm|embed_small).*$", ()),
)

_LOGICAL_TO_MESH = {
    "tp": {
        "vocab": "model", "heads": "model", "kv_heads": "model",
        "hd": "model", "ff": "model", "expert": "model", "ff_inner": None,
        "fsdp": None,
    },
    "fsdp_tp": {
        "vocab": "model", "heads": "model", "kv_heads": "model",
        "hd": "model", "ff": "model", "expert": "model", "ff_inner": None,
        "fsdp": "data",
    },
    # decode layouts for archs whose kv-head count does not divide the
    # model axis: attention TP moves from heads to head_dim so q and the
    # hd-sharded KV cache line up with zero resharding.
    "tp_hd": {
        "vocab": "model", "heads": None, "kv_heads": None, "hd": "model",
        "ff": "model", "expert": "model", "ff_inner": None, "fsdp": None,
    },
    "fsdp_tp_hd": {
        "vocab": "model", "heads": None, "kv_heads": None, "hd": "model",
        "ff": "model", "expert": "model", "ff_inner": None, "fsdp": "data",
    },
    # pure data/fsdp variant (beyond-paper perf iteration for small archs:
    # no TP activation all-reduces; params fully sharded over BOTH axes).
    "fsdp_only": {
        "vocab": None, "heads": None, "kv_heads": None, "hd": None,
        "ff": None, "expert": "model", "ff_inner": None,
        "fsdp": ("data", "model"),
    },
    "replicated": {k: None for k in
                   ("vocab", "heads", "kv_heads", "hd", "ff", "expert",
                    "ff_inner", "fsdp")},
}


def _divides(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return n % size == 0


def spec_for(path: str, shape: Sequence[int], layout: str, mesh: Mesh,
             extra_leading: Tuple[Optional[str], ...] = ()) -> P:
    """Resolve the PartitionSpec for one param.

    ``extra_leading`` prepends mesh axes (e.g. ("client",) when params
    carry a leading client dim in the FL round step).
    """
    table = _LOGICAL_TO_MESH[layout]
    for pat, logical in _RULES:
        if re.match(pat, path):
            axes: list = list(extra_leading)
            used = {a for a in extra_leading if a}
            # logical spec is aligned to the trailing dims when the param
            # has MORE dims than the rule (unstacked variant drops the
            # leading layer dim).
            spec = list(logical)
            nd = len(shape) - len(extra_leading)
            if len(spec) > nd:
                spec = spec[len(spec) - nd:]
            while len(spec) < nd:
                spec.append(None)
            for dim, logical_ax in zip(shape[len(extra_leading):], spec):
                mesh_ax = table.get(logical_ax) if logical_ax else None
                ok = mesh_ax is not None
                if ok:
                    parts = (mesh_ax,) if isinstance(mesh_ax, str) \
                        else tuple(mesh_ax)
                    ok = all(a in mesh.shape and a not in used
                             for a in parts) and \
                        _divides(dim, mesh, mesh_ax) and \
                        dim >= max(mesh.shape[a] for a in parts)
                if ok:
                    axes.append(mesh_ax)
                    used.update(parts)
                else:
                    axes.append(None)
            return P(*axes)
    return P(*extra_leading) if extra_leading else P()


def params_specs(params: pt.PyTree, layout: str, mesh: Mesh,
                 extra_leading: Tuple[Optional[str], ...] = ()) -> pt.PyTree:
    """PartitionSpec tree matching ``params`` (works on ShapeDtypeStructs)."""
    return pt.tree_map_with_path(
        lambda p, x: spec_for(p, x.shape, layout, mesh, extra_leading), params)


def params_shardings(params, layout, mesh, extra_leading=()):
    specs = params_specs(params, layout, mesh, extra_leading)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def batch_spec(mesh: Mesh, client_axis: bool = False) -> P:
    """Token batches shard over the data axis (and client axis in FL)."""
    lead = ("client",) if client_axis else ()
    data_ax = "data" if "data" in mesh.shape else None
    return P(*lead, data_ax)


def layout_for(cfg) -> str:
    """Pick the intra-client layout by model scale (DESIGN.md §5)."""
    if cfg.fl_clients_single_pod <= 4:
        return "fsdp_tp"
    return "tp"


def validate_specs(params, specs, mesh) -> list:
    """Return a list of (path, shape, spec) divisibility violations."""
    bad = []
    for (p, x), s in zip(pt.flatten_with_paths(params),
                         jax.tree_util.tree_leaves(specs)):
        for dim, ax in zip(x.shape, tuple(s) + (None,) * len(x.shape)):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % size != 0:
                bad.append((p, x.shape, s))
                break
    return bad
