"""Paged KV cache: fixed-size pages, per-sequence page tables, free list.

The serving engine replaces the dense per-sequence ``max_len`` ring-buffer
caches with a single physical **page pool** shared by every decode slot.
A page holds ``page_size`` consecutive cache positions of one sub-layer
stack (across all ``n_macro`` layers at once, matching the models'
stacked-block cache layout).  Each slot maps logical page j to a physical
page through its **page table**; pages are allocated on demand as a
sequence grows and returned to the free list on eviction — decode memory
is bounded by the pool, not by ``n_slots × max_len`` (the serving-side
analogue of the packed slot buffers that bound training memory,
DESIGN.md §7).

The abstraction covers all three cache species:

* attention KV (gemma3):  full-attention subs page a growing prefix;
  sliding-window subs page the ring allocation (ring slot = pos % window
  — page-aligned, so ``window % page_size == 0`` is required);
* constant-size SSM state (rwkv6): one implicit page per slot — slot
  rows, no table;
* hybrid (hymba): paged KV + slot-row conv/SSM states.

Physical page 0 is the reserved **trash page**: unallocated page-table
entries point at it, inactive slots write to it, and every read through
it is masked before the softmax — so admit/evict touch only host-side
numpy tables and the jitted decode step never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SubPaging:
    """Paging spec for one sub-layer stack's KV cache."""
    name: str            # "sub0", ...
    alloc: int           # logical token capacity A (ring: window; else max_len)
    ring: bool           # sliding-window ring semantics


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Family-aware paging plan: which subs page KV, page counts, state."""
    page_size: int
    max_len: int                     # rounded up to a page multiple
    subs: Tuple[SubPaging, ...]      # attention-bearing subs ((), for ssm)
    has_state: bool                  # slot-row states (ssm / hybrid)

    def sub_pages(self, sub: SubPaging) -> int:
        return sub.alloc // self.page_size

    @property
    def pages_per_seq(self) -> int:
        """Worst-case pages one sequence can hold (its full allocation)."""
        return sum(self.sub_pages(s) for s in self.subs)

    def prompt_pages(self, sub: SubPaging, prompt_len: int) -> int:
        """Pages a freshly-admitted prompt occupies in ``sub``."""
        covered = min(prompt_len, sub.alloc) if sub.ring else prompt_len
        return -(-covered // self.page_size)


def build_layout(cfg, page_size: int, max_len: int) -> PagedLayout:
    """Derive the paging plan from an architecture config.

    ``max_len`` is rounded up to a page multiple (the engine uses the
    rounded value as the dense prefill ``max_len`` too, so paged and
    dense allocations coincide and greedy decode is bitwise-equal).
    """
    if cfg.family in ("vlm", "audio"):
        raise ValueError(
            f"{cfg.name}: the serving engine does not cover the "
            f"{cfg.family} family (patch/frame frontends); use the static "
            f"loop")
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    max_len = -(-max_len // page_size) * page_size
    subs: List[SubPaging] = []
    if cfg.family != "ssm":
        from ..models.transformer import block_layout, cache_alloc
        for si, spec in enumerate(block_layout(cfg)):
            a = cache_alloc(cfg, spec, max_len)
            ring = spec.window > 0 and a == spec.window
            if a % page_size:
                raise ValueError(
                    f"{cfg.name} sub{si}: allocation {a} is not a multiple "
                    f"of page_size {page_size} (ring buffers must be "
                    f"page-aligned)")
            subs.append(SubPaging(name=f"sub{si}", alloc=a, ring=ring))
    return PagedLayout(page_size=page_size, max_len=max_len,
                       subs=tuple(subs),
                       has_state=cfg.family in ("ssm", "hybrid"))


class PageAllocator:
    """Free-list over the physical page pool.  Page 0 is reserved as the
    trash page and never handed out."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"pool needs >= 2 pages (1 is the trash "
                             f"page), got {n_pages}")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop n pages, or None (and take nothing) if the pool is dry."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.n_in_use)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"freeing out-of-range page {p}")
            self._free.append(p)


class PagedTables:
    """Host-side page tables: numpy mirrors of the traced decode args.

    One (n_slots, MP_sub) int32 table per attention sub; entry 0 means
    "unallocated → trash page".  The engine caches the device copies and
    re-pushes only when ``version`` moved (admit/grow/release); shapes
    are static so the jitted step never retraces on admit/evict.
    """

    def __init__(self, layout: PagedLayout, n_slots: int,
                 allocator: PageAllocator):
        self.layout = layout
        self.n_slots = n_slots
        self.allocator = allocator
        self.tables: Dict[str, np.ndarray] = {
            s.name: np.zeros((n_slots, layout.sub_pages(s)), np.int32)
            for s in layout.subs}
        self._held: List[List[int]] = [[] for _ in range(n_slots)]
        # bumped on every mutation so the engine can cache device copies
        self.version = 0

    def pages_held(self, slot: int) -> int:
        return len(self._held[slot])

    def admit(self, slot: int, prompt_len: int) -> bool:
        """Allocate the pages a prompt's cache occupies.  All-or-nothing:
        on a dry pool nothing is taken and False is returned."""
        need = [(s, self.layout.prompt_pages(s, prompt_len))
                for s in self.layout.subs]
        pages = self.allocator.alloc(sum(n for _, n in need))
        if pages is None:
            return False
        self._held[slot].extend(pages)
        it = iter(pages)
        for s, n in need:
            for j in range(n):
                self.tables[s.name][slot, j] = next(it)
        self.version += 1
        return True

    def grow(self, slot: int, step: int) -> bool:
        """Ensure the page holding write position ``step`` exists in every
        sub.  Returns False (allocating nothing further) on a dry pool."""
        ps = self.layout.page_size
        for s in self.layout.subs:
            pos = step % s.alloc if s.ring else step
            if pos >= s.alloc:
                raise ValueError(
                    f"slot {slot} step {step} exceeds {s.name} allocation "
                    f"{s.alloc} (max_len {self.layout.max_len})")
            j = pos // ps
            if self.tables[s.name][slot, j] == 0:
                got = self.allocator.alloc(1)
                if got is None:
                    return False
                self.tables[s.name][slot, j] = got[0]
                self._held[slot].append(got[0])
                self.version += 1
        return True

    def release(self, slot: int) -> None:
        """Evict: return the slot's pages and reset its tables to trash."""
        self.allocator.free(self._held[slot])
        self._held[slot] = []
        for s in self.layout.subs:
            self.tables[s.name][slot, :] = 0
        self.version += 1

    def device_tables(self):
        """jnp copies of the tables, keyed like the models expect."""
        import jax.numpy as jnp
        return {name: jnp.asarray(t) for name, t in self.tables.items()}

    def rows(self, slots: List[int]):
        """jnp table rows for an admitted group (commit_prefill arg)."""
        import jax.numpy as jnp
        return {name: jnp.asarray(t[slots]) for name, t in
                self.tables.items()}
