"""Continuous-batching scheduler: request lifecycle over decode slots.

Requests move WAITING → RUNNING → FINISHED.  The scheduler admits queued
requests into free decode slots mid-flight (FCFS; equal-prompt-length
runs admit as one batched prefill), evicts finished sequences (EOS /
max-gen) returning their pages to the pool, and **preempts** when the
page pool runs dry: the most recently admitted other sequence is
recompute-preempted (vLLM-style) — its pages are freed and it re-queues
at the front with its generated prefix folded into the prompt, so its
token stream continues exactly where it stopped (sampling keys are
per-(request, token-index), independent of batch composition).

All decisions are host-side numpy/list operations; the device only ever
sees fixed-shape traced arguments, so the engine's decode step compiles
once.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .paged_cache import PagedLayout, PagedTables

WAITING, RUNNING, FINISHED = "WAITING", "RUNNING", "FINISHED"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32 — the original prompt
    max_gen: int
    eos_id: int = -1                 # -1 = disabled
    state: str = WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    # recompute-preemption: token already sampled but not yet fed back
    resume_pending: Optional[int] = None
    n_preempt: int = 0
    # metrics (engine wall clock)
    t_submit: float = 0.0
    t_first_token: float = -1.0
    t_finish: float = -1.0

    @property
    def prefill_tokens(self) -> np.ndarray:
        """What to prefill on (re-)admission: the original prompt plus any
        generated prefix whose KV must be reconstructed.  The last
        generated token (if any) is still pending — it is fed to the
        first decode step, not prefetched into the cache."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated[:-1], np.int32)])

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_gen:
            return True
        return (self.eos_id >= 0 and len(self.generated) > 0
                and self.generated[-1] == self.eos_id)


@dataclasses.dataclass
class SlotInfo:
    rid: int
    step: int            # cache positions written so far
    admit_seq: int       # monotone admission counter (preemption order)


class Scheduler:
    """Owns the queue, the slot map, and the paged tables."""

    def __init__(self, layout: PagedLayout, tables: PagedTables,
                 n_slots: int):
        self.layout = layout
        self.tables = tables
        self.n_slots = n_slots
        self.queue: Deque[Request] = deque()
        self.requests: Dict[int, Request] = {}
        self.slots: List[Optional[SlotInfo]] = [None] * n_slots
        self.n_preemptions = 0
        self._admit_seq = 0

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_gen > self.layout.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_gen "
                f"{req.max_gen} exceeds max_len {self.layout.max_len}")
        worst = self.layout.pages_per_seq
        if worst > self.tables.allocator.n_pages - 1:
            raise ValueError(
                f"page pool ({self.tables.allocator.n_pages} pages) cannot "
                f"hold one full sequence ({worst} pages + trash page)")
        self.requests[req.rid] = req
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def running_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    # -- admission ----------------------------------------------------------

    def admit_group(self) -> List[Tuple[int, Request]]:
        """Admit the longest FCFS prefix of equal-prefill-length requests
        that fits the free slots and the page pool.  Returns
        [(slot, request)] — one batched prefill for the engine."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        group: List[Tuple[int, Request]] = []
        glen = -1
        while self.queue and free:
            req = self.queue[0]
            plen = len(req.prefill_tokens)
            if glen >= 0 and plen != glen:
                break
            slot = free[0]
            if not self.tables.admit(slot, plen):
                break                      # pool dry — decode drains first
            glen = plen
            free.pop(0)
            self.queue.popleft()
            req.state = RUNNING
            self.slots[slot] = SlotInfo(rid=req.rid, step=plen,
                                        admit_seq=self._admit_seq)
            self._admit_seq += 1
            group.append((slot, req))
        return group

    # -- growth & preemption ------------------------------------------------

    def ensure_growth(self) -> List[int]:
        """Before a decode step: make sure every running slot has a page
        for its next write position, preempting the most recently
        admitted *other* slot when the pool runs dry.  Returns the slots
        preempted this round."""
        preempted: List[int] = []
        for slot in sorted(self.running_slots(),
                           key=lambda i: self.slots[i].admit_seq):
            info = self.slots[slot]
            if info is None:             # preempted later in this loop
                continue
            while not self.tables.grow(slot, info.step):
                victim = self._pick_victim(exclude=slot)
                if victim is None:
                    raise RuntimeError(
                        f"page pool too small: slot {slot} cannot grow and "
                        f"no other sequence is preemptible")
                self.preempt(victim)
                preempted.append(victim)
        return preempted

    def _pick_victim(self, exclude: int) -> Optional[int]:
        running = [i for i in self.running_slots() if i != exclude]
        if not running:
            return None
        return max(running, key=lambda i: self.slots[i].admit_seq)

    def preempt(self, slot: int) -> None:
        info = self.slots[slot]
        req = self.requests[info.rid]
        self.tables.release(slot)
        self.slots[slot] = None
        req.state = WAITING
        req.n_preempt += 1
        self.n_preemptions += 1
        if req.generated:
            req.resume_pending = req.generated[-1]
        self.queue.appendleft(req)       # FCFS with progress preserved

    # -- eviction -----------------------------------------------------------

    def finish(self, slot: int, t_now: float) -> Request:
        info = self.slots[slot]
        req = self.requests[info.rid]
        self.tables.release(slot)
        self.slots[slot] = None
        req.state = FINISHED
        req.t_finish = t_now
        return req

    # -- decode-step views --------------------------------------------------

    def step_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
        """(tokens, steps, req_ids, gen_idx) — fixed (n_slots,) views of
        the running state; inactive slots carry zeros and write to the
        trash page."""
        tokens = np.zeros((self.n_slots,), np.int32)
        steps = np.zeros((self.n_slots,), np.int32)
        rids = np.zeros((self.n_slots,), np.int32)
        gidx = np.zeros((self.n_slots,), np.int32)
        for i, info in enumerate(self.slots):
            if info is None:
                continue
            req = self.requests[info.rid]
            tokens[i] = (req.resume_pending if req.resume_pending is not None
                         else req.generated[-1])
            steps[i] = info.step
            rids[i] = info.rid
            gidx[i] = len(req.generated)
        return tokens, steps, rids, gidx

    def advance(self, slot: int, token: int) -> None:
        """Record one decoded token for a running slot."""
        info = self.slots[slot]
        req = self.requests[info.rid]
        req.resume_pending = None
        req.generated.append(int(token))
        info.step += 1
