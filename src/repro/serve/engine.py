"""Continuous-batching decode engine over the paged cache (DESIGN.md §12).

The engine jits three device functions once each:

* **prefill** — the model's dense prefill (``last_only=True``) plus the
  first-token sample, per distinct prompt length (jax's shape cache);
* **commit**  — scatter of the dense prefill cache into the admitted
  sequences' pages (one entry per group size);
* **decode**  — one ``decode_step_paged`` + sample over the engine's
  static slot count.  Every dynamic quantity (token, per-slot steps,
  page tables, request ids, generation indices) is a fixed-shape traced
  argument, so admitting and evicting sequences mid-flight NEVER
  retraces the decode step (tests assert ``decode_cache_size == 1``).

Sampling keys are ``fold_in(fold_in(PRNGKey(seed), request_id),
token_index)`` — a function of the request and position only, never of
batch composition — so continuous batching reproduces the static loop's
token streams exactly, and a preempted-and-resumed request continues the
same stream.  ``static_generate`` is the fixed-batch reference loop with
the same sampling scheme (it also fixes the old launcher bug where the
first token was argmax'd even at temperature > 0).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.compileguard import CompileGuard
from ..models import get_model
from .paged_cache import PageAllocator, PagedTables, build_layout
from .scheduler import Request, Scheduler


def sample_tokens(logits, rids, gidx, *, temperature: float, seed: int):
    """logits (B, V) -> (B,) int32.  Greedy at temperature <= 0; otherwise
    categorical with a per-(request, token-index) key — independent of
    which other sequences share the batch."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    base = jax.random.PRNGKey(seed)

    def one(key_r, key_g, row):
        k = jax.random.fold_in(jax.random.fold_in(base, key_r), key_g)
        return jax.random.categorical(k, row / temperature)

    return jax.vmap(one)(rids, gidx, logits).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int
    max_len: int                 # rounded up to a page multiple internally
    page_size: int = 16
    n_pages: int = 0             # 0 = auto: no oversubscription + trash page
    temperature: float = 0.0
    seed: int = 0
    eos_id: int = -1             # -1 = disabled
    attn_impl: str = "reference"
    record_logits: bool = False  # keep per-request logits rows (tests)


class DecodeEngine:
    """Continuous-batching serving loop for one model."""

    def __init__(self, cfg, params, serve: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.model = get_model(cfg)
        if self.model.decode_step_paged is None:
            raise ValueError(f"{cfg.name}: family {cfg.family} has no paged "
                             f"decode contract")
        self.layout = build_layout(cfg, serve.page_size, serve.max_len)
        n_pages = serve.n_pages or (
            serve.n_slots * self.layout.pages_per_seq + 1)
        self.allocator = PageAllocator(max(n_pages, 2))
        self.tables = PagedTables(self.layout, serve.n_slots, self.allocator)
        self.scheduler = Scheduler(self.layout, self.tables, serve.n_slots)
        self.paged = self.model.init_paged_cache(
            serve.n_slots, self.allocator.n_pages, serve.page_size)

        model, lay, sv = self.model, self.layout, serve
        kw = {"attn_impl": sv.attn_impl} if cfg.family != "ssm" else {}

        def prefill_fn(params, tokens, rids, gidx):
            logits, cache = model.prefill(params, tokens,
                                          max_len=lay.max_len,
                                          last_only=True, **kw)
            row = logits[:, -1]
            tok = sample_tokens(row, rids, gidx,
                                temperature=sv.temperature, seed=sv.seed)
            return tok, row, cache

        def commit_fn(paged, cache, slots, rows):
            return model.commit_prefill(paged, cache, slots, rows,
                                        sv.page_size)

        def decode_fn(params, paged, token, steps, tables, rids, gidx):
            logits, paged = model.decode_step_paged(
                params, paged, token, steps, tables, sv.page_size)
            row = logits[:, -1]
            tok = sample_tokens(row, rids, gidx,
                                temperature=sv.temperature, seed=sv.seed)
            return tok, row, paged

        # the recompile-free contract, enforced rather than asserted:
        # decode owns exactly ONE program across admit/evict/preempt;
        # prefill/commit keep jax's documented shape caches (one
        # program per distinct prompt length / admission group size)
        self._prefill = CompileGuard(prefill_fn, name="serve_prefill",
                                     max_programs=None)
        self._commit = CompileGuard(commit_fn, name="serve_commit",
                                    max_programs=None)
        self._decode = CompileGuard(decode_fn, name="serve_decode",
                                    max_programs=1)

        self._next_rid = 0
        self.logits_rows: Dict[int, List[np.ndarray]] = {}
        self.n_decode_steps = 0
        self._tables_cache = None
        self._tables_version = -1

    # -- public API ---------------------------------------------------------

    def submit(self, prompt, max_gen: int, eos_id: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_gen=max_gen,
                      eos_id=self.serve.eos_id if eos_id is None else eos_id,
                      t_submit=time.perf_counter())
        self.scheduler.submit(req)
        if self.serve.record_logits:
            self.logits_rows[rid] = []
        return rid

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue; returns {rid: generated tokens (int32 array)}."""
        sched = self.scheduler
        while sched.has_work():
            admitted = self._admit_all()
            if not sched.running_slots():
                if sched.queue and not admitted:
                    raise RuntimeError("queue stalled: nothing running and "
                                       "nothing admissible")
                continue
            self._decode_one_step()
        return {rid: np.asarray(r.generated, np.int32)
                for rid, r in sched.requests.items()}

    def stats(self) -> Dict[str, Any]:
        reqs = [r for r in self.scheduler.requests.values()
                if r.t_finish >= 0]
        lat = np.asarray([r.t_finish - r.t_submit for r in reqs]) \
            if reqs else np.zeros((0,))
        total = sum(len(r.generated) for r in reqs)
        span = (max(r.t_finish for r in reqs) -
                min(r.t_submit for r in reqs)) if reqs else 0.0
        return {
            "n_requests": len(reqs),
            "total_tokens": int(total),
            "wall_s": float(span),
            "tokens_per_sec": float(total / span) if span > 0 else 0.0,
            "latency_p50_s": float(np.percentile(lat, 50)) if reqs else 0.0,
            "latency_p99_s": float(np.percentile(lat, 99)) if reqs else 0.0,
            "n_preemptions": self.scheduler.n_preemptions,
            "n_decode_steps": self.n_decode_steps,
            "peak_pages": self.allocator.peak_in_use,
            "n_pages": self.allocator.n_pages,
        }

    @property
    def decode_cache_size(self) -> int:
        """jit cache entries for the decode step (must stay 1 across
        admit/evict/preempt — the recompile-free contract)."""
        return self._decode.cache_size

    # -- internals ----------------------------------------------------------

    def _admit_all(self) -> bool:
        sched, admitted = self.scheduler, False
        while True:
            group = sched.admit_group()
            if not group:
                return admitted
            admitted = True
            slots = [s for s, _ in group]
            reqs = [r for _, r in group]
            toks = jnp.asarray(np.stack([r.prefill_tokens for r in reqs]))
            rids = jnp.asarray([r.rid for r in reqs], jnp.int32)
            gidx = jnp.asarray([len(r.generated) for r in reqs], jnp.int32)
            tok, row, cache = self._prefill(self.params, toks, rids, gidx)
            self.paged = self._commit(self.paged, cache,
                                      jnp.asarray(slots, jnp.int32),
                                      self.tables.rows(slots))
            tok_np = np.asarray(tok)
            row_np = np.asarray(row) if self.serve.record_logits else None
            now = time.perf_counter()
            for i, (slot, req) in enumerate(group):
                if req.resume_pending is not None:
                    continue   # token already sampled pre-preemption
                if req.t_first_token < 0:
                    req.t_first_token = now
                req.generated.append(int(tok_np[i]))
                if row_np is not None:
                    self.logits_rows[req.rid].append(row_np[i])
                if req.done:
                    sched.finish(slot, now)

    def _device_tables(self):
        if self._tables_version != self.tables.version:
            self._tables_cache = self.tables.device_tables()
            self._tables_version = self.tables.version
        return self._tables_cache

    def _micro_run_len(self) -> int:
        """How many decode steps can run back-to-back on the device before
        the host must intervene: until the earliest finish (a slot frees
        for admission) or page-boundary crossing (a slot needs a fresh
        page).  EOS must inspect every token, so it pins the run to 1."""
        sched, lay = self.scheduler, self.layout
        k = 1 << 30
        for slot in sched.running_slots():
            info = sched.slots[slot]
            req = sched.requests[info.rid]
            if req.eos_id >= 0:
                return 1
            k = min(k, req.max_gen - len(req.generated))
            for s in lay.subs:
                pos = info.step % s.alloc if s.ring else info.step
                k = min(k, lay.page_size - pos % lay.page_size)
        return max(1, k)

    def _decode_one_step(self) -> None:
        """One scheduling point: grow pages, then run a multi-step decode
        micro-run — K jitted steps chained device-to-device (the sampled
        token feeds the next step without leaving the device), one host
        sync at the end for the bookkeeping."""
        sched = self.scheduler
        sched.ensure_growth()
        running = sched.running_slots()
        tokens, steps, rids, gidx = sched.step_arrays()
        k = self._micro_run_len()
        tables = self._device_tables()
        rids_d = jnp.asarray(rids)
        tok_d = jnp.asarray(tokens[:, None])
        toks, rows = [], []
        for j in range(k):
            tok, row, self.paged = self._decode(
                self.params, self.paged, tok_d, jnp.asarray(steps + j),
                tables, rids_d, jnp.asarray(gidx + j))
            toks.append(tok)
            rows.append(row)
            tok_d = tok[:, None]
            self.n_decode_steps += 1
        tok_np = np.asarray(jnp.stack(toks))                 # (k, n_slots)
        row_np = (np.asarray(jnp.stack(rows))
                  if self.serve.record_logits else None)
        now = time.perf_counter()
        for j in range(k):
            for slot in running:
                if sched.slots[slot] is None:                # finished early
                    continue
                req = sched.requests[sched.slots[slot].rid]
                sched.advance(slot, tok_np[j, slot])
                if row_np is not None:
                    self.logits_rows[req.rid].append(row_np[j, slot])
                if req.done:
                    sched.finish(slot, now)


# ---------------------------------------------------------------------------
# static-batch reference loop
# ---------------------------------------------------------------------------

def static_generate(cfg, params, prompts, gen: int, *, max_len: int,
                    temperature: float = 0.0, seed: int = 0,
                    attn_impl: str = "reference", collect_logits: bool = False,
                    rids=None, extra=None):
    """Fixed-batch prefill + decode: the engine's oracle and the launcher's
    ``--engine static`` path.

    Every token — including the first — is sampled with the per-(request,
    token-index) key scheme, so runs are reproducible from ``seed`` and
    comparable stream-for-stream with the continuous engine when ``rids``
    matches the engine's request ids (default: 0..B-1 in batch order).

    Returns generated tokens (B, gen) int32, plus the per-step logits rows
    [(B, V)] * gen when ``collect_logits``.
    """
    model = get_model(cfg)
    b = prompts.shape[0]
    rids = (jnp.arange(b, dtype=jnp.int32) if rids is None
            else jnp.asarray(rids, jnp.int32))
    kw = {"attn_impl": attn_impl} if cfg.family != "ssm" else {}
    extra = extra or {}

    def prefill_fn(params, tokens, rids):
        logits, cache = model.prefill(params, tokens, max_len=max_len,
                                      last_only=True, **extra, **kw)
        row = logits[:, -1]
        tok = sample_tokens(row, rids, jnp.zeros((b,), jnp.int32),
                            temperature=temperature, seed=seed)
        return tok, row, cache

    def decode_fn(params, cache, token, rids, gidx):
        logits, cache = model.decode_step(params, cache, token)
        row = logits[:, -1]
        tok = sample_tokens(row, rids, gidx, temperature=temperature,
                            seed=seed)
        return tok, row, cache

    prefill_j = CompileGuard(prefill_fn, name="static_prefill",
                             max_programs=1)
    decode_j = CompileGuard(decode_fn, name="static_decode",
                            max_programs=1)

    tok, row, cache = prefill_j(params, prompts, rids)
    toks, rows = [tok], [row]
    for t in range(1, gen):
        tok, row, cache = decode_j(params, cache,
                                   tok[:, None].astype(jnp.int32), rids,
                                   jnp.full((b,), t, jnp.int32))
        toks.append(tok)
        rows.append(row)
    jax.block_until_ready(tok)
    out = np.stack([np.asarray(t) for t in toks], axis=1).astype(np.int32)
    if collect_logits:
        return out, [np.asarray(r) for r in rows]
    return out
