"""Serving engine: paged KV cache + continuous-batching scheduler +
recompile-free decode engine (DESIGN.md §12).

``paged_cache``  — page pool layout, free-list allocator, page tables
``scheduler``    — request lifecycle: admit / grow / evict / preempt
``engine``       — the jitted decode loop + the static-batch baseline
"""
from . import paged_cache, scheduler  # noqa: F401

# engine imports repro.models (which imports nothing from repro.serve);
# keep it a plain import too — ordering here is only documentation.
from . import engine  # noqa: F401
