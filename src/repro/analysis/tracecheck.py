"""Level-1 jaxpr contract checker (DESIGN.md §15).

The repo's core invariants — frozen-slot gradients are dead code
(PR 3), compiled paths never host-sync or retrace (PR 6), every random
draw descends from the seed stream (PR 8), dead buffers are donated
(this PR) — were guaranteed *dynamically*, by property tests that
execute the paths.  This module proves them *statically*: it traces
every registered compiled path via ``jax.make_jaxpr`` and walks the
jaxprs, so a violation is caught on every trace, for every
configuration traced here, without running a round.

Traced paths (the program registry, ``traced_programs()``):

* sync packed round step (``Server.round_step``),
* buffered-async select + flush (``build_cohort_step`` /
  ``Topology.build_buffered_flush``),
* cohort-engine select / chunk / finalize (``build_cohort_programs``),
* serve prefill + decode (``DecodeEngine``), traced under typed PRNG
  keys so key flow is visible in the jaxpr,
* one frozen-grad probe per round path: ``jax.grad`` of the shared
  ``packed_cohort_fn`` loss w.r.t. the *global* params.

Checkers (each also exposed as a pure ``check_*`` function over an
explicit jaxpr, which is how the intentionally-bad fixtures in
``tests/test_analysis.py`` prove every checker fires):

* ``trace-frozen-grad`` — every stacked leaf's global-params cotangent
  must be a scatter(-add) chain into a zeros base: only gathered slot
  rows receive gradient, so frozen rows are DCE-dead.  Removing the
  ``stop_gradient`` in ``local_update_packed`` adds a dense cotangent
  term to the base and the walker rejects it.
* ``trace-host-sync`` — no callback/infeed/debug primitives anywhere
  inside a compiled path (recursively, through pjit/scan/cond bodies).
* ``trace-key-flow`` — every consumed PRNG key descends from
  ``fold_in``/``split``; no key is consumed twice; no raw
  ``random_seed`` output is fed straight to ``random_bits``.
* ``trace-donation`` — paths that declare ``donate_argnums`` actually
  alias every donated leaf in the lowering (``tf.aliasing_output`` in
  the StableHLO), i.e. no silent copies.
* ``trace-compileguard`` — the live entry points are ``CompileGuard``
  instances with the contracted ``max_programs``/``donate_argnums``.
* ``trace-codec-frozen`` — the uplink codecs' decode(encode(.)) maps
  pad slots and non-participants to EXACT zeros (an adversarial
  all-ones payload goes in; any nonzero outside the valid mask would
  re-animate frozen units).  The qint8 sync round step is also traced
  whole (``trace:sync/round_step_qint8``), so the codec's stochastic-
  rounding draws ride the host-sync and key-flow walkers.
"""
from __future__ import annotations

import dataclasses
import functools
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.core as jcore
import jax.numpy as jnp
import numpy as np

from .compileguard import CompileGuard
from .findings import Finding, register_checker

__all__ = ["traced_programs", "TracedProgram",
           "check_host_sync_jaxpr", "check_key_flow_jaxpr",
           "check_frozen_grad_jaxpr", "check_donation_text",
           "check_guard_contract", "check_codec_pad_zeros"]


# ---------------------------------------------------------------------------
# jaxpr walking helpers

def _sub_closed(eqn) -> List[jcore.ClosedJaxpr]:
    """Every ClosedJaxpr nested in one equation's params (pjit body,
    scan body, cond branches, custom_vjp calls, ...)."""
    out = []
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else [v]
        for cj in vals:
            if isinstance(cj, jcore.ClosedJaxpr):
                out.append(cj)
            elif isinstance(cj, jcore.Jaxpr):
                out.append(jcore.ClosedJaxpr(cj, ()))
    return out


def _iter_eqns(jaxpr):
    """All equations, recursively through nested jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for cj in _sub_closed(eqn):
            yield from _iter_eqns(cj.jaxpr)


def _is_key(v) -> bool:
    dt = getattr(getattr(v, "aval", None), "dtype", None)
    return dt is not None and "key<" in str(dt)


def _is_zero_literal(v) -> bool:
    return isinstance(v, jcore.Literal) and np.all(np.asarray(v.val) == 0)


# ---------------------------------------------------------------------------
# checker cores (pure functions over explicit jaxprs — unit-testable on
# intentionally-bad fixtures)

_HOST_SYNC_EXACT = {"infeed", "outfeed", "debug_print"}
_HOST_SYNC_SUBSTR = ("callback",)     # pure_callback, io_callback, ...


def check_host_sync_jaxpr(name: str, closed: jcore.ClosedJaxpr,
                          allow: Sequence[str] = ()) -> List[Finding]:
    out = []
    seen = set()
    for eqn in _iter_eqns(closed.jaxpr):
        pn = eqn.primitive.name
        if pn in allow or pn in seen:
            continue
        if pn in _HOST_SYNC_EXACT or any(s in pn for s in _HOST_SYNC_SUBSTR):
            seen.add(pn)
            out.append(Finding(
                checker="", level="", anchor=name, symbol=pn,
                message=f"host-sync primitive {pn!r} inside compiled "
                        f"path {name!r} — callbacks serialize the device "
                        f"stream every step; move it out of the jit or "
                        f"allowlist it with a documented suppression"))
    return out


class _KeyState:
    __slots__ = ("origin", "consumed")

    def __init__(self, origin: str):
        self.origin = origin          # "input" | "seed" | "derived"
        self.consumed = 0


# prims that consume their key operand (using the same key again after
# one of these repeats the stream) vs. prims that derive fresh keys
# (fold_in is non-consuming derivation: fold_in(k, i) and fold_in(k, j)
# are independent streams by design)
_KEY_CONSUMING = {"random_bits", "random_split"}
_KEY_DERIVING = {"random_fold_in", "random_split", "random_wrap"}


def check_key_flow_jaxpr(name: str,
                         closed: jcore.ClosedJaxpr) -> List[Finding]:
    findings: List[Finding] = []
    env: Dict[int, _KeyState] = {}

    def node(env_, v) -> _KeyState:
        st = env_.get(id(v))
        if st is None:
            st = env_[id(v)] = _KeyState("input")
        return st

    def walk(jaxpr, env_):
        for eqn in jaxpr.eqns:
            pn = eqn.primitive.name
            if pn in _KEY_CONSUMING:
                for iv in eqn.invars:
                    if isinstance(iv, jcore.Literal) or not _is_key(iv):
                        continue
                    st = node(env_, iv)
                    st.consumed += 1
                    if st.consumed == 2:
                        findings.append(Finding(
                            checker="", level="", anchor=name,
                            symbol="key-reuse",
                            message=f"PRNG key consumed twice in "
                                    f"{name!r} (second consumer: {pn}) — "
                                    f"reusing a key repeats the stream; "
                                    f"split or fold_in a fresh key"))
                    if pn == "random_bits" and st.origin == "seed":
                        findings.append(Finding(
                            checker="", level="", anchor=name,
                            symbol="underived-key",
                            message=f"random_bits draws from a raw seed "
                                    f"key in {name!r} — every consumed "
                                    f"key must descend from fold_in/"
                                    f"split so streams are disjoint"))
            subs = _sub_closed(eqn)
            if subs:
                for cj in subs:
                    inner = cj.jaxpr
                    # positional 1:1 operand<->binder alignment: exact
                    # for pjit/scan (consts+carry+xs); cond binders
                    # align with operands after the branch index
                    if len(inner.invars) == len(eqn.invars):
                        ops = eqn.invars
                    elif len(inner.invars) == len(eqn.invars) - 1:
                        ops = eqn.invars[1:]
                    else:
                        ops = None
                    sub_env: Dict[int, _KeyState] = {}
                    if ops is not None:
                        for bv, ov in zip(inner.invars, ops):
                            if _is_key(bv) and \
                                    not isinstance(ov, jcore.Literal):
                                sub_env[id(bv)] = node(env_, ov)
                    walk(inner, sub_env)
                    for bv, ov in zip(inner.outvars, eqn.outvars):
                        if _is_key(ov):
                            st = sub_env.get(id(bv))
                            env_[id(ov)] = st if st is not None \
                                else _KeyState("derived")
                continue
            for ov in eqn.outvars:
                if not _is_key(ov):
                    continue
                if pn == "random_seed":
                    env_[id(ov)] = _KeyState("seed")
                elif pn in _KEY_DERIVING:
                    env_[id(ov)] = _KeyState("derived")
                else:
                    # shape/layout ops (broadcast, reshape, slice, ...)
                    # alias the key material: consuming the view and the
                    # original is still reuse
                    keys_in = [iv for iv in eqn.invars
                               if not isinstance(iv, jcore.Literal)
                               and _is_key(iv)]
                    env_[id(ov)] = node(env_, keys_in[0]) \
                        if len(keys_in) == 1 else _KeyState("derived")

    walk(closed.jaxpr, env)
    return findings


# cotangent producers that preserve "zeros outside the scattered slots"
_ZEROS_PASS = {"convert_element_type", "reshape", "transpose", "squeeze",
               "expand_dims", "copy", "rev", "stop_gradient",
               "broadcast_in_dim"}


def _zeros_scatter_chain(v, producers, depth: int = 0) -> bool:
    """True iff ``v`` provably carries non-zero values only on scattered
    slot rows: a chain of scatter(-add)s whose base bottoms out in a
    zeros literal/broadcast.  Any path that reaches a jaxpr input,
    constvar or an unrecognized producer is a dense contribution."""
    if depth > 64:
        return False
    if _is_zero_literal(v):
        return True
    if isinstance(v, jcore.Literal):
        return False
    e = producers.get(id(v))
    if e is None:
        return False                       # input/const: dense cotangent
    pn = e.primitive.name
    nonlit = [iv for iv in e.invars]
    if pn in _ZEROS_PASS:
        return _zeros_scatter_chain(nonlit[0], producers, depth + 1)
    if pn.startswith("scatter"):           # scatter, scatter-add, ...
        return _zeros_scatter_chain(nonlit[0], producers, depth + 1)
    if pn in ("add", "add_any", "sub", "concatenate"):
        return all(_zeros_scatter_chain(iv, producers, depth + 1)
                   for iv in nonlit)
    if pn == "mul":
        return any(_zeros_scatter_chain(iv, producers, depth + 1)
                   for iv in nonlit)
    if pn == "pad":
        return all(_zeros_scatter_chain(iv, producers, depth + 1)
                   for iv in nonlit[:2])   # operand + padding value
    if pn == "select_n":
        return all(_zeros_scatter_chain(iv, producers, depth + 1)
                   for iv in nonlit[1:])   # all selectable cases
    return False


def check_frozen_grad_jaxpr(name: str, closed: jcore.ClosedJaxpr,
                            stacked: Sequence[Tuple[int, str]]
                            ) -> List[Finding]:
    """``closed`` is the jaxpr of ``grad(loss)(global_params)``;
    ``stacked`` lists (flat output index, leaf path) of the stacked
    leaves whose frozen macro rows must be cotangent-free."""
    jaxpr = closed.jaxpr
    producers = {id(ov): e for e in jaxpr.eqns for ov in e.outvars}
    out = []
    for idx, path in stacked:
        v = jaxpr.outvars[idx]
        if not _zeros_scatter_chain(v, producers):
            out.append(Finding(
                checker="", level="", anchor=name, symbol=path,
                message=f"stacked leaf {path!r}: global-params cotangent "
                        f"in {name!r} is not a scatter-into-zeros chain — "
                        f"frozen rows receive gradient (is the "
                        f"stop_gradient on the merge base intact?)"))
    return out


def check_donation_text(name: str, lowered_text: str,
                        n_donated: int) -> List[Finding]:
    """``n_donated`` = array leaves in the donated arguments; every one
    must carry a ``tf.aliasing_output`` attribute in the lowering."""
    n = lowered_text.count("tf.aliasing_output")
    if n < n_donated:
        return [Finding(
            checker="", level="", anchor=name, symbol="donation",
            message=f"{name!r} declares donation but the lowering "
                    f"aliases only {n} of {n_donated} donated leaves — "
                    f"the rest are silent copies (shape/dtype mismatch "
                    f"between donated input and output?)")]
    return []


def check_codec_pad_zeros(name: str, transform, assign, params, fl,
                          n_slots: int) -> List[Finding]:
    """Frozen-slot invariant THROUGH the codec: feed an adversarial
    all-ones packed payload — pad slots and a non-participant client
    included — through the codec's decode(encode(.)) transform and
    demand exact zeros everywhere the slot plan says nothing shipped.
    Any leak would merge compression noise into units the round never
    trained, silently breaking the freeze contract the comm accounting
    (and the paper's Table 4 story) rests on."""
    from ..common import pytree as pt
    from ..core.masking import _is_leafunit, slot_plan
    c = fl.n_clients
    sel = np.zeros((c, assign.n_units), np.float32)
    sel[:, : max(1, assign.n_units // 2)] = 1.0
    sel[-1, :] = 0.0                      # a non-participant client
    rows, valid = jax.vmap(
        lambda s: slot_plan(assign, s, n_slots, params))(jnp.asarray(sel))
    flat, treedef = jax.tree_util.tree_flatten(params)
    leaves_lu = jax.tree_util.tree_leaves(assign.leaf_units,
                                          is_leaf=_is_leafunit)
    leaves_r = jax.tree_util.tree_leaves(rows)
    pdeltas = jax.tree_util.tree_unflatten(treedef, [
        jnp.ones(((c,) + tuple(leaf.shape)) if lu.kind == "scalar"
                 else ((c, r.shape[1]) + tuple(leaf.shape[1:])),
                 jnp.float32)
        for leaf, lu, r in zip(flat, leaves_lu, leaves_r)])
    w = jnp.ones((c,), jnp.float32)
    decoded, _ = transform(pdeltas, rows, valid, w, jax.random.PRNGKey(0))
    out = []
    paths = [p for p, _ in pt.flatten_with_paths(params)]
    for path, d, v in zip(paths, jax.tree_util.tree_leaves(decoded),
                          jax.tree_util.tree_leaves(valid)):
        vm = jnp.reshape(v, v.shape + (1,) * (d.ndim - v.ndim))
        leak = float(jnp.max(jnp.abs(d) * (1.0 - vm))) if d.size else 0.0
        if leak != 0.0:
            out.append(Finding(
                checker="", level="", anchor=name, symbol=path,
                message=f"codec {name!r}: decoded delta leaks {leak:g} "
                        f"into pad/non-participant slots of leaf "
                        f"{path!r} — decode(encode(.)) must multiply by "
                        f"the valid mask so frozen units stay EXACTLY "
                        f"untouched"))
    return out


def check_guard_contract(name: str, guard: Any,
                         max_programs: Optional[int],
                         donate: Tuple[int, ...]) -> List[Finding]:
    if not isinstance(guard, CompileGuard):
        return [Finding(
            checker="", level="", anchor=name, symbol="compileguard",
            message=f"{name!r} is not routed through CompileGuard "
                    f"(got {type(guard).__name__}) — the retrace budget "
                    f"is unenforced")]
    out = []
    if guard.max_programs != max_programs:
        out.append(Finding(
            checker="", level="", anchor=name, symbol="max-programs",
            message=f"{name!r} declares max_programs="
                    f"{guard.max_programs}, contract says "
                    f"{max_programs}"))
    if guard.donate_argnums != donate:
        out.append(Finding(
            checker="", level="", anchor=name, symbol="donate-argnums",
            message=f"{name!r} declares donate_argnums="
                    f"{guard.donate_argnums}, contract says {donate} — "
                    f"a dropped donation doubles the path's peak memory"))
    return out


# ---------------------------------------------------------------------------
# the traced-program registry

@dataclasses.dataclass
class TracedProgram:
    name: str                       # finding anchor, e.g. "trace:sync/..."
    closed: jcore.ClosedJaxpr
    check_keys: bool = True
    host_allow: Tuple[str, ...] = ()
    # donation: present iff the live path declares donate_argnums
    lowered_text: str = ""
    n_donated: int = 0


@dataclasses.dataclass
class _Registry:
    programs: List[TracedProgram]
    # grad probes: (name, closed, [(out index, leaf path)])
    grad_probes: List[Tuple[str, jcore.ClosedJaxpr,
                            List[Tuple[int, str]]]]
    # live guards: (name, guard, expected max_programs, expected donate)
    guards: List[Tuple[str, Any, Optional[int], Tuple[int, ...]]]


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        tree)


def _stacked_leaves(assign, params) -> List[Tuple[int, str]]:
    from ..core.masking import LeafUnit
    units = jax.tree_util.tree_leaves(
        assign.leaf_units, is_leaf=lambda x: isinstance(x, LeafUnit))
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(params)[0]]
    return [(i, p) for i, (u, p) in enumerate(zip(units, paths))
            if u.kind == "stacked"]


def _toy_fixture(fl):
    """Shared toy-model setup for the round-path traces."""
    from ..models.toy import init_toy_mlp, toy_batches, toy_units
    key = jax.random.PRNGKey(0)
    params = init_toy_mlp(key, n_blocks=4, d=8, hidden=12, out=4)
    assign = toy_units(params)
    batches = toy_batches(jax.random.fold_in(key, 1),
                          n_clients=fl.n_clients, steps=2, batch=2,
                          d=8, out=4)
    n_slots = fl.resolve_n_slots(assign.n_units)
    return params, assign, batches, n_slots


def _grad_probe(name, fl, *, scoring: bool):
    """jaxpr of grad(round loss)(global_params) through the *shared*
    packed cohort trace (``client.packed_cohort_fn`` — the exact
    function the sync step, async dispatch and cohort chunk vmap)."""
    from ..core.client import packed_cohort_fn
    from ..core.masking import slot_plan
    from ..models.toy import toy_loss
    params, assign, batches, n_slots = _toy_fixture(fl)
    sel = np.zeros((fl.n_clients, assign.n_units), np.float32)
    sel[:, :assign.n_units // 2] = 1.0
    rows, valid = jax.vmap(
        lambda s: slot_plan(assign, s, n_slots, params))(jnp.asarray(sel))
    cohort = packed_cohort_fn(toy_loss, assign, fl, None, scoring=scoring)

    def probe(gp):
        return cohort(gp, rows, valid, batches)[1]["loss_mean"].sum()

    closed = jax.make_jaxpr(jax.grad(probe))(params)
    return name, closed, _stacked_leaves(assign, params)


@functools.lru_cache(maxsize=1)
def traced_programs() -> _Registry:
    """Build and trace every registered compiled path (cached — the
    fixture builds are pure and configuration-independent)."""
    from ..core.async_agg import (BufferedAggregator, build_cohort_step,
                                  flush_arg_specs)
    from ..core.cohort import build_cohort_programs
    from ..core.federation import FLConfig, build_round_step
    from ..core.server import Server
    from ..core.topology import resolve_topology
    from ..models.toy import toy_loss

    programs: List[TracedProgram] = []
    probes = []
    guards: List[Tuple[str, Any, Optional[int], Tuple[int, ...]]] = []

    def lower_text(fn, donate, args):
        return jax.jit(fn, donate_argnums=donate).lower(*args).as_text()

    # -- sync packed round step --------------------------------------------
    fl = FLConfig(n_clients=3, train_fraction=0.5, packed=True,
                  fused_agg="off")
    params, assign, batches, _ = _toy_fixture(fl)
    srv = Server(build_round_step(toy_loss, assign, fl), assign, fl, params)
    w = jnp.ones((fl.n_clients,), jnp.float32)
    rk = jax.random.key(0)                         # typed key: key flow
    sync_args = (srv.params, batches, w, rk)
    programs.append(TracedProgram(
        "trace:sync/round_step",
        jax.make_jaxpr(srv.round_step.fn)(*sync_args),
        lowered_text=lower_text(srv.round_step.fn,
                                srv.round_step.donate_argnums, sync_args),
        n_donated=len(jax.tree_util.tree_leaves(srv.params))))
    guards.append(("trace:sync/round_step", srv.round_step, 1, (0,)))
    probes.append(_grad_probe("trace:sync/frozen_grad", fl, scoring=False))

    # -- sync packed round step with the qint8 uplink codec ----------------
    # the codec's stochastic-rounding uniforms must descend from the
    # round key (fold_in(round_key, CODEC_KEY_TAG) then per-leaf
    # fold_in) and never host-sync — both walkers cover this trace
    fl_q = dataclasses.replace(fl, codec="qint8")
    srv_q = Server(build_round_step(toy_loss, assign, fl_q), assign, fl_q,
                   params)
    programs.append(TracedProgram(
        "trace:sync/round_step_qint8",
        jax.make_jaxpr(srv_q.round_step.fn)(*sync_args)))
    guards.append(("trace:sync/round_step_qint8", srv_q.round_step,
                   1, (0,)))

    # -- buffered-async select + flush -------------------------------------
    fl_a = FLConfig(n_clients=3, train_fraction=0.5, packed=True,
                    fused_agg="off", async_buffer=2)
    params_a, assign_a, batches_a, _ = _toy_fixture(fl_a)
    select_fn, cohort_fn, _ = build_cohort_step(toy_loss, assign_a, fl_a)
    programs.append(TracedProgram(
        "trace:async/select",
        jax.make_jaxpr(select_fn.fn)(jax.random.key(0))))
    sel_sds = jax.ShapeDtypeStruct((fl_a.n_clients, assign_a.n_units),
                                   jnp.float32)
    programs.append(TracedProgram(
        "trace:async/cohort",
        jax.make_jaxpr(cohort_fn.fn)(_sds_tree(params_a), sel_sds,
                                     batches_a)))
    flush = resolve_topology("hub").build_buffered_flush(assign_a, fl_a)
    flush_args = (_sds_tree(params_a),) + \
        flush_arg_specs(assign_a, params_a, fl_a)
    agg = BufferedAggregator(fl_a.async_buffer, fl_a.staleness,
                             fl_a.staleness_alpha, flush)
    programs.append(TracedProgram(
        "trace:async/flush",
        jax.make_jaxpr(flush)(*flush_args),
        lowered_text=lower_text(flush, agg._flush.donate_argnums,
                                flush_args),
        n_donated=len(jax.tree_util.tree_leaves(params_a))))
    guards.append(("trace:async/flush", agg._flush, 1, (0,)))
    probes.append(_grad_probe("trace:async/frozen_grad", fl_a,
                              scoring=False))

    # -- cohort engine: select / chunk / finalize ---------------------------
    fl_c = FLConfig(n_clients=4, n_registered=8, cohort_chunk=2,
                    train_fraction=0.5, packed=True, fused_agg="off")
    params_c, assign_c, _, _ = _toy_fixture(fl_c)
    prog = build_cohort_programs(toy_loss, assign_c, fl_c)
    u = assign_c.n_units
    acc_sds = jax.eval_shape(prog.acc_init.fn, _sds_tree(params_c))
    from ..models.toy import toy_batches
    chunk_b = toy_batches(jax.random.PRNGKey(2),
                          n_clients=fl_c.cohort_chunk, steps=2, batch=2,
                          d=8, out=4)
    chunk_args = (_sds_tree(params_c), acc_sds,
                  jax.ShapeDtypeStruct((fl_c.cohort_chunk, u), jnp.float32),
                  jax.ShapeDtypeStruct((fl_c.cohort_chunk,), jnp.float32),
                  jax.ShapeDtypeStruct((fl_c.cohort_chunk,), jnp.int32),
                  chunk_b)
    fin_args = (_sds_tree(params_c), acc_sds,
                jax.ShapeDtypeStruct((fl_c.n_clients, u), jnp.float32),
                jax.ShapeDtypeStruct((fl_c.n_clients,), jnp.float32),
                jax.ShapeDtypeStruct((fl_c.n_clients,), jnp.float32))
    programs.append(TracedProgram(
        "trace:cohort/select",
        jax.make_jaxpr(prog.select.fn)(jax.random.key(0))))
    programs.append(TracedProgram(
        "trace:cohort/chunk",
        jax.make_jaxpr(prog.chunk.fn)(*chunk_args),
        lowered_text=lower_text(prog.chunk.fn, prog.chunk.donate_argnums,
                                chunk_args),
        n_donated=len(jax.tree_util.tree_leaves(acc_sds))))
    programs.append(TracedProgram(
        "trace:cohort/finalize",
        jax.make_jaxpr(prog.finalize.fn)(*fin_args),
        lowered_text=lower_text(prog.finalize.fn,
                                prog.finalize.donate_argnums, fin_args),
        n_donated=len(jax.tree_util.tree_leaves(acc_sds))))
    guards.append(("trace:cohort/select", prog.select, 1, ()))
    guards.append(("trace:cohort/chunk", prog.chunk, 1, (1,)))
    guards.append(("trace:cohort/finalize", prog.finalize, 1, (1,)))
    probes.append(_grad_probe("trace:cohort/frozen_grad", fl_c,
                              scoring=True))

    # -- serve prefill + decode ---------------------------------------------
    # typed keys must be on while tracing: sample_tokens creates its
    # base key *inside* the trace via jax.random.PRNGKey, which only
    # surfaces as key-typed random_* primitives under custom prng
    from ..configs.base import get_config
    from ..models import get_model
    from ..serve.engine import DecodeEngine, ServeConfig

    cfg = get_config("gemma3-12b").reduced()
    model_params = jax.eval_shape(
        lambda k: get_model(cfg).init_params(k), jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, model_params,
                       ServeConfig(n_slots=2, max_len=32, page_size=16,
                                   temperature=0.7))
    tokens, steps, rids, gidx = eng.scheduler.step_arrays()
    tables = eng.tables.device_tables()
    old_flag = jax.config.jax_enable_custom_prng
    jax.config.update("jax_enable_custom_prng", True)
    try:
        programs.append(TracedProgram(
            "trace:serve/decode",
            jax.make_jaxpr(eng._decode.fn)(
                model_params, eng.paged,
                jnp.asarray(tokens[:, None]), jnp.asarray(steps), tables,
                jnp.asarray(rids), jnp.asarray(gidx))))
        programs.append(TracedProgram(
            "trace:serve/prefill",
            jax.make_jaxpr(eng._prefill.fn)(
                model_params,
                jax.ShapeDtypeStruct((2, 8), jnp.int32),
                jnp.asarray(rids), jnp.asarray(gidx))))
    finally:
        jax.config.update("jax_enable_custom_prng", old_flag)
    guards.append(("trace:serve/decode", eng._decode, 1, ()))
    guards.append(("trace:serve/prefill", eng._prefill, None, ()))

    return _Registry(programs=programs, grad_probes=probes, guards=guards)


# ---------------------------------------------------------------------------
# registry wiring

@register_checker("trace-host-sync", "trace")
def _host_sync_checker(root: Path) -> List[Finding]:
    reg = traced_programs()
    return [f for p in reg.programs
            for f in check_host_sync_jaxpr(p.name, p.closed, p.host_allow)]


@register_checker("trace-key-flow", "trace")
def _key_flow_checker(root: Path) -> List[Finding]:
    reg = traced_programs()
    return [f for p in reg.programs if p.check_keys
            for f in check_key_flow_jaxpr(p.name, p.closed)]


@register_checker("trace-frozen-grad", "trace")
def _frozen_grad_checker(root: Path) -> List[Finding]:
    reg = traced_programs()
    return [f for name, closed, stacked in reg.grad_probes
            for f in check_frozen_grad_jaxpr(name, closed, stacked)]


@register_checker("trace-donation", "trace")
def _donation_checker(root: Path) -> List[Finding]:
    reg = traced_programs()
    return [f for p in reg.programs if p.n_donated
            for f in check_donation_text(p.name, p.lowered_text,
                                         p.n_donated)]


@register_checker("trace-compileguard", "trace")
def _guard_checker(root: Path) -> List[Finding]:
    reg = traced_programs()
    return [f for name, guard, maxp, dn in reg.guards
            for f in check_guard_contract(name, guard, maxp, dn)]


@register_checker("trace-codec-frozen", "trace")
def _codec_frozen_checker(root: Path) -> List[Finding]:
    """Every registered non-identity codec's transform, on the shared
    toy fixture (``none`` builds no transform — nothing to leak)."""
    from ..core import codecs as _codecs
    from ..core.federation import FLConfig
    out: List[Finding] = []
    for cname in _codecs.available_codecs():
        if cname == "none":
            continue
        fl = FLConfig(n_clients=3, train_fraction=0.5, packed=True,
                      fused_agg="off", codec=cname)
        params, assign, _, n_slots = _toy_fixture(fl)
        transform = _codecs.build_codec_transform(
            _codecs.get_codec(cname), assign, fl)
        out.extend(check_codec_pad_zeros(
            f"trace:codec/{cname}", transform, assign, params, fl,
            n_slots))
    return out
