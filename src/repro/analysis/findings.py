"""Finding/report/suppression plumbing shared by both analyzer levels.

A checker is a function registered under a name and a level (``lint``
for AST checks, ``trace`` for jaxpr checks) that returns a list of
:class:`Finding`.  The CLI runs every registered checker, applies the
baseline suppressions file, writes ``results/analysis.json`` and exits
non-zero on any unsuppressed finding — the CI gate.

Suppression format (``src/repro/analysis/baseline.json``)::

    {"suppressions": [
        {"checker": "lint-bare-jit",
         "match": "src/repro/launch/dryrun.py::*",
         "reason": "documented exception ..."}]}

``match`` is an ``fnmatch`` glob over the finding's stable fingerprint
``<anchor>::<symbol>`` (anchor = file path or traced-path name, no line
numbers, so suppressions survive unrelated edits).  Every suppression
must keep matching something: a stale entry is itself reported as a
finding, so the baseline can only shrink.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["Finding", "register_checker", "registered_checkers",
           "run_checkers", "load_suppressions", "apply_suppressions",
           "report_dict"]


@dataclasses.dataclass
class Finding:
    checker: str            # registered checker name
    level: str              # "lint" | "trace"
    anchor: str             # file path or traced-path name (stable)
    message: str
    symbol: str = ""        # class/function/program within the anchor
    line: int = 0           # display only — not part of the fingerprint
    suppressed: bool = False
    suppress_reason: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.anchor}::{self.symbol}"

    @property
    def location(self) -> str:
        return f"{self.anchor}:{self.line}" if self.line else self.anchor

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        d["location"] = self.location
        return d


# -- checker registry --------------------------------------------------------

_CHECKERS: Dict[str, Tuple[str, Callable]] = {}


def register_checker(name: str, level: str):
    """Decorator: register ``fn(root: Path) -> List[Finding]``."""
    if level not in ("lint", "trace"):
        raise ValueError(f"level must be lint|trace, got {level!r}")

    def deco(fn):
        _CHECKERS[name] = (level, fn)
        return fn
    return deco


def registered_checkers(level: Optional[str] = None) -> List[str]:
    return sorted(n for n, (lv, _) in _CHECKERS.items()
                  if level in (None, lv))


def run_checkers(root: Path, level: Optional[str] = None) -> List[Finding]:
    out: List[Finding] = []
    for name in registered_checkers(level):
        lv, fn = _CHECKERS[name]
        for f in fn(root):
            f.checker, f.level = name, lv
            out.append(f)
    return out


# -- suppressions ------------------------------------------------------------

def load_suppressions(path: Path) -> List[Dict]:
    if not Path(path).exists():
        return []
    data = json.loads(Path(path).read_text())
    sups = data.get("suppressions", [])
    for s in sups:
        for k in ("checker", "match", "reason"):
            if not s.get(k):
                raise ValueError(
                    f"suppression entry {s!r} missing required key {k!r}")
    return sups


def apply_suppressions(findings: List[Finding],
                       sups: List[Dict]) -> List[Finding]:
    """Mark suppressed findings in place; append a finding per stale
    suppression (one that matched nothing)."""
    used = [False] * len(sups)
    for f in findings:
        for i, s in enumerate(sups):
            if s["checker"] == f.checker and \
                    fnmatch.fnmatch(f.fingerprint, s["match"]):
                f.suppressed = True
                f.suppress_reason = s["reason"]
                used[i] = True
                break
    for s, u in zip(sups, used):
        if not u:
            findings.append(Finding(
                checker="suppressions", level="lint",
                anchor="src/repro/analysis/baseline.json",
                symbol=f"{s['checker']}::{s['match']}",
                message=f"stale suppression (matched no finding): "
                        f"checker={s['checker']} match={s['match']!r} — "
                        f"delete it"))
    return findings


# -- report ------------------------------------------------------------------

def report_dict(findings: List[Finding], checkers: List[str]) -> Dict:
    unsup = [f for f in findings if not f.suppressed]
    return {
        "version": 1,
        "tool": "repro.analysis",
        "checkers_run": checkers,
        "summary": {
            "total": len(findings),
            "suppressed": len(findings) - len(unsup),
            "unsuppressed": len(unsup),
            "by_checker": {
                c: sum(1 for f in findings if f.checker == c)
                for c in sorted({f.checker for f in findings})},
        },
        "findings": [f.to_json() for f in findings],
    }
