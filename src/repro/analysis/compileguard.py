"""CompileGuard: ``jax.jit`` with an enforced compile-count contract.

The serve engine's recompile-free contract (DESIGN.md §12) was a
single-site assertion — ``decode_cache_size == 1`` read off the jitted
decode step after the fact.  CompileGuard generalizes it to every
compiled path in the system: each entry point declares up front how
many distinct programs it is allowed to compile (``max_programs``, the
round paths all declare 1), the wrapper records the abstract signature
of every call, and a call that would cross the budget raises
:class:`CompileGuardError` **before** paying for the retrace — naming
the argument whose shape/dtype/structure changed, which is exactly the
information a silent recompile hides.

Donation rides the same wrapper: ``donate_argnums`` is forwarded to
``jax.jit`` and kept introspectable (``guard.donate_argnums``) so the
static analyzer (``repro.analysis.tracecheck``) can assert the round
paths donate their dead params/accumulator buffers and that the
lowering actually aliased them (no silent copies).

This module must stay import-light (jax only): ``core/`` and ``serve/``
import it, so it cannot import anything from ``repro``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

__all__ = ["CompileGuard", "CompileGuardError"]


class CompileGuardError(RuntimeError):
    """A guarded entry point tried to compile more programs than its
    declared budget (or retraced without a visible signature change)."""


def _leaf_spec(x) -> Tuple:
    """Hashable abstract spec of one argument leaf (what jit keys on)."""
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return ("array", tuple(x.shape), str(x.dtype),
                bool(getattr(x, "weak_type", False)))
    # python scalars trace as weak-typed 0-d arrays: the value never
    # forces a retrace, only the python type can
    return ("py", type(x).__name__)


def _spec_str(spec: Tuple) -> str:
    if spec[0] == "array":
        kind, shape, dtype, weak = spec
        return f"{dtype}{list(shape)}" + ("*" if weak else "")
    return f"<{spec[1]}>"


class CompileGuard:
    """Wrap ``fn`` in ``jax.jit`` and enforce a program-count budget.

    Parameters
    ----------
    fn:            the python function to jit (kept on ``guard.fn``).
    name:          label used in error messages and analyzer reports.
    max_programs:  how many distinct compiled programs this entry point
                   may own; ``None`` = unbounded (signature history is
                   still recorded for reporting).  The round paths and
                   the serve decode step declare 1; serve prefill is
                   unbounded (one program per distinct prompt length is
                   the documented shape cache).
    donate_argnums: forwarded to ``jax.jit`` and kept introspectable.
    jit_kwargs:    any further ``jax.jit`` options (``in_shardings``,
                   ``out_shardings``, ``static_argnums``, ...).
    """

    def __init__(self, fn: Callable, *, name: Optional[str] = None,
                 max_programs: Optional[int] = 1,
                 donate_argnums: Sequence[int] = (),
                 **jit_kwargs: Any):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "jitted")
        self.max_programs = max_programs
        self.donate_argnums = tuple(donate_argnums)
        self._jit = jax.jit(fn, donate_argnums=self.donate_argnums,
                            **jit_kwargs)
        # call-order history of abstract signatures:
        # sig key -> [(pretty arg path, leaf spec), ...]
        self._sigs: Dict[Tuple, List[Tuple[str, Tuple]]] = {}

    # -- signature bookkeeping ----------------------------------------------

    def _signature(self, args, kwargs):
        leaves, treedef = jax.tree_util.tree_flatten((args, dict(kwargs)))
        paths = [jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path((args,
                                                       dict(kwargs)))[0]]
        specs = tuple(_leaf_spec(x) for x in leaves)
        key = (str(treedef), specs)
        pretty = list(zip(paths, specs))
        return key, pretty

    def _diff(self, pretty) -> List[str]:
        """Human diff of the new signature vs the last recorded one."""
        if not self._sigs:
            return []
        old = list(self._sigs.values())[-1]
        if len(old) != len(pretty):
            return [f"argument structure changed: {len(old)} -> "
                    f"{len(pretty)} leaves (e.g. an optional argument "
                    f"appeared or a pytree changed shape)"]
        out = []
        for (op, os), (np_, ns) in zip(old, pretty):
            if os != ns or op != np_:
                out.append(f"arg {np_ or op}: "
                           f"{_spec_str(os)} -> {_spec_str(ns)}")
        return out or ["no shape/dtype change visible — weak_type or "
                       "sharding drift forced the retrace"]

    def _record(self, args, kwargs, *, about_to_compile: bool):
        key, pretty = self._signature(args, kwargs)
        if key in self._sigs:
            return
        if (about_to_compile and self.max_programs is not None
                and len(self._sigs) >= self.max_programs):
            diff = "\n  ".join(self._diff(pretty))
            raise CompileGuardError(
                f"CompileGuard[{self.name}]: call would compile program "
                f"#{len(self._sigs) + 1} (budget {self.max_programs}). "
                f"Retrace-triggering argument(s):\n  {diff}")
        self._sigs[key] = pretty

    # -- jit surface --------------------------------------------------------

    def __call__(self, *args, **kwargs):
        self._record(args, kwargs, about_to_compile=True)
        out = self._jit(*args, **kwargs)
        # ground truth: jit may retrace on distinctions our spec does
        # not model (e.g. sharding changes); catch those after the fact
        n = self.cache_size
        if self.max_programs is not None and n > self.max_programs:
            raise CompileGuardError(
                f"CompileGuard[{self.name}]: jit cache holds {n} "
                f"programs (budget {self.max_programs}) but the call "
                f"signatures look identical — a non-shape retrace "
                f"(sharding/weak_type) slipped through")
        return out

    def lower(self, *args, **kwargs):
        """Explicit lowering (dry-run paths); counts against the budget."""
        self._record(args, kwargs, about_to_compile=True)
        return self._jit.lower(*args, **kwargs)

    def eval_shape(self, *args, **kwargs):
        return jax.eval_shape(self.fn, *args, **kwargs)

    # -- introspection (used by repro.analysis.tracecheck) ------------------

    @property
    def cache_size(self) -> int:
        """Number of compiled programs: max of the jit cache (executed
        calls) and the recorded signature count (``lower()`` calls)."""
        try:
            cached = self._jit._cache_size()
        except Exception:
            cached = 0
        return max(cached, len(self._sigs))

    @property
    def programs(self) -> List[List[Tuple[str, str]]]:
        """Recorded signatures, call order: [[(arg path, spec), ...]]."""
        return [[(p, _spec_str(s)) for p, s in sig]
                for sig in self._sigs.values()]

    def assert_programs(self, n: int):
        """Hard assertion for smoke gates: at most ``n`` programs."""
        if self.cache_size > n:
            raise CompileGuardError(
                f"CompileGuard[{self.name}]: {self.cache_size} compiled "
                f"programs, expected <= {n}")
