"""Level-2 repo lint: AST checks over ``src/repro`` (DESIGN.md §15).

Four checkers, each registered with the analyzer registry and each
usable on an arbitrary file list so the bad-fixture tests can feed
intentionally broken sources:

* ``lint-registry``      — every ``@register_strategy/topology/
  staleness/client_sampler/fault/codec`` target carries a docstring and
  a resolvable name (a ``name = "..."`` class attribute, a name passed
  to the decorator, or — for function registries — the function name).
* ``lint-seeded-random`` — no unseeded ``np.random.*`` module-level
  draws and no wall-clock ``time.time()`` in ``core/`` or ``serve/``;
  the blessed idiom is ``np.random.default_rng(np.random.SeedSequence(
  (seed, tag, ...)))`` and ``time.perf_counter()`` for durations.
* ``lint-bare-jit``      — no bare ``jax.jit`` in the blessed modules
  (the compiled round/serve/dryrun paths); those must route through
  :class:`repro.analysis.compileguard.CompileGuard` so the retrace
  contract is enforced, not just asserted in tests.
* ``lint-flconfig``      — every numeric ``FLConfig`` field is covered
  by a validator/consumer inside the class (``__post_init__`` or a
  ``resolve_*``/``uses_*`` method), and every field is read somewhere
  in ``src/repro`` outside its definition (no dead knobs).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Set

from .findings import Finding, register_checker

# registries whose targets the discipline check covers; register_codec
# is the ROADMAP's next plugin axis — listed now so the gate covers it
# the day it lands (its absence today is simply zero decorated targets)
REGISTER_DECORATORS = {
    "register_strategy", "register_topology", "register_staleness",
    "register_client_sampler", "register_fault", "register_codec",
}

# np.random attributes that are legitimate *seeded* constructors; any
# other np.random.<attr> use in core/serve is an unseeded draw
SEEDED_NP_ATTRS = {
    "default_rng", "SeedSequence", "Generator", "BitGenerator",
    "PCG64", "Philox", "SFC64", "MT19937",
}

# modules whose compiled entry points must route through CompileGuard
BLESSED_MODULES = (
    "src/repro/core/server.py",
    "src/repro/core/async_agg.py",
    "src/repro/core/cohort.py",
    "src/repro/serve/engine.py",
    "src/repro/launch/dryrun.py",
)

SEEDED_SCOPE = ("src/repro/core/", "src/repro/serve/")


def _rel(root: Path, path: Path) -> str:
    try:
        return str(path.relative_to(root))
    except ValueError:
        return str(path)


def _parse(path: Path):
    return ast.parse(path.read_text(), filename=str(path))


def repo_py_files(root: Path) -> List[Path]:
    return sorted((root / "src" / "repro").rglob("*.py"))


def _decorator_name(dec: ast.expr) -> Optional[str]:
    """Name of a decorator, seeing through call forms and attributes."""
    node = dec.func if isinstance(dec, ast.Call) else dec
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _class_name_attr(node: ast.ClassDef) -> Optional[str]:
    """A literal ``name = "..."`` / ``name: str = "..."`` class attr."""
    for stmt in node.body:
        target = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            target = stmt.targets[0].id
            value = stmt.value
        elif isinstance(stmt, ast.AnnAssign) \
                and isinstance(stmt.target, ast.Name):
            target = stmt.target.id
            value = stmt.value
        if target == "name" and isinstance(value, ast.Constant) \
                and isinstance(value.value, str) and value.value:
            return value.value
        # tuple form: ``name, seam = "crash", "crash"``
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Tuple) \
                and isinstance(stmt.value, ast.Tuple) \
                and len(stmt.targets[0].elts) == len(stmt.value.elts):
            for tgt, val in zip(stmt.targets[0].elts, stmt.value.elts):
                if isinstance(tgt, ast.Name) and tgt.id == "name" \
                        and isinstance(val, ast.Constant) \
                        and isinstance(val.value, str) and val.value:
                    return val.value
    return None


def _decorator_name_kwarg(dec: ast.expr) -> Optional[str]:
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg == "name" and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                return kw.value.value
        for a in dec.args:
            if isinstance(a, ast.Constant) and isinstance(a.value, str):
                return a.value
    return None


# -- checker 1: registry discipline -----------------------------------------

def lint_registry(root: Path,
                  files: Optional[Iterable[Path]] = None) -> List[Finding]:
    out: List[Finding] = []
    for path in (files or repo_py_files(root)):
        rel = _rel(root, path)
        tree = _parse(path)
        for node in ast.walk(tree):
            if not isinstance(node, (ast.ClassDef, ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            regs = [d for d in node.decorator_list
                    if _decorator_name(d) in REGISTER_DECORATORS]
            if not regs:
                continue
            reg = _decorator_name(regs[0])
            if not ast.get_docstring(node):
                out.append(Finding(
                    checker="", level="", anchor=rel, symbol=node.name,
                    line=node.lineno,
                    message=f"@{reg} target {node.name!r} has no "
                            f"docstring — registered plugins are the "
                            f"public surface; document the contract"))
            name = _decorator_name_kwarg(regs[0])
            if isinstance(node, ast.ClassDef):
                name = name or _class_name_attr(node)
            else:
                name = name or node.name     # function registries
            if not name:
                out.append(Finding(
                    checker="", level="", anchor=rel, symbol=node.name,
                    line=node.lineno,
                    message=f"@{reg} target {node.name!r} has no "
                            f"resolvable registry name (add a literal "
                            f"``name = \"...\"`` attribute or pass "
                            f"``name=`` to the decorator)"))
    return out


# -- checker 2: seeded randomness / wall clock -------------------------------

def lint_seeded_random(root: Path,
                       files: Optional[Iterable[Path]] = None
                       ) -> List[Finding]:
    out: List[Finding] = []
    for path in (files or repo_py_files(root)):
        rel = _rel(root, path)
        if files is None and not rel.startswith(SEEDED_SCOPE):
            continue
        tree = _parse(path)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Attribute):
                continue
            # np.random.<attr> / numpy.random.<attr>
            v = node.value
            if isinstance(v, ast.Attribute) and v.attr == "random" \
                    and isinstance(v.value, ast.Name) \
                    and v.value.id in ("np", "numpy") \
                    and node.attr not in SEEDED_NP_ATTRS:
                out.append(Finding(
                    checker="", level="", anchor=rel,
                    symbol=f"np.random.{node.attr}", line=node.lineno,
                    message=f"unseeded np.random.{node.attr} in a "
                            f"determinism-critical tree — draw from "
                            f"np.random.default_rng(np.random."
                            f"SeedSequence((seed, tag, ...))) instead"))
            # time.time() — wall clock leaks into round math; durations
            # use time.perf_counter()
            if node.attr == "time" and isinstance(v, ast.Name) \
                    and v.id == "time":
                out.append(Finding(
                    checker="", level="", anchor=rel, symbol="time.time",
                    line=node.lineno,
                    message="time.time() in a determinism-critical "
                            "tree — use time.perf_counter() for "
                            "durations or a seeded simulated clock"))
    return out


# -- checker 3: bare jax.jit in blessed modules ------------------------------

def lint_bare_jit(root: Path,
                  files: Optional[Iterable[Path]] = None) -> List[Finding]:
    out: List[Finding] = []
    paths = list(files) if files is not None else \
        [root / m for m in BLESSED_MODULES if (root / m).exists()]
    for path in paths:
        rel = _rel(root, path)
        tree = _parse(path)
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "jit" \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id == "jax":
                out.append(Finding(
                    checker="", level="", anchor=rel, symbol="jax.jit",
                    line=node.lineno,
                    message="bare jax.jit in a blessed compiled-path "
                            "module — route through CompileGuard so the "
                            "retrace budget and donation contract are "
                            "enforced (repro.analysis.compileguard)"))
    return out


# -- checker 4: FLConfig field/validator coverage ----------------------------

def _flconfig_class(tree: ast.Module) -> Optional[ast.ClassDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "FLConfig":
            return node
    return None


def _is_numeric_ann(ann: ast.expr) -> bool:
    if isinstance(ann, ast.Name):
        return ann.id in ("int", "float")
    if isinstance(ann, ast.Subscript):       # Optional[int] etc.
        return any(_is_numeric_ann(n) for n in ast.walk(ann)
                   if isinstance(n, ast.Name))
    return False


def lint_flconfig(root: Path,
                  config_file: Optional[Path] = None,
                  files: Optional[Iterable[Path]] = None) -> List[Finding]:
    cfg_path = config_file or (root / "src/repro/core/federation.py")
    if not cfg_path.exists():
        return []
    rel = _rel(root, cfg_path)
    tree = _parse(cfg_path)
    cls = _flconfig_class(tree)
    if cls is None:
        return []
    fields = {}           # name -> (lineno, numeric)
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) \
                and isinstance(stmt.target, ast.Name):
            fields[stmt.target.id] = (stmt.lineno,
                                      _is_numeric_ann(stmt.annotation))
    # self.<field> references inside FLConfig methods = validator or
    # consumer coverage
    method_refs: Set[str] = set()
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for node in ast.walk(stmt):
                if isinstance(node, ast.Attribute) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id == "self":
                    method_refs.add(node.attr)
    # .<field> attribute reads anywhere else in the tree = knob is live
    external_refs: Set[str] = set()
    for path in (files or repo_py_files(root)):
        if path.resolve() == cfg_path.resolve():
            continue
        for node in ast.walk(_parse(path)):
            if isinstance(node, ast.Attribute):
                external_refs.add(node.attr)

    out: List[Finding] = []
    for name, (lineno, numeric) in fields.items():
        if numeric and name not in method_refs:
            out.append(Finding(
                checker="", level="", anchor=rel, symbol=name, line=lineno,
                message=f"numeric FLConfig field {name!r} has no "
                        f"validator/consumer inside FLConfig — add a "
                        f"__post_init__ range check (misconfig should "
                        f"fail at build time, not rounds later)"))
        if name not in external_refs and name not in method_refs:
            # a field consumed only through an FLConfig resolver method
            # (e.g. resolve_n_edges) is live — method_refs covers it
            out.append(Finding(
                checker="", level="", anchor=rel, symbol=name, line=lineno,
                message=f"FLConfig field {name!r} is never read outside "
                        f"its definition — dead knob (wire it up or "
                        f"delete it)"))
    return out


# -- registry wiring ---------------------------------------------------------

@register_checker("lint-registry", "lint")
def _registry_checker(root: Path) -> List[Finding]:
    return lint_registry(root)


@register_checker("lint-seeded-random", "lint")
def _seeded_checker(root: Path) -> List[Finding]:
    return lint_seeded_random(root)


@register_checker("lint-bare-jit", "lint")
def _bare_jit_checker(root: Path) -> List[Finding]:
    return lint_bare_jit(root)


@register_checker("lint-flconfig", "lint")
def _flconfig_checker(root: Path) -> List[Finding]:
    return lint_flconfig(root)
