"""Analyzer entry point — the CI gate (DESIGN.md §15).

Usage::

    python -m repro.analysis.cli --report results/analysis.json

Runs every registered checker (``--level lint`` / ``--level trace``
restricts to one level), applies the baseline suppressions, writes the
JSON report and exits non-zero iff any non-suppressed finding remains.
``test.sh --analyze`` and the GitHub Actions workflow call exactly this.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import lint as _lint              # noqa: F401 — registers checkers
from . import tracecheck as _trace       # noqa: F401 — registers checkers
from .findings import (apply_suppressions, load_suppressions,
                       registered_checkers, report_dict, run_checkers)

REPO_ROOT = Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.cli",
        description="repo static-analysis gate: AST lint + jaxpr "
                    "contract checks")
    ap.add_argument("--report", default="results/analysis.json",
                    help="JSON report path (default %(default)s)")
    ap.add_argument("--suppressions",
                    default=str(REPO_ROOT /
                                "src/repro/analysis/baseline.json"),
                    help="baseline suppressions file")
    ap.add_argument("--level", choices=("all", "lint", "trace"),
                    default="all",
                    help="run only one checker level (default all)")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="repo root for the AST lint (default: this "
                         "checkout)")
    args = ap.parse_args(argv)

    level = None if args.level == "all" else args.level
    checkers = registered_checkers(level)
    findings = run_checkers(Path(args.root), level)
    findings = apply_suppressions(
        findings, load_suppressions(Path(args.suppressions)))

    report = report_dict(findings, checkers)
    out = Path(args.report)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")

    unsup = [f for f in findings if not f.suppressed]
    s = report["summary"]
    print(f"repro.analysis: {len(checkers)} checkers, "
          f"{s['total']} finding(s) ({s['suppressed']} suppressed) "
          f"-> {out}")
    for f in unsup:
        print(f"  {f.checker}: {f.location} [{f.symbol}] {f.message}")
    return 1 if unsup else 0


if __name__ == "__main__":
    sys.exit(main())
