"""Static-analysis subsystem (DESIGN.md §15): jaxpr contract checkers
(`tracecheck`), repo AST lint (`lint`), and the `CompileGuard` jit
wrapper every compiled entry point routes through.

Only `CompileGuard` is exported eagerly — `core/` and `serve/` import
it, so this package must not import them back at import time.  The
checkers live behind `repro.analysis.cli`.
"""
from .compileguard import CompileGuard, CompileGuardError

__all__ = ["CompileGuard", "CompileGuardError"]
