"""The four assigned input shapes + per-arch applicability (DESIGN.md §7)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# archs admissible for long_500k (sub-quadratic decode; DESIGN.md §7)
LONG_CONTEXT_OK = ("rwkv6-3b", "hymba-1.5b", "gemma3-12b")


def shape_applicable(arch_name: str, cfg, shape: InputShape
                     ) -> Tuple[bool, str]:
    if shape.name == "long_500k":
        if arch_name in LONG_CONTEXT_OK:
            return True, ""
        return False, ("full-attention arch: 500k dense KV decode skipped "
                       "(DESIGN.md §7)")
    return True, ""


def list_pairs():
    """All (arch, shape) pairs with applicability annotations."""
    from ..configs.base import list_configs, get_config
    out = []
    for a in list_configs():
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(a, cfg, s)
            out.append((a, s.name, ok, why))
    return out
