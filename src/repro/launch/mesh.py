"""Production meshes (TPU v5e target) and FL logical views.

``make_production_mesh`` is the spec-literal mesh: (16, 16)
("data", "model") for one 256-chip pod; (2, 16, 16)
("pod", "data", "model") for the 2-pod, 512-chip deployment.

``make_fl_mesh`` is the federated *view* of the same device array
(DESIGN.md §5): a leading ``client`` axis carved out of the data axis —
clients are mesh subgroups (cross-device mode) or whole pods (cross-silo
mode, multi-pod: clients never span a pod, so the pod axis folds into
the client axis and the paper's WAN bottleneck lands on the pod-to-pod
DCN link).

``make_hier_fl_mesh`` is the hierarchical topology's view (DESIGN.md
§6): the client axis further carved into a leading ``edge`` group axis,
``(edge, client, data, model)``.  Clients of one edge are adjacent mesh
subgroups (their reduce stays on local interconnect — the edge
aggregator); only the per-edge partial aggregates cross the ``edge``
axis boundary, which is the edge->hub WAN link.

Functions, not module constants: importing this module never touches
jax device state (dryrun.py must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         devices=jax.devices()[: _size(shape)])


def make_fl_mesh(n_clients: int, *, multi_pod: bool = False):
    """(client, data, model) view with client*data = pods*16, model = 16."""
    pods = 2 if multi_pod else 1
    total_dp = pods * 16
    if multi_pod:
        # cross-silo: the pod axis folds into the client axis
        n_clients = max(n_clients, pods)
        if n_clients % pods:
            raise ValueError("multi-pod clients must fill pods evenly")
    if total_dp % n_clients:
        raise ValueError(f"client axis {n_clients} must divide {total_dp}")
    shape = (n_clients, total_dp // n_clients, 16)
    return jax.make_mesh(shape, ("client", "data", "model"),
                         devices=jax.devices()[: _size(shape)])


def make_hier_fl_mesh(n_edges: int, n_clients: int, *,
                      multi_pod: bool = False):
    """(edge, client, data, model) view: edge * client * data = DP chips.

    The flat client axis of ``make_fl_mesh`` is split edge-major, so
    client c lands in edge c // (n_clients/n_edges) — matching the
    contiguous edge groups the hierarchical aggregation stage uses
    (core/comm.py ``edge_membership``).
    """
    pods = 2 if multi_pod else 1
    total_dp = pods * 16
    if n_edges < 1 or n_clients % n_edges:
        raise ValueError(f"edge axis {n_edges} must divide the "
                         f"{n_clients} clients evenly")
    if total_dp % n_clients:
        raise ValueError(f"client axis {n_clients} must divide {total_dp}")
    shape = (n_edges, n_clients // n_edges, total_dp // n_clients, 16)
    return jax.make_mesh(shape, ("edge", "client", "data", "model"),
                         devices=jax.devices()[: _size(shape)])


def make_host_mesh(*, model: int = 1):
    """Degenerate 1-device mesh for CPU tests and examples."""
    return jax.make_mesh((1, model), ("data", "model"),
                         devices=jax.devices()[:model])


def _size(shape):
    n = 1
    for s in shape:
        n *= s
    return n
