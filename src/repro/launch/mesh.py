"""Production meshes (TPU v5e target) and FL logical views.

``make_production_mesh`` is the spec-literal mesh: (16, 16)
("data", "model") for one 256-chip pod; (2, 16, 16)
("pod", "data", "model") for the 2-pod, 512-chip deployment.

``make_fl_mesh`` is the federated *view* of the same device array
(DESIGN.md §5): a leading ``client`` axis carved out of the data axis —
clients are mesh subgroups (cross-device mode) or whole pods (cross-silo
mode, multi-pod: clients never span a pod, so the pod axis folds into
the client axis and the paper's WAN bottleneck lands on the pod-to-pod
DCN link).

``make_hier_fl_mesh`` is the hierarchical topology's view (DESIGN.md
§6): the client axis further carved into a leading ``edge`` group axis,
``(edge, client, data, model)``.  Clients of one edge are adjacent mesh
subgroups (their reduce stays on local interconnect — the edge
aggregator); only the per-edge partial aggregates cross the ``edge``
axis boundary, which is the edge->hub WAN link.

``make_client_mesh`` / ``shard_over_clients`` are the cohort engine's
1-D ``(client,)`` device mesh (DESIGN.md §13): the in-flight cohort's
leading client axis is split over device groups with ``shard_map``, each
group vmapping its shard of clients — per-client rows of a batched
local update are independent of their cohort, so the sharded run is
bitwise-equal to the single-device vmap (property-tested).

Functions, not module constants: importing this module never touches
jax device state (dryrun.py must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def _nearest_valid(total: int, want: int) -> str:
    """Human hint: the divisors of ``total`` bracketing ``want``."""
    divs = [d for d in range(1, total + 1) if total % d == 0]
    below = max((d for d in divs if d < want), default=None)
    above = min((d for d in divs if d > want), default=None)
    opts = [str(d) for d in (below, above) if d is not None]
    return " or ".join(opts) if opts else "none"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         devices=jax.devices()[: _size(shape)])


def make_fl_mesh(n_clients: int, *, multi_pod: bool = False):
    """(client, data, model) view with client*data = pods*16, model = 16."""
    pods = 2 if multi_pod else 1
    total_dp = pods * 16
    if multi_pod:
        # cross-silo: the pod axis folds into the client axis
        n_clients = max(n_clients, pods)
        if n_clients % pods:
            raise ValueError(
                f"multi-pod clients must fill the {pods} pods evenly: "
                f"requested {n_clients} clients with "
                f"{len(jax.devices())} devices visible; nearest valid "
                f"cohort sizes: {pods * (n_clients // pods)} or "
                f"{pods * (n_clients // pods + 1)}")
    if total_dp % n_clients:
        raise ValueError(
            f"client axis {n_clients} must divide the {total_dp}-way "
            f"data parallelism ({len(jax.devices())} devices visible, "
            f"model axis 16); nearest valid cohort sizes: "
            f"{_nearest_valid(total_dp, n_clients)}")
    shape = (n_clients, total_dp // n_clients, 16)
    return jax.make_mesh(shape, ("client", "data", "model"),
                         devices=jax.devices()[: _size(shape)])


def make_hier_fl_mesh(n_edges: int, n_clients: int, *,
                      multi_pod: bool = False):
    """(edge, client, data, model) view: edge * client * data = DP chips.

    The flat client axis of ``make_fl_mesh`` is split edge-major, so
    client c lands in edge c // (n_clients/n_edges) — matching the
    contiguous edge groups the hierarchical aggregation stage uses
    (core/comm.py ``edge_membership``).
    """
    pods = 2 if multi_pod else 1
    total_dp = pods * 16
    if n_edges < 1 or n_clients % n_edges:
        raise ValueError(
            f"edge axis {n_edges} must divide the {n_clients} clients "
            f"evenly ({len(jax.devices())} devices visible); nearest "
            f"valid edge counts for {n_clients} clients: "
            f"{_nearest_valid(n_clients, max(n_edges, 1))}")
    if total_dp % n_clients:
        raise ValueError(
            f"client axis {n_clients} must divide the {total_dp}-way "
            f"data parallelism ({len(jax.devices())} devices visible, "
            f"model axis 16); nearest valid cohort sizes: "
            f"{_nearest_valid(total_dp, n_clients)}")
    shape = (n_edges, n_clients // n_edges, total_dp // n_clients, 16)
    return jax.make_mesh(shape, ("edge", "client", "data", "model"),
                         devices=jax.devices()[: _size(shape)])


def make_client_mesh(n_shards: int):
    """1-D ``(client,)`` mesh over the first ``n_shards`` devices."""
    ndev = len(jax.devices())
    if n_shards < 1 or n_shards > ndev:
        raise ValueError(
            f"client_shards={n_shards} needs between 1 and {ndev} "
            f"devices ({ndev} visible)")
    return jax.make_mesh((n_shards,), ("client",),
                         devices=jax.devices()[:n_shards])


def shard_over_clients(fn, n_shards: int, n_clients: int):
    """Split ``fn``'s leading client axis over a ``(client,)`` mesh.

    ``fn(replicated, *per_client) -> per-client outputs`` — typically a
    vmapped cohort stage: the first argument (a pytree, e.g. global
    params) is replicated, every other argument and every output leaf
    carries a leading client axis that shard_map splits into
    ``n_shards`` device-local blocks, each vmapped on its own device
    group.  Per-client rows are independent, so the result is bitwise
    what the unsharded vmap produces.
    """
    ndev = len(jax.devices())
    if n_clients % n_shards:
        valid = [d for d in range(1, min(n_clients, ndev) + 1)
                 if n_clients % d == 0]
        raise ValueError(
            f"client_shards={n_shards} must divide the cohort of "
            f"{n_clients} clients ({ndev} devices visible); valid "
            f"shard counts here: {valid}")
    mesh = make_client_mesh(n_shards)
    from jax.sharding import PartitionSpec as P
    try:
        _shard_map = jax.shard_map
        extra = {"check_vma": False}
    except AttributeError:  # jax < 0.6 spells it experimental
        from jax.experimental.shard_map import shard_map as _shard_map
        extra = {"check_rep": False}

    def wrapped(replicated, *per_client):
        sharded = _shard_map(
            fn, mesh=mesh,
            in_specs=(P(),) + tuple(P("client") for _ in per_client),
            out_specs=P("client"), **extra)
        return sharded(replicated, *per_client)

    return wrapped


def make_host_mesh(*, model: int = 1):
    """Degenerate 1-device mesh for CPU tests and examples."""
    return jax.make_mesh((1, model), ("data", "model"),
                         devices=jax.devices()[:model])


def _size(shape):
    n = 1
    for s in shape:
        n *= s
    return n
