import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init.  512 host devices back both the 256-chip single-pod mesh and the
# 2-pod 512-chip mesh (placeholders — lowering only, nothing allocates).

# Multi-pod dry-run: prove every (arch × input-shape × mesh) lowers,
# compiles, fits, and report its roofline terms.
#
#     PYTHONPATH=src python -m repro.launch.dryrun \
#         --arch qwen3-1.7b --shape train_4k --mesh single [--step fl_round]
#
# Methodology (EXPERIMENTS.md §Methodology):
#   * the FULL config compiles with the compact layer scan — this is the
#     pass/fail lowering proof and the source of memory_analysis();
#   * per-device FLOPs / bytes / collective bytes come from two small
#     UNROLLED compiles (1-macro and 2-macro depth) extrapolated linearly
#     — XLA counts a while-loop body once, so scanned cost_analysis
#     undercounts by the trip count, and a full unroll both compiles
#     ~15x slower and fuses worse on the CPU backend.
#
# Writes one JSON record per run under results/dryrun/.
# (No __future__ import here: the XLA_FLAGS lines above must stay first.)

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..analysis.compileguard import CompileGuard

from ..configs.base import get_config
from ..common import pytree as pt
from ..sharding import layout_for
from . import roofline, specs
from .mesh import (make_fl_mesh, make_hier_fl_mesh,
                   make_production_mesh)
from .shapes import SHAPES, shape_applicable
from .steps import (default_loss_kwargs, make_decode_step, make_fl_round_step,
                    make_prefill_step, make_train_step)


def param_counts(cfg, params_sds) -> Dict[str, int]:
    total = pt.param_count(params_sds)
    active = total
    if cfg.moe is not None:
        from ..models.transformer import block_layout, n_macro
        n_moe_layers = sum(s.moe for s in block_layout(cfg)) * n_macro(cfg)
        e, k, ff = cfg.moe.num_experts, cfg.moe.top_k, cfg.moe.expert_d_ff
        inactive = 3 * (e - k) * cfg.d_model * ff * n_moe_layers
        active = total - inactive
    return {"total": total, "active": active}


def depth_variants(cfg):
    """(2-macro cfg, 3-macro cfg, n_macro) for the cost extrapolation
    (1-layer anchors trip degenerate GSPMD decisions — see roofline)."""
    if cfg.family == "audio":
        return (cfg.replace(n_layers=2, n_enc_layers=2),
                cfg.replace(n_layers=3, n_enc_layers=3), cfg.n_layers)
    from ..models.transformer import block_layout
    macro = len(block_layout(cfg))
    return (cfg.replace(n_layers=2 * macro), cfg.replace(n_layers=3 * macro),
            cfg.n_layers // macro)


def logits_pspec(layout, mesh, shape, step_kind):
    """Explicit logits sharding (see models.layers.set_logits_partition)."""
    from .specs import _dp_axes, _dp_size
    if step_kind == "decode":
        return None                      # tiny (B,1,V); leave to GSPMD
    dp = _dp_axes(mesh)
    if layout == "fsdp_only":
        dp = dp + ("model",)
    if shape.global_batch % _dp_size(mesh) != 0:
        return None
    vocab_ax = None if layout in ("fsdp_only", "replicated") else "model"
    return P(dp, None, vocab_ax)


def build_jitted(cfg, shape, step_kind, mesh, layout, *, unroll, remat,
                 fl_fraction=0.5, fl_synchronized=False, fl_clients=None,
                 fl_topology="hub", fl_edges=None, fl_async_buffer=0,
                 fl_strategy="uniform", loss_overrides=None):
    """Returns (jitted, args, tokens_processed, is_train, extra_record)."""
    from ..models import layers as _layers
    _layers.set_logits_partition(
        logits_pspec(layout, mesh, shape, step_kind)
        if step_kind != "fl_round" else None)
    params = specs.params_sds(cfg)
    p_sh = specs.param_shardings(cfg, mesh, params, layout)
    rep = NamedSharding(mesh, P())
    extra: Dict[str, Any] = {}

    if step_kind == "train":
        from ..optim.masked import adam_init
        opt = jax.eval_shape(adam_init, params)
        opt_sh = specs.opt_shardings(p_sh, mesh)
        batch = specs.batch_specs(cfg, shape)
        b_sh = specs.batch_shardings(cfg, shape, mesh, layout)
        kw = default_loss_kwargs(cfg, remat=remat, unroll=unroll)
        kw.update(loss_overrides or {})
        fn = make_train_step(cfg, loss_kwargs=kw)
        jitted = CompileGuard(fn, name="dryrun_train", max_programs=1,
                              in_shardings=(p_sh, opt_sh, b_sh),
                              out_shardings=(p_sh, opt_sh, rep))
        return jitted, (params, opt, batch), \
            shape.global_batch * shape.seq_len, True, extra
    if step_kind == "prefill":
        batch = specs.batch_specs(cfg, shape)
        b_sh = specs.batch_shardings(cfg, shape, mesh, layout)
        cache = specs.cache_sds(cfg, shape)
        c_sh = specs.cache_shardings(cfg, shape, mesh, cache)
        kw = default_loss_kwargs(cfg, unroll=unroll)
        kw.update(loss_overrides or {})
        fn = make_prefill_step(cfg, shape, loss_kwargs=kw)
        jitted = CompileGuard(fn, name="dryrun_prefill", max_programs=1,
                              in_shardings=(p_sh, b_sh),
                              out_shardings=(rep, c_sh))
        return jitted, (params, batch), \
            shape.global_batch * shape.seq_len, False, extra
    if step_kind == "decode":
        cache = specs.cache_sds(cfg, shape)
        c_sh = specs.cache_shardings(cfg, shape, mesh, cache)
        token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        t_sh = specs.token_shardings(cfg, shape, mesh)
        fn = make_decode_step(cfg, unroll=unroll)
        jitted = CompileGuard(fn, name="dryrun_decode", max_programs=1,
                              in_shardings=(p_sh, c_sh, t_sh),
                              out_shardings=(rep, c_sh))
        return jitted, (params, cache, token), shape.global_batch, False, \
            extra
    if step_kind == "fl_round":
        c = fl_clients
        fn, assign, fl = make_fl_round_step(
            cfg, n_clients=c, train_fraction=fl_fraction,
            strategy=fl_strategy,
            synchronized=fl_synchronized, topology=fl_topology,
            n_edges=fl_edges,
            loss_kwargs=default_loss_kwargs(cfg, remat=remat, unroll=unroll))
        extra["fl"] = {"n_clients": c, "n_units": assign.n_units,
                       "n_train_units": fl.n_train_units,
                       "strategy": fl_strategy,
                       "synchronized": fl_synchronized,
                       "topology": fl_topology}
        if fl_topology == "hierarchical":
            extra["fl"]["n_edges"] = fl.resolve_n_edges()
        if fl_async_buffer:
            # buffered-async mode: the lowering proof is the FLUSH
            # program — the topology's scatter-accumulate over a
            # (B, ...) stacked buffer of packed trained-slot updates
            # (core/async_agg.py); clients' local programs are the
            # packed cohort step already proven by the sync fl_round
            from ..core.async_agg import flush_arg_specs
            from ..core.topology import resolve_topology
            fl = dataclasses.replace(fl, async_buffer=fl_async_buffer)
            extra["fl"]["async_buffer"] = fl_async_buffer
            flush = resolve_topology(fl_topology).build_buffered_flush(
                assign, fl)
            buf_args = flush_arg_specs(assign, params, fl)
            jitted = CompileGuard(
                flush, name="dryrun_async_flush", max_programs=1,
                in_shardings=(p_sh,) + (rep,) * len(buf_args),
                out_shardings=p_sh)
            return jitted, (params,) + buf_args, \
                fl_async_buffer * shape.seq_len, False, extra
        # hierarchical meshes split the flat client dim edge-major
        client_axes = ("edge", "client") if "edge" in mesh.axis_names \
            else "client"
        if fl_topology == "gossip":
            # stateful topology: per-client replicas, client-sharded
            params = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((c,) + s.shape, s.dtype),
                params)
            p_sh = jax.tree_util.tree_map(
                lambda sh: NamedSharding(mesh, P(client_axes, *sh.spec)),
                p_sh)
        b_per = max(shape.global_batch // c, 1)
        bspec = specs.batch_specs(
            cfg, dataclasses.replace(shape, global_batch=b_per))
        batch = {k: jax.ShapeDtypeStruct((c, 1) + v.shape, v.dtype)
                 for k, v in ((k, v) for k, v in bspec.items())}
        weights = jax.ShapeDtypeStruct((c,), jnp.float32)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        b_sh = jax.tree_util.tree_map(
            lambda v: NamedSharding(mesh, P(client_axes, None, "data",
                                            *(None,) * (v.ndim - 3))), batch)
        args = (params, batch, weights, key)
        in_sh = (p_sh, b_sh, rep, rep)
        from ..core.strategies import SelectionState, resolve_strategy
        if resolve_strategy(fl_strategy, fl_synchronized).stateful:
            # scored strategies: the round step takes the live
            # SelectionState as a fifth (replicated, tiny) argument and
            # returns the per-unit norm telemetry in the metrics — the
            # lowering proof must cover that variant of the program
            u = assign.n_units
            args = args + (SelectionState(
                scores=jax.ShapeDtypeStruct((u,), jnp.float32),
                counts=jax.ShapeDtypeStruct((u,), jnp.float32),
                round=jax.ShapeDtypeStruct((), jnp.int32)),)
            in_sh = in_sh + (rep,)
            extra["fl"]["scored"] = True
        jitted = CompileGuard(fn, name="dryrun_fl_round", max_programs=1,
                              in_shardings=in_sh,
                              out_shardings=(p_sh, None))
        return jitted, args, b_per * c * shape.seq_len, True, extra
    raise ValueError(step_kind)


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               step_kind: str = "auto", layout: Optional[str] = None,
               fl_fraction: float = 0.5, fl_synchronized: bool = False,
               fl_topology: str = "hub", fl_async_buffer: int = 0,
               fl_strategy: str = "uniform",
               lower_only: bool = False, remat: bool = True,
               skip_accounting: bool = False,
               verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}
    if step_kind == "auto":
        step_kind = {"train": "train", "prefill": "prefill",
                     "decode": "decode"}[shape.kind]
    layout = layout or layout_for(cfg)
    if (step_kind == "decode" and cfg.family != "ssm"
            and cfg.n_kv_heads % 16 != 0 and not layout.endswith("_hd")):
        # kv-heads don't divide the model axis: move attention TP to the
        # head_dim so q matches the hd-sharded KV cache (rules.py).
        layout = layout + "_hd"
    t0 = time.time()
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": step_kind, "layout": layout, "skipped": False,
    }
    fl_clients = cfg.fl_clients_single_pod * (2 if multi_pod else 1)
    fl_edges = None
    if step_kind == "fl_round":
        if fl_topology == "hierarchical":
            from ..core.federation import FLConfig
            fl_edges = FLConfig(n_clients=fl_clients).resolve_n_edges()
            while cfg.fl_clients_single_pod % fl_edges:  # mesh needs even
                fl_edges -= 1                            # edge groups
            mesh = make_hier_fl_mesh(fl_edges, cfg.fl_clients_single_pod,
                                     multi_pod=multi_pod)
        else:
            mesh = make_fl_mesh(cfg.fl_clients_single_pod,
                                multi_pod=multi_pod)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    record["chips"] = chips

    counts = param_counts(cfg, specs.params_sds(cfg))
    record.update({"n_params": counts["total"],
                   "n_params_active": counts["active"]})

    # --- 1. full-config scan compile: the lowering proof + memory ------
    jitted, args, tokens, train, extra = build_jitted(
        cfg, shape, step_kind, mesh, layout, unroll=False, remat=remat,
        fl_fraction=fl_fraction, fl_synchronized=fl_synchronized,
        fl_clients=fl_clients, fl_topology=fl_topology, fl_edges=fl_edges,
        fl_async_buffer=fl_async_buffer, fl_strategy=fl_strategy)
    record.update(extra)
    with mesh:
        lowered = jitted.lower(*args)
        record["lower_s"] = round(time.time() - t0, 1)
        if lower_only:
            return record
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0 - record["lower_s"], 1)
        # the smoke gate's retrace contract: the whole dry run lowered
        # exactly one program for this step kind
        jitted.assert_programs(1)
    ma = roofline.memory_analysis_terms(compiled)
    record["memory_analysis"] = ma
    record["bytes_per_device"] = ma["peak_bytes"]
    record["fits_hbm_16gb"] = bool(ma["peak_bytes"] <= 16e9)

    if skip_accounting:
        record["total_s"] = round(time.time() - t0, 1)
        return record

    # --- 2. cost accounting: 1-macro / 2-macro unrolled compiles --------
    cfg1, cfg2, nm = depth_variants(cfg)
    acct = []
    for c in (cfg1, cfg2):
        j, a, _, _, _ = build_jitted(
            c, shape, step_kind, mesh, layout, unroll=True, remat=remat,
            fl_fraction=fl_fraction, fl_synchronized=fl_synchronized,
            fl_clients=fl_clients, fl_topology=fl_topology,
            fl_edges=fl_edges, fl_async_buffer=fl_async_buffer,
            fl_strategy=fl_strategy)
        with mesh:
            comp = j.lower(*a).compile()
        acct.append((roofline.cost_analysis_terms(comp),
                     roofline.collective_bytes(comp.as_text())))
    (ca1, cb1), (ca2, cb2) = acct
    ex = roofline.extrapolate_layers
    flops = ex(ca1["flops"], ca2["flops"], nm)
    hbytes = ex(ca1["bytes"], ca2["bytes"], nm)
    coll = {k: max(ex(cb1[k], cb2[k], nm), 0.0) for k in cb1}
    terms = roofline.roofline_terms(hlo_flops=flops, hlo_bytes=hbytes,
                                    coll_bytes=coll["total"])
    mf = roofline.model_flops(cfg, counts["total"], counts["active"],
                              tokens, train=train)
    record.update({
        "cost_analysis": {"flops_per_device": flops,
                          "bytes_per_device": hbytes,
                          "raw_2macro": ca1, "raw_3macro": ca2},
        "collective_bytes": coll,
        "roofline": terms,
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / chips / flops) if flops else None,
        "total_s": round(time.time() - t0, 1),
    })
    if verbose:
        print(f"[{arch} × {shape_name} × {record['mesh']} × {step_kind}] "
              f"lower {record['lower_s']}s compile {record['compile_s']}s "
              f"total {record['total_s']}s")
        print(f"  params {counts['total']/1e9:.2f}B "
              f"(active {counts['active']/1e9:.2f}B)  layout {layout}")
        print(f"  memory/device: arg {ma['argument_size_in_bytes']/1e9:.2f}GB"
              f" temp {ma['temp_size_in_bytes']/1e9:.2f}GB"
              f" out {ma['output_size_in_bytes']/1e9:.2f}GB"
              f" peak {ma['peak_bytes']/1e9:.2f}GB"
              f" fits16GB={record['fits_hbm_16gb']}")
        print(f"  per-device: {flops:.3e} FLOPs, {hbytes:.3e} B HBM, "
              f"{coll['total']/1e9:.3f} GB coll "
              f"(ar {coll['all-reduce']/1e9:.2f} ag {coll['all-gather']/1e9:.2f}"
              f" rs {coll['reduce-scatter']/1e9:.2f}"
              f" a2a {coll['all-to-all']/1e9:.2f})")
        r = terms
        print(f"  roofline: compute {r['compute_s']*1e3:.2f}ms "
              f"memory {r['memory_s']*1e3:.2f}ms "
              f"collective {r['collective_s']*1e3:.2f}ms "
              f"-> {r['dominant']}-bound; useful-FLOP ratio "
              f"{round(record['useful_flops_ratio'], 3)}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--step", default="auto",
                    choices=["auto", "train", "prefill", "decode", "fl_round"])
    ap.add_argument("--layout", default=None)
    ap.add_argument("--fl-fraction", type=float, default=0.5)
    ap.add_argument("--fl-synchronized", action="store_true")
    ap.add_argument("--fl-topology", default="hub",
                    choices=["hub", "hierarchical", "gossip"])
    ap.add_argument("--fl-strategy", default="uniform",
                    help="registered selection strategy; stateful "
                         "(scored) strategies lower the round step with "
                         "its SelectionState argument + norm telemetry")
    ap.add_argument("--fl-async-buffer", type=int, default=0,
                    help="compile the buffered-async FLUSH program "
                         "(B stacked packed updates) instead of the "
                         "sync round step")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--skip-accounting", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    rec = run_dryrun(args.arch, args.shape, multi_pod=(args.mesh == "multi"),
                     step_kind=args.step, layout=args.layout,
                     fl_fraction=args.fl_fraction,
                     fl_synchronized=args.fl_synchronized,
                     fl_topology=args.fl_topology,
                     fl_strategy=args.fl_strategy,
                     fl_async_buffer=args.fl_async_buffer,
                     lower_only=args.lower_only, remat=not args.no_remat,
                     skip_accounting=args.skip_accounting)
    os.makedirs(args.out, exist_ok=True)
    suffix = "" if args.step == "auto" else f"_{args.step}"
    path = os.path.join(
        args.out, f"{args.arch}_{args.shape}_{args.mesh}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
