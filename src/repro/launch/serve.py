"""Serving launcher: batched prefill + autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-1.7b --reduced --batch 4 --prompt-len 32 --gen 16

Runs for real on this host with a reduced config; the same step functions
lower for the production mesh in the dry-run (decode_32k / long_500k).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config, list_configs
from ..models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_configs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)

    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                                 cfg.vocab)
    extra = {}
    if cfg.family == "vlm":
        from ..models.transformer import vit_width
        extra["patches"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.n_patches, vit_width(cfg)))
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(
            jax.random.fold_in(key, 3), (b, cfg.enc_seq, cfg.d_model))

    max_len = s + args.gen + 8 + (cfg.n_patches if cfg.family == "vlm"
                                  else 0)
    kw = {"attn_impl": "reference"} if cfg.family != "ssm" else {}
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=max_len, last_only=True,
                                   **extra, **kw))(params, prompts)
    print(f"prefill {b}x{s}: {time.time()-t0:.2f}s "
          f"(cache step={int(cache['step'])})")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits[:, -1] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        tok = tok.astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"decoded {args.gen} tokens x {b} seqs in {dt:.2f}s "
          f"({args.gen * b / max(dt, 1e-9):.1f} tok/s on CPU)")
    for i in range(min(b, 2)):
        print(f"  seq{i}: {gen[i].tolist()}")


if __name__ == "__main__":
    main()
