"""Serving launcher: static-batch loop or the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen3-1.7b --reduced --batch 4 --prompt-len 32 --gen 16

    PYTHONPATH=src python -m repro.launch.serve --engine continuous \
        --arch qwen3-1.7b --reduced --batch 4 --requests 12 \
        --prompt-len 32 --gen 16 --gen-spread 8

``--engine static`` runs the fixed-batch prefill+decode reference loop
(``serve.engine.static_generate``); ``--engine continuous`` routes the
same requests through the paged continuous-batching engine (DESIGN.md
§12) with ``--batch`` decode slots.  Both sample every token — including
the first — reproducibly from ``--seed`` when ``--temperature`` > 0.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config, list_configs
from ..models import get_model
from ..serve.engine import DecodeEngine, ServeConfig, static_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_configs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", default="static",
                    choices=("static", "continuous"))
    ap.add_argument("--batch", type=int, default=4,
                    help="static: batch size; continuous: decode slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    # continuous-engine knobs
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous: total requests (default: --batch)")
    ap.add_argument("--gen-spread", type=int, default=0,
                    help="continuous: request i generates gen + i %% spread")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page pool size (0 = auto, no oversubscription)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)

    b, s = args.batch, args.prompt_len
    n_req = args.requests or b
    prompts = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1), (max(b, n_req), s), 0, cfg.vocab))
    extra = {}
    if cfg.family == "vlm":
        from ..models.transformer import vit_width
        extra["patches"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.n_patches, vit_width(cfg)))
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(
            jax.random.fold_in(key, 3), (b, cfg.enc_seq, cfg.d_model))

    gens = [args.gen + (i % args.gen_spread if args.gen_spread else 0)
            for i in range(n_req)]
    max_len = s + max(gens) + 8 + (cfg.n_patches if cfg.family == "vlm"
                                   else 0)

    if args.engine == "continuous":
        sv = ServeConfig(n_slots=b, max_len=max_len,
                         page_size=args.page_size, n_pages=args.pool_pages,
                         temperature=args.temperature, seed=args.seed)
        eng = DecodeEngine(cfg, params, sv)
        for i in range(n_req):
            eng.submit(prompts[i], gens[i])
        t0 = time.time()
        results = eng.run()
        dt = time.time() - t0
        st = eng.stats()
        print(f"continuous: {n_req} requests x {b} slots, "
              f"{st['total_tokens']} tokens in {dt:.2f}s "
              f"({st['tokens_per_sec']:.1f} tok/s incl. compile), "
              f"{st['n_decode_steps']} decode steps, "
              f"{st['n_preemptions']} preemptions, "
              f"peak pages {st['peak_pages']}/{st['n_pages'] - 1}")
        for i in range(min(n_req, 2)):
            print(f"  req{i}: {results[i].tolist()}")
        return

    t0 = time.time()
    out = static_generate(cfg, params, jnp.asarray(prompts[:b]), args.gen,
                          max_len=max_len, temperature=args.temperature,
                          seed=args.seed, extra=extra)
    dt = time.time() - t0
    print(f"static: prefill {b}x{s} + {args.gen} tokens/seq in {dt:.2f}s "
          f"({args.gen * b / max(dt, 1e-9):.1f} tok/s incl. compile)")
    for i in range(min(b, 2)):
        print(f"  seq{i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
