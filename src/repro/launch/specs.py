"""ShapeDtypeStruct input specs + shardings for every (arch × shape).

``input_specs(cfg, shape)`` returns weak-type-correct stand-ins for every
model input — no device allocation; the dry-run lowers against these.
``*_shardings`` resolve NamedShardings on a given mesh for params,
optimizer state, batches and KV caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..common import pytree as pt
from ..configs.base import ArchConfig
from ..models import get_model
from ..models.transformer import vit_width
from ..sharding import params_specs, layout_for
from .shapes import InputShape

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: InputShape, dtype=None
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill batch stand-ins (tokens/labels [+frontend stubs])."""
    dtype = dtype or jnp.dtype(cfg.lowering_dtype)
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        s_text = s - cfg.n_patches
        out["tokens"] = _sds((b, s_text), I32)
        out["labels"] = _sds((b, s_text), I32)
        out["patches"] = _sds((b, cfg.n_patches, vit_width(cfg)), dtype)
    elif cfg.family == "audio":
        out["tokens"] = _sds((b, s), I32)
        out["labels"] = _sds((b, s), I32)
        out["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), dtype)
    else:
        out["tokens"] = _sds((b, s), I32)
        out["labels"] = _sds((b, s), I32)
    return out


def params_sds(cfg: ArchConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.lowering_dtype)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: model.init_params(k, dtype), key)


def cache_sds(cfg: ArchConfig, shape: InputShape, dtype=None):
    dtype = dtype or jnp.dtype(cfg.lowering_dtype)
    model = get_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, dtype))


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _dp_axes(mesh)]))


def batch_shardings(cfg, shape, mesh, layout: Optional[str] = None) -> Any:
    dp = _dp_axes(mesh)
    if layout == "fsdp_only" and "model" in mesh.shape:
        dp = dp + ("model",)      # pure-DP layout: batch over every axis
    b = shape.global_batch
    size = int(np.prod([mesh.shape[a] for a in dp]))
    lead = dp if b % size == 0 else (dp[:-1] if b % int(
        np.prod([mesh.shape[a] for a in dp[:-1]] or [1])) == 0 and dp[:-1]
        else None)

    def spec(path, leaf):
        return NamedSharding(mesh, P(lead, *(None,) * (leaf.ndim - 1)))

    return pt.tree_map_with_path(spec, batch_specs(cfg, shape))


def param_shardings(cfg, mesh, params, layout: Optional[str] = None):
    layout = layout or layout_for(cfg)
    specs = params_specs(params, layout, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def opt_shardings(param_sh, mesh):
    """AdamState(mu, nu, count): moments follow params; count replicated."""
    from ..optim.masked import AdamState
    return AdamState(mu=param_sh, nu=param_sh,
                     count=NamedSharding(mesh, P()))


def cache_shardings(cfg, shape, mesh, cache) -> Any:
    """Shard KV caches: batch over data axes, seq over model; fall back to
    sharding seq over everything when batch is unshardable (long_500k)."""
    dp = _dp_axes(mesh)
    dp_n = _dp_size(mesh)
    model_n = mesh.shape.get("model", 1)

    def spec(path, leaf):
        name = path.split("/")[-1]
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        axes = [None] * leaf.ndim
        if name in ("k", "v", "xk", "xv"):           # (nm, B, A, hkv, hd)
            bdim, hkv, hd = leaf.shape[1], leaf.shape[3], leaf.shape[4]
            if bdim % dp_n == 0:
                axes[1] = dp
            # model axis goes on kv-heads (matches the TP q sharding with
            # zero resharding) or head_dim; NEVER the seq dim — a
            # head-sharded q against a seq-sharded cache makes GSPMD
            # all-gather the whole cache (observed 60 GB/device).
            if hkv % model_n == 0:
                axes[3] = "model"
            elif hd % model_n == 0:
                axes[4] = "model"
        else:                                        # states: shard batch only
            if leaf.ndim >= 2 and leaf.shape[1] % dp_n == 0:
                axes[1] = dp
        return NamedSharding(mesh, P(*axes))

    return pt.tree_map_with_path(spec, cache)


def token_shardings(cfg, shape, mesh):
    """(B, 1) decode token."""
    dp = _dp_axes(mesh)
    lead = dp if shape.global_batch % _dp_size(mesh) == 0 else None
    return NamedSharding(mesh, P(lead, None))
