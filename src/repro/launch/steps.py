"""Step builders: the functions the dry-run lowers and the launchers run.

* ``make_train_step``   — fwd+bwd+masked-Adam (remat per macro-block)
* ``make_prefill_step`` — prefill with last-token logits + KV cache build
* ``make_decode_step``  — ONE new token against a seq_len KV cache
* ``make_fl_round_step``— the paper's federated round (core.federation)
  over the (client, data, model) mesh view; client_batches (C, 1, b, S)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.federation import FLConfig, build_round_step
from ..core.masking import build_units_zoo
from ..models import get_model
from ..optim.masked import adam_init, adam_step
from .shapes import InputShape


def default_loss_kwargs(cfg: ArchConfig, shape: Optional[InputShape] = None,
                        *, remat: bool = True,
                        unroll: bool = False) -> Dict[str, Any]:
    # unroll=True fully unrolls the layer scan: required for honest
    # cost_analysis/collective accounting in the dry-run (XLA counts a
    # while-loop body once); CPU tests keep the compact scan.
    kw: Dict[str, Any] = {"remat": remat, "unroll": unroll}
    if cfg.family != "ssm":
        kw["attn_impl"] = "chunked"
        kw["q_chunk"] = 1024
    return kw


def make_train_step(cfg: ArchConfig, *, lr: float = 3e-4,
                    remat: bool = True, loss_kwargs: Optional[Dict] = None):
    model = get_model(cfg)
    kw = loss_kwargs if loss_kwargs is not None else \
        default_loss_kwargs(cfg, remat=remat)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch, **kw)
        params, opt_state = adam_step(grads, opt_state, params, lr=lr)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ArchConfig, shape: InputShape,
                      loss_kwargs: Optional[Dict] = None):
    model = get_model(cfg)
    kw = dict(loss_kwargs or {})
    kw.pop("remat", None)
    if cfg.family == "ssm":
        kw.pop("attn_impl", None)
        kw.pop("q_chunk", None)

    def prefill_step(params, batch):
        extra = {}
        if cfg.family == "vlm":
            extra["patches"] = batch["patches"]
        if cfg.family == "audio":
            extra["frames"] = batch["frames"]
        logits, cache = model.prefill(params, batch["tokens"],
                                      max_len=shape.seq_len,
                                      last_only=True, **extra, **kw)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, *, unroll: bool = False):
    from ..models import _FAMILY
    mod = _FAMILY[cfg.family]

    def decode_step(params, cache, token):
        return mod.decode_step(cfg, params, cache, token, unroll=unroll)

    return decode_step


def make_fl_round_step(cfg: ArchConfig, *, n_clients: int,
                       train_fraction: float = 0.5,
                       strategy: str = "uniform",
                       synchronized: bool = False, lr: float = 3e-4,
                       topology: str = "hub",
                       n_edges: Optional[int] = None,
                       loss_kwargs: Optional[Dict] = None):
    """The paper's technique at pod scale: one compiled federated round.

    ``topology`` picks the registered federation topology; hierarchical
    gets ``n_edges`` edge aggregators (default ~sqrt of the clients).
    """
    model = get_model(cfg)
    params_shape = jax.eval_shape(
        lambda k: model.init_params(k, jnp.dtype(cfg.lowering_dtype)),
        jax.random.PRNGKey(0))
    assign = build_units_zoo(cfg, params_shape)
    from ..core.freezing import n_train_from_fraction
    fl = FLConfig(
        n_clients=n_clients,
        n_train_units=n_train_from_fraction(assign.n_units, train_fraction),
        strategy=strategy, synchronized=synchronized, lr=lr,
        topology=topology, n_edges=n_edges)
    kw = loss_kwargs if loss_kwargs is not None else \
        default_loss_kwargs(cfg, remat=True)
    return build_round_step(model.loss_fn, assign, fl, loss_kwargs=kw), \
        assign, fl
