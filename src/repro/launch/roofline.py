"""Roofline terms from a compiled dry-run artifact (no hardware needed).

  compute    = HLO_FLOPs / (chips · 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips · 819e9 B/s HBM)
  collective = collective_bytes / (chips · 50e9 B/s ICI per link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from the optimized HLO text — the sum of RESULT sizes
of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (result size ≈ bytes received per participating
device; the standard conservative proxy).  MODEL_FLOPS = 6·N·D (dense) /
6·N_active·D (MoE) gives the useful-compute ratio.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

import numpy as np

# TPU v5e, per chip
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result sizes per collective kind over the optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(type_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def model_flops(cfg, n_params_total: int, n_params_active: Optional[int],
                tokens: int, *, train: bool) -> float:
    """6·N·D (train) / 2·N·D (inference forward) per processed token."""
    n = n_params_active if n_params_active else n_params_total
    mult = 6.0 if train else 2.0
    return mult * n * tokens


def roofline_terms(*, hlo_flops: float, hlo_bytes: float,
                   coll_bytes: float, chips: int = 1) -> Dict[str, float]:
    """All inputs are PER-DEVICE (XLA compiles and analyses the per-device
    SPMD program — verified in EXPERIMENTS.md §Methodology), so the chip
    count is already divided out; ``chips`` is accepted for callers that
    pass global quantities."""
    compute = hlo_flops / (chips * PEAK_FLOPS)
    memory = hlo_bytes / (chips * HBM_BW)
    collective = coll_bytes / (chips * ICI_BW)
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant}


def extrapolate_layers(v_a: float, v_b: float, n_macro: int,
                       a: int = 2) -> float:
    """Per-device cost of the full depth from a-macro and (a+1)-macro
    compiles: v(n) = v_a + (n-a)·(v_b-v_a).

    Costs are layer-affine (all assigned archs are layer-homogeneous per
    macro).  Anchors default to depths (2, 3): the 1-layer compile trips
    degenerate GSPMD decisions (logits gathers) that don't represent the
    deep model.  Exact for collective bytes, within ~10% for FLOPs vs a
    full unroll (EXPERIMENTS.md §Methodology)."""
    return v_a + (n_macro - a) * (v_b - v_a)


def cost_analysis_terms(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_analysis_terms(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = float(getattr(ma, k, 0) or 0)
    out["peak_bytes"] = (out["argument_size_in_bytes"]
                         + out["temp_size_in_bytes"]
                         + out["output_size_in_bytes"]
                         - out["alias_size_in_bytes"])
    return out
