"""Opt-in production launch profile: XLA flags + allocator env.

The training CLI is tuned for debuggability by default (no XLA flag
overrides, default malloc).  For long fleet-scale runs the olmax-style
profile below buys measurable wall-clock: the latency-hiding scheduler
overlaps collective communication with compute, a large all-reduce
combine threshold batches small aggregation collectives into one ring
pass, and tcmalloc avoids glibc-malloc arena contention when the host
side streams cohort chunks from many loader threads.

Async collectives themselves need no flag on this XLA version — the
old ``--xla_gpu_enable_async_collectives`` /
``--xla_gpu_enable_highest_priority_async_stream`` switches were
removed upstream and async is the default; passing them aborts the
process at XLA-flag parse time, which is why they are absent here.

``LD_PRELOAD`` cannot take effect in an already-running interpreter,
so ``--prod-env`` re-execs the launcher under the built environment
(guarded by ``REPRO_PROD_ENV`` so the exec happens exactly once).
"""
from __future__ import annotations

import os
import sys
from typing import Dict, List, Optional, Sequence

# Every flag here must parse under the pinned jaxlib: XLA calls
# ``LOG(FATAL)`` on unknown XLA_FLAGS entries, so a stale flag does not
# degrade gracefully — it kills the launcher.  test_env.py smoke-checks
# the set against the live backend.
PROD_XLA_FLAGS: Sequence[str] = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_all_reduce_combine_threshold_bytes=134217728",
    "--xla_gpu_enable_while_loop_double_buffering=true",
)

# Debian/Ubuntu spellings, most specific first.  Only an existing path
# is ever placed in LD_PRELOAD: preloading a missing .so makes the
# dynamic linker print a warning per exec'd child, including every
# subprocess the benchmarks spawn.
TCMALLOC_PATHS: Sequence[str] = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)

# Guard variable: set in the child environment by reexec_under_prod_env
# so the re-exec'd launcher recognises the profile is already applied.
GUARD_VAR = "REPRO_PROD_ENV"


def _find_tcmalloc() -> Optional[str]:
    for path in TCMALLOC_PATHS:
        if os.path.exists(path):
            return path
    return None


def _merge_xla_flags(existing: str, extra: Sequence[str]) -> str:
    """Append ``extra`` to an XLA_FLAGS string without clobbering.

    A flag the user already set (by name) wins over the profile's
    value — ``--prod-env`` tunes defaults, it does not override
    explicit operator choices.
    """
    merged: List[str] = [f for f in existing.split() if f]
    have = {f.split("=", 1)[0] for f in merged}
    for flag in extra:
        if flag.split("=", 1)[0] not in have:
            merged.append(flag)
    return " ".join(merged)


def production_env(base: Optional[Dict[str, str]] = None, *,
                   tcmalloc: bool = True) -> Dict[str, str]:
    """Build the production environment dict (pure; no process mutation).

    Starts from ``base`` (default: a copy of ``os.environ``) and layers
    the profile on top.  User-set XLA flags are preserved; an existing
    LD_PRELOAD keeps its entries with tcmalloc appended.
    """
    env = dict(os.environ if base is None else base)
    env["XLA_FLAGS"] = _merge_xla_flags(env.get("XLA_FLAGS", ""),
                                        PROD_XLA_FLAGS)
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    if tcmalloc:
        so = _find_tcmalloc()
        if so is not None:
            preload = [p for p in env.get("LD_PRELOAD", "").split(":") if p]
            if so not in preload:
                preload.append(so)
            env["LD_PRELOAD"] = ":".join(preload)
            # Silence tcmalloc's large-alloc warnings: chunked cohort
            # streaming intentionally makes multi-GB host allocations.
            env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                           "60000000000")
    env[GUARD_VAR] = "1"
    return env


def reexec_under_prod_env(module: str, argv: Sequence[str], *,
                          tcmalloc: bool = True) -> None:
    """Replace this process with ``python -m module argv`` under the
    production environment.  No-op when the guard variable shows the
    profile is already active (the re-exec'd child lands here again
    with the same --prod-env flag on its command line)."""
    if os.environ.get(GUARD_VAR):
        return
    env = production_env(tcmalloc=tcmalloc)
    os.execve(sys.executable,
              [sys.executable, "-m", module, *argv], env)
