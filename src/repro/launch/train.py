"""Federated training launcher (runs for real on this host at reduced
scale; on a pod the same code runs under the production mesh).

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-1.7b --reduced --clients 4 --rounds 20 \
        --train-fraction 0.5 [--strategy uniform|fixed_last|full|
                              score_weighted|depth_dropout|successive|...]
        [--score-ema 0.9 --score-every 1]
        [--synchronized] [--topology hub|hierarchical|gossip [--edges 2]]
        [--packed] [--fused-agg auto|on|off] [--ckpt results/ck/run1]
        [--async-buffer 4 --staleness polynomial --delay-dist pareto:1.5]
        [--registered 100000 --cohort-chunk 2 --client-sampler uniform|
         loss_proportional|telemetry_driven] [--client-shards 2]
        [--history-cap 64] [--prod-env]

Drives the paper's federated round (per-client layer subsets from the
registered strategy, masked local Adam, participation-weighted FedAvg)
over synthetic LM data partitioned IID across clients — all through the
``Federation`` facade.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from ..configs.base import get_config, list_configs
from ..core import (Checkpointer, FLConfig, Federation,
                    registered_client_samplers, registered_strategies,
                    registered_topologies)
from ..data import FederatedLoader, iid_partition, lm_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_configs())
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (required on this CPU host)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--train-fraction", type=float, default=0.5)
    ap.add_argument("--strategy", default="uniform",
                    choices=registered_strategies())
    ap.add_argument("--score-ema", type=float, default=0.9,
                    help="EMA decay of the per-unit gradient-norm "
                         "scores a stateful strategy (score_weighted, "
                         "depth_dropout, successive) maintains")
    ap.add_argument("--score-every", type=int, default=1,
                    help="fold norm telemetry into the selection state "
                         "every N rounds/flushes")
    ap.add_argument("--synchronized", action="store_true")
    ap.add_argument("--topology", default="hub",
                    choices=registered_topologies())
    ap.add_argument("--edges", type=int, default=None,
                    help="edge aggregators (hierarchical; default ~sqrt)")
    ap.add_argument("--packed", action="store_true",
                    help="packed trained-unit round path (DESIGN.md §7)")
    ap.add_argument("--fused-agg", default="auto",
                    choices=("auto", "on", "off"),
                    help="fused Pallas aggregation (kernels/masked_agg)")
    ap.add_argument("--fedprox-mu", type=float, default=0.0)
    ap.add_argument("--async-buffer", type=int, default=0,
                    help="FedBuff-style semi-async rounds: flush the "
                         "global model every N buffered updates (0=sync)")
    ap.add_argument("--staleness", default="polynomial",
                    help="stale-delta reweighting rule (registered in "
                         "core/async_agg.py)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5)
    ap.add_argument("--delay-dist", default="pareto:1.5",
                    help="simulated client-latency distribution for "
                         "async rounds: none|exponential[:s]|"
                         "lognormal[:s]|pareto[:a]")
    ap.add_argument("--registered", type=int, default=0,
                    help="registered fleet size: sample --clients "
                         "participants per round from this many "
                         "registered clients (0 = fleet == cohort)")
    ap.add_argument("--cohort-chunk", type=int, default=0,
                    help="stream the cohort through the round step in "
                         "chunks of this many clients (0 = whole "
                         "cohort in one shot); bounds host memory")
    ap.add_argument("--client-sampler", default="uniform",
                    choices=registered_client_samplers(),
                    help="per-round cohort draw from the registered "
                         "fleet (core/cohort.py registry)")
    ap.add_argument("--client-shards", type=int, default=0,
                    help="shard_map the cohort over this many device "
                         "groups on the mesh client axis (0 = vmap)")
    ap.add_argument("--history-cap", type=int, default=0,
                    help="retain at most N rounds of selection history; "
                         "older rounds fold into O(1) accounting "
                         "totals (0 = unbounded)")
    ap.add_argument("--prod-env", action="store_true",
                    help="re-exec under the production launch profile "
                         "(launch/env.py: latency-hiding scheduler, "
                         "combined collectives, tcmalloc)")
    ap.add_argument("--faults", default="",
                    help="fault-injection chaos spec, e.g. "
                         "'crash:0.1,nan:0.05,kill:0.02' (core/faults.py;"
                         " delta faults need --packed)")
    ap.add_argument("--max-delta-norm", type=float, default=0.0,
                    help="quarantine packed updates whose delta norm "
                         "exceeds this (0 = isfinite gate only)")
    ap.add_argument("--drop-prob", type=float, default=0.0,
                    help="per-dispatch in-transit loss probability "
                         "(async mode only)")
    ap.add_argument("--codec", default="none",
                    help="uplink compression codec for packed trained-"
                         "slot deltas (core/codecs.py): none, qint8, "
                         "qint4, topk_ef")
    ap.add_argument("--codec-topk", type=float, default=0.1,
                    help="kept-coordinate fraction for the topk_ef codec")
    ap.add_argument("--fault-retries", type=int, default=3,
                    help="resample attempts per crashed cohort slot")
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--steps-per-round", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.prod_env:
        # LD_PRELOAD and XLA_FLAGS only take effect at process start:
        # replace this launcher with itself under the profile (no-op
        # in the re-exec'd child — env.GUARD_VAR is set there).
        from .env import reexec_under_prod_env
        reexec_under_prod_env("repro.launch.train", sys.argv[1:])

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n = args.clients * args.batch_size * args.steps_per_round * 8
    data = lm_batch(n, args.seq, cfg.vocab, key=args.seed)
    if cfg.family == "vlm":
        from ..models.transformer import vit_width
        data["patches"] = np.random.default_rng(args.seed).normal(
            0, 1, (n, cfg.n_patches, vit_width(cfg))).astype(np.float32)
    if cfg.family == "audio":
        data["frames"] = np.random.default_rng(args.seed).normal(
            0, 1, (n, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    shards = iid_partition(n, args.clients, key=args.seed + 1)
    client_data = [{k: v[s] for k, v in data.items()} for s in shards]
    if args.registered > args.clients:
        # registered fleet larger than the synthetic corpus: tile the
        # cohort-sized shards (dict views, no copies) so every
        # registered id resolves; per-(round, id) draws stay distinct
        client_data = [client_data[i % args.clients]
                       for i in range(args.registered)]
    loader = FederatedLoader(client_data,
                             batch_size=args.batch_size,
                             steps_per_round=args.steps_per_round,
                             key=args.seed)

    fl = FLConfig(n_clients=args.clients,
                  train_fraction=args.train_fraction,
                  strategy=args.strategy, synchronized=args.synchronized,
                  lr=args.lr, prox_mu=args.fedprox_mu,
                  topology=args.topology, n_edges=args.edges,
                  packed=args.packed, fused_agg=args.fused_agg,
                  async_buffer=args.async_buffer,
                  staleness=args.staleness,
                  staleness_alpha=args.staleness_alpha,
                  client_delay_dist=args.delay_dist,
                  score_ema=args.score_ema, score_every=args.score_every,
                  n_registered=args.registered,
                  cohort_chunk=args.cohort_chunk,
                  client_sampler=args.client_sampler,
                  client_shards=args.client_shards,
                  history_cap=args.history_cap,
                  faults=args.faults,
                  max_delta_norm=args.max_delta_norm,
                  client_drop_prob=args.drop_prob,
                  fault_retries=args.fault_retries,
                  codec=args.codec, codec_topk=args.codec_topk)
    hooks = [Checkpointer(args.ckpt)] if args.ckpt else []
    fed = Federation.from_config(cfg, fl, data=loader, seed=args.seed,
                                 dropout_rate=args.dropout, hooks=hooks)
    print(f"arch={cfg.name} reduced={args.reduced} "
          f"units={fed.assign.n_units} "
          f"train={fl.resolve_n_train(fed.assign.n_units)} "
          f"clients={args.clients} topology={args.topology}" +
          (f" edges={fl.resolve_n_edges()}"
           if args.topology == "hierarchical" else "") +
          (f" async_buffer={fl.async_buffer} staleness={fl.staleness}"
           f" delays={fl.client_delay_dist}" if fl.async_buffer else "") +
          (f" scoring=on ema={fl.score_ema} every={fl.score_every}"
           if fed.server.sel_state is not None else "") +
          (f" fleet={fl.n_registered or args.clients}"
           f" chunk={fl.cohort_chunk or args.clients}"
           f" sampler={fl.client_sampler or 'uniform'}"
           if fl.uses_cohort_engine() else "") +
          (f" client_shards={fl.client_shards}"
           if fl.client_shards else "") +
          (f" faults={fl.faults}" if fl.faults else "") +
          (f" codec={fl.codec}" if fl.codec != "none" else ""))
    t0 = time.time()
    fed.fit(args.rounds, log_every=1)
    print(f"total {time.time()-t0:.1f}s; comm summary:")
    print(json.dumps(fed.comm_summary(), indent=1))
    if args.ckpt:
        print(f"saved server state to {args.ckpt}")


if __name__ == "__main__":
    main()
