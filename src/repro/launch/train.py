"""Federated training launcher (runs for real on this host at reduced
scale; on a pod the same code runs under the production mesh).

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-1.7b --reduced --clients 4 --rounds 20 \
        --train-fraction 0.5 [--strategy uniform|fixed_last|full]
        [--synchronized] [--ckpt results/ck/run1]

Drives the paper's federated round (random per-client layer subsets,
masked local Adam, participation-weighted FedAvg) over synthetic LM data
partitioned IID across clients.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config, list_configs
from ..core import FLConfig, build_round_step, build_units_zoo
from ..core.freezing import n_train_from_fraction
from ..core.server import Server
from ..data import FederatedLoader, iid_partition, lm_batch
from ..models import get_model
from ..ckpt import save_server_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_configs())
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (required on this CPU host)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--train-fraction", type=float, default=0.5)
    ap.add_argument("--strategy", default="uniform",
                    choices=["uniform", "fixed_last", "weighted", "full"])
    ap.add_argument("--synchronized", action="store_true")
    ap.add_argument("--fedprox-mu", type=float, default=0.0)
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--steps-per-round", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)
    assign = build_units_zoo(cfg, params)
    n_train = n_train_from_fraction(assign.n_units, args.train_fraction)
    print(f"arch={cfg.name} reduced={args.reduced} units={assign.n_units} "
          f"train={n_train} clients={args.clients}")

    n = args.clients * args.batch_size * args.steps_per_round * 8
    data = lm_batch(n, args.seq, cfg.vocab, key=args.seed)
    if cfg.family == "vlm":
        from ..models.transformer import vit_width
        data["patches"] = np.random.default_rng(args.seed).normal(
            0, 1, (n, cfg.n_patches, vit_width(cfg))).astype(np.float32)
    if cfg.family == "audio":
        data["frames"] = np.random.default_rng(args.seed).normal(
            0, 1, (n, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    shards = iid_partition(n, args.clients, key=args.seed + 1)
    loader = FederatedLoader([{k: v[s] for k, v in data.items()}
                              for s in shards],
                             batch_size=args.batch_size,
                             steps_per_round=args.steps_per_round,
                             key=args.seed)
    fl = FLConfig(n_clients=args.clients, n_train_units=n_train,
                  strategy=args.strategy, synchronized=args.synchronized,
                  lr=args.lr, prox_mu=args.fedprox_mu)
    srv = Server(build_round_step(model.loss_fn, assign, fl,
                                  loss_kwargs={"attn_impl": "reference"}),
                 assign, fl, params, seed=args.seed,
                 dropout_rate=args.dropout)
    t0 = time.time()
    srv.run(args.rounds, lambda r: jax.tree_util.tree_map(
        jnp.asarray, loader.round_batches(r)),
        weights=jnp.asarray(loader.weights()), log_every=1)
    print(f"total {time.time()-t0:.1f}s; comm summary:")
    print(json.dumps(srv.comm_summary(), indent=1))
    if args.ckpt:
        save_server_state(args.ckpt, srv)
        print(f"saved server state to {args.ckpt}")


if __name__ == "__main__":
    main()
