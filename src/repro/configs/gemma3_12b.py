"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global interleave, 128k context.
[hf:google/gemma-3-1b-pt family card]

Sliding window 1024 on local layers; every 6th layer is global.  head_dim
is 256 (gemma3 decouples it from d_model/n_heads)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=256,
    source="hf:google/gemma-3-1b-pt",
    qk_norm=True,
    sliding_window=1024,
    global_every=6,              # L L L L L G pattern
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_position=131_072,
    fl_clients_single_pod=4,
))
