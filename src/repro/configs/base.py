"""Architecture config system.

Every assigned architecture gets one module in this package defining a
module-level ``CONFIG: ArchConfig``.  Configs are registered by name and
selectable from every launcher via ``--arch <id>``.

``ArchConfig.reduced()`` returns the smoke-test variant (≤2 layers,
d_model ≤ 512, ≤4 experts) of the same family, used by tests and CPU
examples.  The full config is only ever *lowered* (dry-run), never
allocated on this host.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    expert_d_ff: int
    # every `interleave`-th block is MoE (1 = all blocks MoE, 2 = alternate)
    interleave: int = 1
    # llama4-style always-on shared expert width (0 = none)
    shared_d_ff: int = 0
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    # token-drop capacity factor; reduced() raises it to dropless so the
    # prefill+decode path is bit-consistent with the full forward
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMCfg:
    state_dim: int = 16          # N
    conv_width: int = 4
    expand: int = 2              # d_inner = expand * head width share
    dt_rank: int = 0             # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    source: str = ""             # citation (hf:/arXiv:)

    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0        # stablelm-2 uses 25% partial rotary
    # sliding window: 0 = full attention everywhere
    sliding_window: int = 0
    # gemma3: every `global_every`-th layer is global, the rest sliding-window
    global_every: int = 0
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"            # silu | gelu
    glu: bool = True             # gated MLP (False -> 2-matrix MLP, whisper)
    tie_embeddings: bool = False
    max_position: int = 131_072

    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None

    # enc-dec (whisper): encoder layer count; 0 = decoder-only
    n_enc_layers: int = 0
    enc_seq: int = 0             # frame-embedding length fed by the stub frontend
    # vlm: number of patch-embedding tokens fed by the stub frontend
    n_patches: int = 0

    # FL topology on the production pod (see DESIGN.md §5)
    fl_clients_single_pod: int = 16

    param_dtype: str = "float32"      # smoke/training dtype on CPU
    lowering_dtype: str = "bfloat16"  # dry-run dtype (TPU target)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head table rows: vocab rounded up to a multiple of
        128 so the vocab dim shards on the 16-wide model axis and stays
        MXU-aligned (whisper 51865, internvl2 92553, granite 49155 and
        hymba 32001 are odd).  Pad ids are ordinary never-observed
        classes (training from scratch) — DESIGN.md §7."""
        return -(-self.vocab // 128) * 128

    @property
    def subquadratic(self) -> bool:
        """True if long_500k decode is admissible (DESIGN.md §7)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0 or self.global_every > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper has a decoder)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/flavour, toy sizes."""
        kw = dict(
            n_layers=2,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            max_position=4096,
            fl_clients_single_pod=4,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                expert_d_ff=64, shared_d_ff=64 if self.moe.shared_d_ff else 0,
                capacity_factor=8.0)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=8)
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["enc_seq"] = 16
        if self.n_patches:
            kw["n_patches"] = 8
        if self.global_every:
            kw["global_every"] = 2  # keep the local:global interleave alive
            kw["n_layers"] = 4
        if self.sliding_window:
            kw["sliding_window"] = 64
        return self.replace(**kw)


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def _ensure_loaded():
    # import side-effect registration of every config module in this package
    from . import (  # noqa: F401
        stablelm_3b, qwen2_5_14b, llama4_maverick_400b_a17b, gemma3_12b,
        rwkv6_3b, hymba_1_5b, internvl2_26b, qwen3_1_7b, whisper_medium,
        granite_moe_1b_a400m,
    )
