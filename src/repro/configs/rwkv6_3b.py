"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536
— Finch, data-dependent decay.  [arXiv:2404.05892]

Attention heads are re-purposed as WKV heads (head_dim 64 per the paper)."""
from .base import ArchConfig, SSMCfg, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                  # wkv heads, head_dim 64
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    source="arXiv:2404.05892",
    ssm=SSMCfg(state_dim=64),    # wkv state is head_dim x head_dim
    fl_clients_single_pod=16,
))
