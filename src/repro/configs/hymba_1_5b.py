"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention+mamba heads within each
block, fused by per-branch norm + mean.  [arXiv:2411.13676]

Hymba's meta-tokens and cross-layer KV sharing are simplifications we note
in DESIGN.md; sliding-window attention (win 1024) on all but every 8th
layer, per the paper's mostly-SWA layout."""
from .base import ArchConfig, SSMCfg, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    source="arXiv:2411.13676",
    sliding_window=1024,
    global_every=8,
    ssm=SSMCfg(state_dim=16, conv_width=4, expand=2),
    fl_clients_single_pod=16,
))
