"""whisper-medium [audio] — 24L d_model=1024 16H d_ff=4096 vocab=51865 —
encoder-decoder, conv frontend STUBBED.  [arXiv:2212.04356]

input_specs() feeds 1500 precomputed frame embeddings (post-conv, post
mel-spectrogram) per DESIGN.md §7.  Whisper uses LayerNorm, GELU, a
2-matrix MLP, learned positions (no RoPE), tied decoder embeddings."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,                 # decoder layers
    n_enc_layers=24,
    enc_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    source="arXiv:2212.04356",
    norm="layernorm",
    act="gelu",
    glu=False,
    rope_pct=0.0,                # learned absolute positions
    tie_embeddings=True,
    max_position=448,
    fl_clients_single_pod=16,
))
