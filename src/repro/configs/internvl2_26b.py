"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2.  [arXiv:2404.16821]

The InternViT-6B vision tower is STUBBED (DESIGN.md §7): input_specs()
feeds 1024 projected patch embeddings of width d_model, interleaved before
the text tokens.  This config is the InternLM2-20B style language backbone."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    source="arXiv:2404.16821",
    rope_theta=1_000_000.0,
    n_patches=1024,
    fl_clients_single_pod=4,
))
