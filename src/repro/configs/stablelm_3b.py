"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32 i.e. MHA) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b scaled per assignment]
StableLM-2 flavour: LayerNorm, partial rotary (25%), no qkv bias."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    source="hf:stabilityai/stablelm-2-1_6b",
    norm="layernorm",
    rope_pct=0.25,
    fl_clients_single_pod=16,
))
