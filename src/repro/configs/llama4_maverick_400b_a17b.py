"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192(expert) vocab=202048, MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E family card]

Maverick interleaves MoE every other block (interleave=2); dense blocks and
the always-on shared expert use d_ff=16384; routed experts d_ff=8192 (the
assigned figure).  Totals ≈400B params, ≈17B active — matching the card.
"Early fusion" refers to the multimodal frontend, which is out of scope for
the assigned text backbone (cf. DESIGN.md §7)."""
from .base import ArchConfig, MoECfg, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=16384,                  # dense-block / shared-expert width
    vocab=202048,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    rope_theta=500_000.0,
    moe=MoECfg(num_experts=128, top_k=1, expert_d_ff=8192,
               interleave=2, shared_d_ff=16384),
    fl_clients_single_pod=1,     # 400B: one silo per pod (DESIGN.md §5)
))
