"""Pytree utilities shared across the framework.

Params are plain nested dicts of jnp arrays.  Paths are "/"-joined key
tuples (``blocks/attn/wq``) — stable across jax versions and easy to match
with sharding / freeze-unit rules.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def path_str(path) -> str:
    """Render a jax KeyPath as 'a/b/c'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:  # pragma: no cover - future key kinds
            parts.append(str(p))
    return "/".join(parts)


def tree_paths(tree: PyTree) -> Tuple[str, ...]:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return tuple(path_str(p) for p, _ in leaves)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """tree_map where fn receives ('a/b/c', leaf)."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(path_str(p), x), tree)


def flatten_with_paths(tree: PyTree) -> Iterator[Tuple[str, Any]]:
    for p, leaf in jax.tree_util.tree_leaves_with_path(tree):
        yield path_str(p), leaf


def param_count(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree: PyTree, bytes_per_elem: int | None = None) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        n = int(np.prod(x.shape))
        b = bytes_per_elem if bytes_per_elem is not None else jnp.dtype(x.dtype).itemsize
        total += n * b
    return total


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_allclose(a: PyTree, b: PyTree, **kw) -> bool:
    oks = jax.tree_util.tree_map(
        lambda x, y: bool(jnp.allclose(x, y, **kw)), a, b)
    return all(jax.tree_util.tree_leaves(oks))


def tree_any_nan(a: PyTree) -> bool:
    return any(bool(jnp.isnan(x).any()) for x in jax.tree_util.tree_leaves(a)
               if jnp.issubdtype(x.dtype, jnp.floating))


def global_norm(a: PyTree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(a))
    return jnp.sqrt(sq)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a)


def tree_stack(trees) -> PyTree:
    """Stack a list of identically-structured trees along a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: PyTree, n: int):
    return [jax.tree_util.tree_map(lambda x: x[i], tree) for i in range(n)]


def leaf_by_path(tree: PyTree, path: str):
    node = tree
    for k in path.split("/"):
        if isinstance(node, (list, tuple)):
            node = node[int(k)]
        else:
            node = node[k]
    return node


def tree_size_report(tree: PyTree, top: int = 12) -> str:
    rows = sorted(flatten_with_paths(tree),
                  key=lambda kv: -int(np.prod(kv[1].shape)))
    lines = [f"total params: {param_count(tree):,}"]
    for p, x in rows[:top]:
        lines.append(f"  {p:<60s} {str(x.shape):<20s} {int(np.prod(x.shape)):,}")
    return "\n".join(lines)
