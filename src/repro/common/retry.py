"""Jittered exponential backoff (crash-resilient client handling).

The cohort engine resamples crashed clients with bounded retries
(DESIGN.md §14); real deployments would also sleep between transport
attempts.  Both want the same schedule: exponential growth, a cap, and
*deterministic* jitter — every delay is a pure function of
``(seed, token, attempt)`` (the stateless-draw idiom of
``DelayScheduler``), so simulated runs replay bit-exactly and two
callers backing off for different tokens decorrelate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class Backoff:
    """Jittered exponential backoff schedule.

    ``delay(attempt)`` grows ``base * factor**attempt`` up to
    ``max_delay``, then jitters *downward* by up to ``jitter`` of the
    value (full value at jitter=0) — the "equal jitter" variant: the
    cap is respected, retries never synchronize.
    """
    attempts: int = 3
    base: float = 0.05
    factor: float = 2.0
    max_delay: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.attempts < 0:
            raise ValueError(f"attempts must be >= 0, got {self.attempts}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")

    def delay(self, attempt: int,
              token: Union[int, Sequence[int]] = 0) -> float:
        """The ``attempt``-th delay for ``token`` (any int tuple — e.g.
        ``(round, position)`` — decorrelates concurrent backoffs)."""
        d = min(self.base * self.factor ** int(attempt), self.max_delay)
        if self.jitter <= 0.0:
            return d
        toks = (token,) if isinstance(token, (int, np.integer)) else \
            tuple(int(t) for t in token)
        rng = np.random.default_rng(np.random.SeedSequence(
            (int(self.seed), 0xBACC0FF) + toks + (int(attempt),)))
        return d * (1.0 - self.jitter * float(rng.random()))


def retry_call(fn: Callable[[int], "object"], *, backoff: Backoff,
               retry_on: Tuple[type, ...] = (Exception,),
               token: Union[int, Sequence[int]] = 0,
               sleep: Optional[Callable[[float], None]] = time.sleep):
    """Call ``fn(attempt)`` with up to ``backoff.attempts`` retries.

    Sleeps ``backoff.delay(attempt, token)`` between attempts (pass
    ``sleep=None`` for simulated time — no real waiting).  Raises the
    last exception when every attempt fails.
    """
    last: Optional[BaseException] = None
    for attempt in range(max(1, backoff.attempts)):
        try:
            return fn(attempt)
        except retry_on as e:           # noqa: PERF203 (bounded loop)
            last = e
            if attempt + 1 < backoff.attempts and sleep is not None:
                sleep(backoff.delay(attempt, token))
    assert last is not None
    raise last
