from .pytree import (  # noqa: F401
    PyTree, path_str, tree_paths, tree_map_with_path, flatten_with_paths,
    param_count, param_bytes, tree_add, tree_sub, tree_scale,
    tree_zeros_like, tree_allclose, tree_any_nan, global_norm, tree_cast,
    tree_stack, tree_unstack, leaf_by_path, tree_size_report,
)
from .retry import Backoff, retry_call  # noqa: F401
