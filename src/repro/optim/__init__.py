from .masked import (  # noqa: F401
    AdamState, SGDState, adam_init, adam_step, sgd_init, sgd_step,
)
