"""Masked optimizers.

The paper's client semantics (Alg. 2): frozen layers receive no gradient
and are never touched by the optimizer.  ``mask`` is a pytree of 0/1
floats broadcastable to the params (built by ``core.masking``); a masked
step leaves both the frozen params AND their optimizer state bit-exact
(property-tested in tests/test_masking.py).

Clients re-initialize optimizer state every round (the paper trains each
round from the fresh global model with a fresh ADAM), so ``init`` is
cheap and called per round inside the compiled round step.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jnp.ndarray


def adam_init(params) -> AdamState:
    def zeros():
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, jnp.float32), params)

    # two independent zero trees — a tree_map(jnp.copy) here would
    # materialize a gratuitous full-model copy per client per round
    return AdamState(mu=zeros(), nu=zeros(),
                     count=jnp.zeros((), jnp.int32))


def adam_step(grads, state: AdamState, params, *, lr: float = 1e-2,
              b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
              mask: Optional[PyTree] = None) -> Tuple[PyTree, AdamState]:
    count = state.count + 1
    tf = count.astype(jnp.float32)
    c1 = 1.0 - b1 ** tf
    c2 = 1.0 - b2 ** tf

    def upd(g, m, v, p, k=None):
        gf = g.astype(jnp.float32)
        if k is not None:
            gf = gf * k
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        step = lr * (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        p_new = (p.astype(jnp.float32) - step).astype(p.dtype)
        if k is not None:
            # frozen entries: param and state bit-exact unchanged
            m_new = jnp.where(k > 0, m_new, m)
            v_new = jnp.where(k > 0, v_new, v)
            p_new = jnp.where(k > 0, p_new, p)
        return p_new, m_new, v_new

    if mask is None:
        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params)
    else:
        bmask = jax.tree_util.tree_map(
            lambda p, k: jnp.broadcast_to(
                jnp.reshape(k, k.shape + (1,) * (p.ndim - k.ndim)), p.shape),
            params, mask)
        out = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, params,
                                     bmask)
    p_new = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                is_leaf=lambda t: isinstance(t, tuple))
    return p_new, AdamState(mu=mu, nu=nu, count=count)


class SGDState(NamedTuple):
    momentum: PyTree
    count: jnp.ndarray


def sgd_init(params) -> SGDState:
    z = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    return SGDState(momentum=z, count=jnp.zeros((), jnp.int32))


def sgd_step(grads, state: SGDState, params, *, lr: float = 1e-2,
             momentum: float = 0.0, mask: Optional[PyTree] = None):
    def upd(g, m, p, k=None):
        gf = g.astype(jnp.float32)
        if k is not None:
            gf = gf * k
        m_new = momentum * m + gf
        p_new = (p.astype(jnp.float32) - lr * m_new).astype(p.dtype)
        if k is not None:
            m_new = jnp.where(k > 0, m_new, m)
            p_new = jnp.where(k > 0, p_new, p)
        return p_new, m_new

    if mask is None:
        out = jax.tree_util.tree_map(upd, grads, state.momentum, params)
    else:
        bmask = jax.tree_util.tree_map(
            lambda p, k: jnp.broadcast_to(
                jnp.reshape(k, k.shape + (1,) * (p.ndim - k.ndim)), p.shape),
            params, mask)
        out = jax.tree_util.tree_map(upd, grads, state.momentum, params, bmask)
    p_new = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree_util.tree_map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
    return p_new, SGDState(momentum=m, count=state.count + 1)
