from .store import (  # noqa: F401
    save_pytree, load_pytree, load_metadata, save_server_state,
    restore_server_state, FORMAT_VERSION,
    CheckpointError, CorruptCheckpointError, CheckpointVersionError,
)
