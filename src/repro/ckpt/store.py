"""Pytree checkpointing: flat-path npz arrays + a structure manifest.

Restartable server state for long federated runs: global params, round
counter, RNG key and selection history.  No external deps (orbax is not
available offline); paths are the stable "a/b/c" keys from common.pytree.

Writes are crash-atomic (DESIGN.md §14): both files are staged to a tmp
path, fsync'd and ``os.replace``'d, with the npz committed *before* the
manifest — a kill at any byte leaves either the previous complete
checkpoint or the new one, never a torn mix.  Restores verify a CRC32
over the npz payload and the manifest format version, raising typed
errors (:class:`CorruptCheckpointError` / :class:`CheckpointVersionError`)
instead of whatever np.load would garble out of a truncated zip.
"""
from __future__ import annotations

import io
import json
import os
import zipfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pytree as pt

# bump when the on-disk layout changes incompatibly; readers accept
# anything <= their own version (older manifests carry no version at
# all and are treated as version 0)
FORMAT_VERSION = 1


class CheckpointError(RuntimeError):
    """Base class for typed checkpoint-restore failures."""


class CorruptCheckpointError(CheckpointError):
    """The checkpoint bytes are damaged (truncated, bit-flipped, or not
    the format the manifest promises)."""


class CheckpointVersionError(CheckpointError):
    """The checkpoint was written by a newer format than this reader."""


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".json"


def _atomic_write(path: str, data: bytes) -> None:
    """tmp file + fsync + rename: the previous complete file survives a
    crash at any point, and readers never observe a partial write."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save_pytree(path: str, tree: Any, metadata: Optional[Dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = dict(pt.flatten_with_paths(tree))
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in flat.items()})
    payload = buf.getvalue()
    manifest = {
        "format_version": FORMAT_VERSION,
        "checksum": zlib.crc32(payload) & 0xFFFFFFFF,
        "paths": list(flat.keys()),
        "shapes": {k: list(np.shape(v)) for k, v in flat.items()},
        "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    # npz first, manifest second: the manifest (whose checksum covers
    # the npz) is the commit point both loaders and the crash-restart
    # harness key off
    _atomic_write(_npz_path(path), payload)
    _atomic_write(_manifest_path(path),
                  json.dumps(manifest, indent=1).encode())


def _read_manifest(path: str) -> Dict:
    mp = _manifest_path(path)
    if not os.path.exists(mp):
        return {}
    try:
        with open(mp) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CorruptCheckpointError(
            f"checkpoint manifest {mp} is not valid JSON ({e}); the "
            "write was torn or the file was damaged") from None
    ver = int(manifest.get("format_version", 0))
    if ver > FORMAT_VERSION:
        raise CheckpointVersionError(
            f"checkpoint {path} is format version {ver}, this reader "
            f"understands <= {FORMAT_VERSION}; upgrade the code or "
            "re-save the checkpoint")
    return manifest


def _verified_bytes(path: str, manifest: Dict) -> bytes:
    npz_path = _npz_path(path)
    try:
        with open(npz_path, "rb") as f:
            data = f.read()
    except OSError as e:
        raise CorruptCheckpointError(
            f"checkpoint arrays {npz_path} unreadable: {e}") from None
    want = manifest.get("checksum")
    if want is not None and (zlib.crc32(data) & 0xFFFFFFFF) != int(want):
        raise CorruptCheckpointError(
            f"checkpoint {npz_path} fails its CRC32 check: the file is "
            "truncated or bit-flipped; restore from the previous "
            "checkpoint")
    return data


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (params template)."""
    manifest = _read_manifest(path)
    data = _verified_bytes(path, manifest)
    try:
        npz = np.load(io.BytesIO(data))
        flat = {k: npz[k] for k in npz.files}
    except (zipfile.BadZipFile, ValueError, OSError, EOFError,
            KeyError) as e:
        raise CorruptCheckpointError(
            f"checkpoint {_npz_path(path)} is not a readable npz "
            f"archive ({e}); the file is truncated or damaged") from None

    def fill(p, leaf):
        if p not in flat:
            raise CorruptCheckpointError(
                f"checkpoint {_npz_path(path)} is missing array {p!r} "
                "the restore template requires")
        arr = flat[p]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{p}: checkpoint shape {arr.shape} != "
                             f"template {leaf.shape}")
        return jnp.asarray(arr, leaf.dtype)

    return pt.tree_map_with_path(fill, like)


def load_metadata(path: str) -> Dict:
    if not os.path.exists(_manifest_path(path)):
        raise FileNotFoundError(_manifest_path(path))
    return _read_manifest(path).get("metadata", {})


def save_server_state(path: str, server, extra: Optional[Dict] = None,
                      pending_record: Optional[Any] = None):
    """``pending_record`` lets a Checkpointer hook persist the round it
    is being called *for*: end-of-round hooks run before the server
    appends the record to ``history``, so without it a kill right after
    the save would lose the newest completed round."""
    history = list(server.history)
    if pending_record is not None:
        history.append(pending_record)
    meta = {
        "round": len(history),
        "history": [vars(r) for r in history],
        "sel_history": [np.asarray(s).tolist() for s in server.sel_history],
        "key": np.asarray(jax.random.key_data(server.key)).tolist()
        if hasattr(jax.random, "key_data") else np.asarray(server.key).tolist(),
    }
    meta.update(extra or {})
    if getattr(server, "_sel_base", 0):
        # history_cap trimming active: the folded accounting totals are
        # part of the restartable state (comm_summary reads them)
        meta["sel_base"] = int(server._sel_base)
        meta["comm_totals"] = {k: (int(v) if k == "rounds" else float(v))
                               for k, v in server._comm_totals.items()}
    tree = server.params
    wrapped = False
    engine = getattr(server, "async_engine", None)
    if engine is not None and engine.started:
        # buffered-async runs carry live state beyond the params: the
        # buffer's packed deltas, in-flight dispatches (with simulated
        # completion times), per-client round tags and the current
        # version's selection — all needed for a bit-exact resume
        async_meta, async_arrays = engine.checkpoint_state()
        meta["async"] = async_meta
        tree = {"params": server.params, "async_arrays": async_arrays}
        wrapped = True
    cohort = getattr(server, "cohort_engine", None)
    if cohort is not None and cohort.started:
        # cohort-engine runs carry the fleet's per-client EMAs and, at
        # a mid-round chunk boundary, the in-flight partial aggregate —
        # both needed for a bit-exact resume (DESIGN.md §13)
        cohort_meta, cohort_arrays = cohort.checkpoint_state()
        meta["cohort"] = cohort_meta
        if not wrapped:
            tree = {"params": server.params}
            wrapped = True
        tree["cohort_arrays"] = cohort_arrays
    codec_state = getattr(server, "codec_state", None)
    if codec_state is not None:
        # stateful uplink codec (DESIGN.md §16): the per-client error-
        # feedback residuals are part of the model's trajectory — a
        # resume without them replays compression error the original
        # run had already folded back in
        if not wrapped:
            tree = {"params": server.params}
            wrapped = True
        tree["codec_state"] = codec_state
        meta["codec_state"] = True
    sel_state = getattr(server, "sel_state", None)
    if sel_state is not None:
        # scored selection (DESIGN.md §11): the strategy's live state
        # pytree — score EMAs, per-unit train counts, round index —
        # must restore bit-exactly for a resumed run's selections to
        # match an uninterrupted one
        if not wrapped:
            tree = {"params": server.params}
        tree["sel_state"] = dict(sel_state._asdict())
        meta["sel_state"] = True
    save_pytree(path, tree, metadata=meta)


def restore_server_state(path: str, server):
    """Restore params (= topology state), history, selection history and
    the RNG stream, so a resumed ``fit`` continues bit-exactly: the next
    round's key, loader base and log cadence all pick up where the saved
    run stopped.  Buffered-async checkpoints additionally rebuild the
    update buffer, per-client round tags and the delay-scheduler's
    in-flight work (``AsyncRoundEngine.restore_state``)."""
    meta = load_metadata(path)
    engine = getattr(server, "async_engine", None)
    scored = bool(meta.get("sel_state"))
    sel_state = getattr(server, "sel_state", None)
    if scored and sel_state is None:
        raise ValueError(
            "checkpoint holds scored-selection state; restore it into a "
            "Federation configured with the original stateful strategy")
    if sel_state is not None and not scored:
        raise ValueError(
            "this server's strategy is stateful but the checkpoint has "
            "no selection state; restore with the original strategy")
    sel_template = dict(sel_state._asdict()) if scored else None
    cohort = getattr(server, "cohort_engine", None)
    if "async" in meta and engine is None:
        raise ValueError(
            "checkpoint holds buffered-async state; restore it into "
            "a Federation configured with FLConfig.async_buffer > 0")
    if "cohort" in meta and cohort is None:
        raise ValueError(
            "checkpoint holds cohort-engine state; restore it into a "
            "Federation configured with the original "
            "FLConfig.n_registered/cohort_chunk")
    codec_saved = bool(meta.get("codec_state"))
    codec_state = getattr(server, "codec_state", None)
    if codec_saved and codec_state is None:
        raise ValueError(
            "checkpoint holds codec error-feedback state; restore it "
            "into a Federation configured with the original stateful "
            "FLConfig.codec")
    if codec_state is not None and not codec_saved:
        raise ValueError(
            "this server's codec is stateful but the checkpoint has no "
            "codec state; restore with the original FLConfig.codec")
    if "async" in meta or "cohort" in meta or scored or codec_saved:
        template = {"params": server.params}
        if "async" in meta:
            template["async_arrays"] = engine.arrays_template(
                meta["async"])
        if "cohort" in meta:
            template["cohort_arrays"] = cohort.arrays_template(
                meta["cohort"])
        if scored:
            template["sel_state"] = sel_template
        if codec_saved:
            template["codec_state"] = codec_state
        tree = load_pytree(path, template)
        server.params = tree["params"]
        if codec_saved:
            server.codec_state = tree["codec_state"]
        if "async" in meta:
            engine.restore_state(meta["async"], tree["async_arrays"])
        if "cohort" in meta:
            cohort.restore_state(meta["cohort"], tree["cohort_arrays"])
    else:
        server.params = load_pytree(path, server.params)
    if scored:
        server.sel_state = type(sel_state)(**tree["sel_state"])
    if "sel_base" in meta:
        server._sel_base = int(meta["sel_base"])
        server._comm_totals = {
            k: (int(v) if k == "rounds" else float(v))
            for k, v in meta["comm_totals"].items()}
    if "history" in meta:
        from ..core.server import RoundRecord
        server.history = [RoundRecord(**r) for r in meta["history"]]
    if "sel_history" in meta:
        server.sel_history = [np.asarray(s, np.float32)
                              for s in meta["sel_history"]]
    if "key" in meta:
        kd = np.asarray(meta["key"], np.uint32)
        typed = (hasattr(jax.dtypes, "prng_key") and
                 jnp.issubdtype(server.key.dtype, jax.dtypes.prng_key))
        server.key = jax.random.wrap_key_data(kd) if typed \
            else jnp.asarray(kd, server.key.dtype)
    return meta
