"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

from ...models.attention import attend_reference


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Same (BH, S, hd) layout as the kernel; delegates to the model-zoo
    reference attention (B=BH, H=1)."""
    bh, sq, hd = q.shape
    bhkv = k.shape[0]
    n_rep = bh // bhkv
    kq = jnp.repeat(k, n_rep, axis=0)
    vq = jnp.repeat(v, n_rep, axis=0)
    o = attend_reference(q[:, :, None], kq[:, :, None], vq[:, :, None],
                         causal=causal, window=window)
    return o[:, :, 0]
