"""Pallas TPU flash attention (fwd + bwd), causal / sliding-window / GQA.

TPU adaptation of the blockwise-softmax algorithm: Q/K/V stream through
VMEM in MXU-aligned blocks; the score matrix never leaves VMEM.  Grid is
(batch·q_heads, q_blocks, kv_blocks) with the kv dim sequential
("arbitrary") so the online-softmax accumulators live in VMEM scratch
across kv steps.  Out-of-causal-range and out-of-window KV blocks are
skipped with ``pl.when`` (no MXU work — this is the block-skip the pure
JAX chunked baseline cannot express; see EXPERIMENTS.md §Perf).

GQA: K/V are indexed at ``head // n_rep`` via the BlockSpec index map, so
grouped heads never materialize repeated K/V in HBM.

Backward is the standard two-kernel recompute scheme using the saved
per-row logsumexp: one kernel accumulates dQ (grid kv-inner), one
accumulates dK/dV (grid q-inner).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *,
                scale, causal, window, blk_q, blk_k, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k
    # static-shape block skip decision must be dynamic: use pl.when
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + blk_q - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + blk_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (blk_q, hd)
        k = k_ref[0].astype(jnp.float32)                  # (blk_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(l)


def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        blk_q=128, blk_k=128, interpret=False):
    """q (BH, Sq, hd); k/v (BHkv, Sk, hd) with BH = BHkv * n_rep.

    Returns (o (BH, Sq, hd), lse (BH, Sq))."""
    bh, sq, hd = q.shape
    bhkv, sk, _ = k.shape
    n_rep = bh // bhkv
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    nq, nk = sq // blk_q, sk // blk_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_kv=nk)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, qi, ki, n_rep=n_rep: (b // n_rep, ki, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, qi, ki, n_rep=n_rep: (b // n_rep, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_q), lambda b, qi, ki: (b, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        scratch_shapes=[
            _vmem((blk_q,), jnp.float32),
            _vmem((blk_q,), jnp.float32),
            _vmem((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   acc_scr, *, scale, causal, window, blk_q, blk_k, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start, k_start = qi * blk_q, ki * blk_k
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + blk_q - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + blk_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        acc_scr[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        dq_ref[0] = acc_scr[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *,
                    scale, causal, window, blk_q, blk_k, n_q):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start, k_start = qi * blk_q, ki * blk_k
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + blk_q - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + blk_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=0,
                        blk_q=128, blk_k=128, interpret=False):
    """Returns (dq, dk, dv).  dk/dv are per-QUERY-head (BH, ...); the GQA
    reduction over the group happens in ops.py."""
    bh, sq, hd = q.shape
    bhkv, sk, _ = k.shape
    n_rep = bh // bhkv
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    nq, nk = sq // blk_q, sk // blk_k
    scale = 1.0 / math.sqrt(hd)
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, blk_q=blk_q, blk_k=blk_k, n_kv=nk),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, qi, ki, n_rep=n_rep: (b // n_rep, ki, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, qi, ki, n_rep=n_rep: (b // n_rep, ki, 0)),
            pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_q), lambda b, qi, ki: (b, qi)),
            pl.BlockSpec((1, blk_q), lambda b, qi, ki: (b, qi)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[_vmem((blk_q, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, blk_q=blk_q, blk_k=blk_k, n_q=nq),
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, ki, qi: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, ki, qi, n_rep=n_rep: (b // n_rep, ki, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, ki, qi, n_rep=n_rep: (b // n_rep, ki, 0)),
            pl.BlockSpec((1, blk_q, hd), lambda b, ki, qi: (b, qi, 0)),
            pl.BlockSpec((1, blk_q), lambda b, ki, qi: (b, qi)),
            pl.BlockSpec((1, blk_q), lambda b, ki, qi: (b, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_k, hd), lambda b, ki, qi: (b, ki, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, ki, qi: (b, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sk, hd), q.dtype),
            jax.ShapeDtypeStruct((bh, sk, hd), q.dtype),
        ],
        scratch_shapes=[_vmem((blk_k, hd), jnp.float32),
                        _vmem((blk_k, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
