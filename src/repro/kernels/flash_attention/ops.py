"""Jit-able wrapper: model-layout flash attention with custom VJP.

``flash_attention(q, k, v)`` takes the model layout (B, S, H, hd) /
(B, S, Hkv, hd), flattens to the kernel layout, and differentiates
through the Pallas bwd kernels.  ``interpret=True`` (default on CPU)
runs the kernel bodies in Python for validation; on TPU pass
``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd, flash_attention_bwd


def _to_kernel_layout(x):
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


def _from_kernel_layout(x, b, h):
    bh, s, d = x.shape
    return x.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, blk_q=128, blk_k=128,
                    interpret=True):
    o, _ = _fwd(q, k, v, causal, window, blk_q, blk_k, interpret)[0], None
    return o


def _fwd(q, k, v, causal, window, blk_q, blk_k, interpret):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    qk = _to_kernel_layout(q)
    kk = _to_kernel_layout(k)
    vk = _to_kernel_layout(v)
    o, lse = flash_attention_fwd(qk, kk, vk, causal=causal, window=window,
                                 blk_q=blk_q, blk_k=blk_k,
                                 interpret=interpret)
    return _from_kernel_layout(o, b, h), (qk, kk, vk, o, lse, b, h, hkv)


def _fwd_rule(q, k, v, causal, window, blk_q, blk_k, interpret):
    o, res = _fwd(q, k, v, causal, window, blk_q, blk_k, interpret)
    return o, res


def _bwd_rule(causal, window, blk_q, blk_k, interpret, res, do):
    qk, kk, vk, o, lse, b, h, hkv = res
    dok = _to_kernel_layout(do)
    dq, dk, dv = flash_attention_bwd(qk, kk, vk, o, lse, dok,
                                     causal=causal, window=window,
                                     blk_q=blk_q, blk_k=blk_k,
                                     interpret=interpret)
    n_rep = h // hkv
    dq = _from_kernel_layout(dq, b, h)
    # GQA: reduce the per-query-head dk/dv over each group
    sk, d = dk.shape[1], dk.shape[2]
    dk = dk.reshape(b, hkv, n_rep, sk, d).sum(axis=2).transpose(0, 2, 1, 3)
    dv = dv.reshape(b, hkv, n_rep, sk, d).sum(axis=2).transpose(0, 2, 1, 3)
    return dq, dk, dv


flash_attention.defvjp(_fwd_rule, _bwd_rule)
