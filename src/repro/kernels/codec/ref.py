"""jnp reference quantize/unpack for the codec kernel.

``quantize_pack_ref`` mirrors the kernel arithmetic op-for-op so the
Pallas path (interpret or compiled) can be property-tested bitwise
against it.  ``dequantize_unpack`` is the decode half used inside the
round step — unpacking is cheap elementwise work, so it stays plain jnp.
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_pack_ref(x, u, bits):
    """Pure-jnp mirror of ``kernel.quantize_pack`` (same wire format)."""
    if bits not in (8, 4):
        raise ValueError(f"quantize_pack_ref: bits must be 8 or 4, got {bits}")
    qmax = 127.0 if bits == 8 else 7.0
    if bits == 4 and x.shape[1] % 2:
        pad = [(0, 0), (0, 1)]
        x = jnp.pad(x, pad)
        u = jnp.pad(u, pad)
    absmax = jnp.max(jnp.abs(x), axis=1)
    scale = absmax * (1.0 / qmax)   # reciprocal multiply: see kernel
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.floor(x * inv[:, None] + u), -qmax, qmax)
    if bits == 8:
        return q.astype(jnp.int8), scale
    pairs = (q.astype(jnp.int32) + 8).reshape(x.shape[0], -1, 2)
    return (pairs[:, :, 0] | (pairs[:, :, 1] << 4)).astype(jnp.uint8), scale


def dequantize_unpack(packed, scale, bits, p):
    """Decode ``(packed, scale)`` back to ``(R, p)`` float32 rows."""
    if bits == 8:
        q = packed.astype(jnp.float32)
    else:
        lo = (packed & 0xF).astype(jnp.int32) - 8
        hi = (packed >> 4).astype(jnp.int32) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
        q = q[:, :p].astype(jnp.float32)
    return q * scale[:, None]
