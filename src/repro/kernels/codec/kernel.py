"""Fused stochastic-rounding quantize-pack Pallas kernel.

One pass per slot row: absmax scale compute, stochastic round
(``floor(x/scale + u)`` with pre-drawn uniforms), and bit-pack — int8
rows stay one byte per element, int4 rows pack two nibbles per byte.

The uniforms are generated *outside* the kernel (``jax.random.uniform``
on a key derived in the round step) so the kernel body is pure
arithmetic: it lowers identically under the Pallas interpreter on CPU
and Mosaic on TPU, and matches the jnp reference in ``ref.py`` bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def resolve_interpret(interpret=None):
    """Resolve the interpret flag: None means 'interpret unless TPU/GPU'."""
    if interpret is not None:
        return interpret
    return jax.default_backend() not in ("tpu", "gpu")


def _scale_round(x, u, qmax):
    """Shared row math: absmax scale + stochastic round to [-qmax, qmax].

    ``scale = absmax * (1/qmax)`` (reciprocal multiply, not division):
    XLA rewrites division-by-constant into reciprocal multiply when
    compiling, which is not exactly rounded — using the multiply form
    everywhere keeps compiled kernel == eager jnp reference bitwise.
    """
    absmax = jnp.max(jnp.abs(x))
    scale = absmax * (1.0 / qmax)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.floor(x * inv + u), -qmax, qmax)
    return q, scale


def _q8_kernel(x_ref, u_ref, q_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)
    q, scale = _scale_round(x, u, 127.0)
    q_ref[0] = q.astype(jnp.int8)
    s_ref[0, 0] = scale


def _q4_kernel(x_ref, u_ref, q_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)
    q, scale = _scale_round(x, u, 7.0)
    pairs = (q.astype(jnp.int32) + 8).reshape(-1, 2)
    q_ref[0] = (pairs[:, 0] | (pairs[:, 1] << 4)).astype(jnp.uint8)
    s_ref[0, 0] = scale


def quantize_pack(x, u, bits, *, interpret=None):
    """Quantize-pack rows of ``x`` with per-row absmax scales.

    Args:
      x: ``(R, P)`` float32 rows to quantize (one scale per row).
      u: ``(R, P)`` uniforms in ``[0, 1)`` for stochastic rounding.
      bits: 8 (int8 bytes) or 4 (two nibbles per uint8 byte).

    Returns:
      ``(packed, scale)`` — packed ``(R, P)`` int8 for 8-bit or
      ``(R, ceil(P/2))`` uint8 for 4-bit, and ``(R,)`` float32 scales.
    """
    if bits not in (8, 4):
        raise ValueError(f"quantize_pack: bits must be 8 or 4, got {bits}")
    interpret = resolve_interpret(interpret)
    r, p = x.shape
    if bits == 4 and p % 2:
        pad = [(0, 0), (0, 1)]
        x = jnp.pad(x, pad)
        u = jnp.pad(u, pad)
    pp = x.shape[1]
    if bits == 8:
        kernel, q_cols, q_dtype = _q8_kernel, pp, jnp.int8
    else:
        kernel, q_cols, q_dtype = _q4_kernel, pp // 2, jnp.uint8
    packed, scale = pl.pallas_call(
        kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, pp), lambda i: (i, 0)),
            pl.BlockSpec((1, pp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q_cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, q_cols), q_dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, u)
    return packed, scale[:, 0]


quantize_pack_q8 = functools.partial(quantize_pack, bits=8)
quantize_pack_q4 = functools.partial(quantize_pack, bits=4)
