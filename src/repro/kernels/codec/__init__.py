from .kernel import quantize_pack, resolve_interpret
from .ref import dequantize_unpack, quantize_pack_ref

__all__ = ["quantize_pack", "quantize_pack_ref", "dequantize_unpack",
           "resolve_interpret"]
