"""Oracle: the unfused pytree masked FedAvg from core.aggregation."""
from ...core.aggregation import masked_fedavg as masked_fedavg_ref  # noqa: F401
