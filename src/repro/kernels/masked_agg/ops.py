"""Pytree-level wrapper: pack params/deltas into unit tiles, run the
fused kernel, unpack.  Drop-in replacement for core.aggregation.
masked_fedavg (tested equal in tests/test_kernels_masked_agg.py).

The per-leaf packing metadata — which unit owns each tile row, segment
sizes, pad amounts, row offsets — is a pure function of the unit
assignment and the leaf shapes, so it is planned ONCE
(:func:`build_agg_plan`) and reused across traces; the traced function
only executes the planned pads/reshapes.  ``interpret`` resolves from
the backend by default (compiled Pallas on TPU/GPU, interpreter on
CPU) — see ``kernel.resolve_interpret``.

``masked_combine_fused`` is the general entry point: it takes the
per-client per-unit weight matrix ``wsel (C, U)`` directly, which lets
the hierarchical topology run its hub combine through the same kernel
(clients -> edges, ``wsel`` -> per-edge weight mass; see
``core.aggregation.hierarchical_edge_partials``).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...common import pytree as pt
from ...core.masking import UnitAssignment, _is_leafunit
from .kernel import masked_agg

TILE = 2048


class AggSegment(NamedTuple):
    """One contiguous run of tile rows belonging to one (leaf, unit)."""
    path: str
    unit: int        # freeze unit owning these rows
    n: int           # payload elements (before padding)
    n_tiles: int     # tile rows
    macro: int       # macro index within the leaf (-1 for scalar leaves)


class AggPlan(NamedTuple):
    """Build-time tiling plan for the fused masked aggregation."""
    tile: int
    leaves: Tuple[Tuple[str, Tuple[int, ...], Tuple[int, ...]], ...]
    # (path, leaf shape, unit ids per macro row — len 1 for scalar)
    segments: Tuple[AggSegment, ...]
    n_rows: int      # total tile rows


def _leaf_units_flat(assign, params):
    """Per-leaf (unit ids per element-block) — unit id for every macro row."""
    out = []
    for (path, leaf), lu in zip(
            pt.flatten_with_paths(params),
            jax.tree_util.tree_leaves(assign.leaf_units, is_leaf=_is_leafunit)):
        if lu.kind == "scalar":
            out.append((path, leaf, np.asarray([lu.base])))
        else:
            ids = lu.base + lu.stride * np.arange(leaf.shape[0])
            out.append((path, leaf, ids))
    return out


def build_agg_plan(assign: UnitAssignment, params, tile: int = TILE
                   ) -> AggPlan:
    """Plan the unit-tile packing once, outside any trace.

    Only leaf *shapes* are read, so ``params`` may be tracers (building
    the plan lazily at first trace is equivalent to build time — the
    plan is cached on the round-step closure and never re-planned).
    """
    leaves = []
    segments = []
    n_rows = 0
    for path, leaf, unit_ids in _leaf_units_flat(assign, params):
        shape = tuple(leaf.shape)
        leaves.append((path, shape, tuple(int(u) for u in unit_ids)))
        if len(unit_ids) == 1:
            sizes = [(int(np.prod(shape)) if shape else 1, -1)]
        else:
            per = int(np.prod(shape[1:])) if len(shape) > 1 else 1
            sizes = [(per, m) for m in range(shape[0])]
        for (n, macro), u in zip(sizes, unit_ids):
            nt = -(-n // tile)
            segments.append(AggSegment(path, int(u), n, nt, macro))
            n_rows += nt
    return AggPlan(tile, tuple(leaves), tuple(segments), n_rows)


def masked_combine_fused(global_params, deltas, wsel, assign: UnitAssignment,
                         *, tile: int = TILE,
                         interpret: Optional[bool] = None,
                         plan: Optional[AggPlan] = None) -> Any:
    """Fused ``new_u = g_u + Σ_c wsel_cu·Δ_cu / Σ_c wsel_cu``.

    ``deltas``: client-stacked pytree (C leading); ``wsel (C, U)`` is
    the per-client per-unit weight mass (``sel * weights`` for the flat
    FedAvg; per-edge weight mass for the hierarchical hub combine).
    """
    if plan is None or plan.tile != tile:
        plan = build_agg_plan(assign, global_params, tile)
    c = wsel.shape[0]
    gleaves = {p: l for p, l in pt.flatten_with_paths(global_params)}
    dleaves = {p: l for p, l in pt.flatten_with_paths(deltas)}

    g_rows, d_rows, w_rows = [], [], []
    for path, shape, unit_ids in plan.leaves:
        leaf, d = gleaves[path], dleaves[path]
        if len(unit_ids) == 1:
            segs = [(leaf.reshape(-1), d.reshape(c, -1), unit_ids[0])]
        else:
            lf = leaf.reshape(shape[0], -1)
            df = d.reshape(c, shape[0], -1)
            segs = [(lf[m], df[:, m], u) for m, u in enumerate(unit_ids)]
        for gseg, dseg, u in segs:
            n = gseg.shape[0]
            nt = -(-n // tile)
            pad = nt * tile - n
            g_rows.append(jnp.pad(gseg, (0, pad)).reshape(nt, tile))
            d_rows.append(jnp.pad(dseg, ((0, 0), (0, pad)))
                          .reshape(c, nt, tile).swapaxes(0, 1))
            w_rows.append(jnp.broadcast_to(wsel[:, u], (nt, c)))

    g_t = jnp.concatenate(g_rows, axis=0)
    d_t = jnp.concatenate(d_rows, axis=0)
    w_t = jnp.concatenate(w_rows, axis=0)
    out_t = masked_agg(g_t, d_t, w_t, interpret=interpret)

    # unpack: walk the plan's segments in packing order
    flat_out = {}
    row = 0
    i = 0
    for path, shape, unit_ids in plan.leaves:
        leaf = gleaves[path]
        pieces = []
        for _ in unit_ids:
            seg = plan.segments[i]
            assert seg.path == path
            pieces.append(out_t[row:row + seg.n_tiles].reshape(-1)[:seg.n])
            row += seg.n_tiles
            i += 1
        if len(unit_ids) == 1:
            flat_out[path] = pieces[0].reshape(shape).astype(leaf.dtype)
        else:
            flat_out[path] = jnp.stack(
                [p.reshape(shape[1:]) for p in pieces]).astype(leaf.dtype)

    return pt.tree_map_with_path(lambda p, x: flat_out[p], global_params)


def masked_fedavg_fused(global_params, deltas, sel, weights,
                        assign: UnitAssignment, *, tile: int = TILE,
                        interpret: Optional[bool] = None,
                        plan: Optional[AggPlan] = None) -> Any:
    """Same contract as core.aggregation.masked_fedavg.

    deltas: client-stacked pytree (C leading); sel (C, U); weights (C,).
    """
    wsel = sel * weights[:, None].astype(sel.dtype)        # (C, U)
    return masked_combine_fused(global_params, deltas, wsel, assign,
                                tile=tile, interpret=interpret, plan=plan)
