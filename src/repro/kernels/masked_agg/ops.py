"""Pytree-level wrapper: pack params/deltas into unit tiles, run the
fused kernel, unpack.  Drop-in replacement for core.aggregation.
masked_fedavg (tested equal in tests/test_kernels_masked_agg.py)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ...common import pytree as pt
from ...core.masking import UnitAssignment, _is_leafunit
from .kernel import masked_agg

TILE = 2048


def _leaf_units_flat(assign, params):
    """Per-leaf (unit ids per element-block) — unit id for every macro row."""
    out = []
    for (path, leaf), lu in zip(
            pt.flatten_with_paths(params),
            jax.tree_util.tree_leaves(assign.leaf_units, is_leaf=_is_leafunit)):
        if lu.kind == "scalar":
            out.append((path, leaf, np.asarray([lu.base])))
        else:
            ids = lu.base + lu.stride * np.arange(leaf.shape[0])
            out.append((path, leaf, ids))
    return out


def masked_fedavg_fused(global_params, deltas, sel, weights,
                        assign: UnitAssignment, *, tile: int = TILE,
                        interpret: bool = True) -> Any:
    """Same contract as core.aggregation.masked_fedavg.

    deltas: client-stacked pytree (C leading); sel (C, U); weights (C,).
    """
    c = sel.shape[0]
    leaves = _leaf_units_flat(assign, global_params)
    wsel = sel * weights[:, None].astype(sel.dtype)        # (C, U)

    g_rows, d_rows, w_rows = [], [], []
    meta = []  # (path, shape, n_elems, n_tiles per segment rows)
    dleaves = {p: l for p, l in pt.flatten_with_paths(deltas)}
    for path, leaf, unit_ids in leaves:
        d = dleaves[path]
        if len(unit_ids) == 1:
            segs = [(leaf.reshape(-1), d.reshape(c, -1), int(unit_ids[0]))]
        else:
            lf = leaf.reshape(leaf.shape[0], -1)
            df = d.reshape(c, leaf.shape[0], -1)
            segs = [(lf[m], df[:, m], int(u))
                    for m, u in enumerate(unit_ids)]
        for gseg, dseg, u in segs:
            n = gseg.shape[0]
            nt = -(-n // tile)
            pad = nt * tile - n
            g_rows.append(jnp.pad(gseg, (0, pad)).reshape(nt, tile))
            d_rows.append(jnp.pad(dseg, ((0, 0), (0, pad)))
                          .reshape(c, nt, tile).swapaxes(0, 1))
            w_rows.append(jnp.broadcast_to(wsel[:, u], (nt, c)))
            meta.append((path, n, nt))

    g_t = jnp.concatenate(g_rows, axis=0)
    d_t = jnp.concatenate(d_rows, axis=0)
    w_t = jnp.concatenate(w_rows, axis=0)
    out_t = masked_agg(g_t, d_t, w_t, interpret=interpret)

    # unpack: walk meta in packing order
    flat_out = {}
    row = 0
    i = 0
    for path, leaf, unit_ids in leaves:
        pieces = []
        for _ in unit_ids:
            mpath, n, nt = meta[i]
            assert mpath == path
            pieces.append(out_t[row:row + nt].reshape(-1)[:n])
            row += nt
            i += 1
        if len(unit_ids) == 1:
            flat_out[path] = pieces[0].reshape(leaf.shape).astype(leaf.dtype)
        else:
            flat_out[path] = jnp.stack(
                [p.reshape(leaf.shape[1:]) for p in pieces]).astype(leaf.dtype)

    return pt.tree_map_with_path(lambda p, x: flat_out[p], global_params)
