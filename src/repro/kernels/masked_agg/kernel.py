"""Pallas TPU fused masked participation-weighted FedAvg.

THE paper op: ``new_u = global_u + Σ_c w_c·sel_cu·Δ_cu / Σ_c w_c·sel_cu``
fused over client-stacked deltas.  ops.py packs each freeze unit's
params into tile rows and precomputes the per-tile client weight row
``wm[t, c] = w_c · sel_{c, unit(t)}`` (masks are per-unit constants, so
they collapse from (C, N) floats to (T, C)); the kernel then fuses the
weighted client reduction, the denominator guard, and the global add in
one VMEM pass — one HBM read of the deltas instead of the 3–4 passes the
unfused jnp version takes.

Grid: (n_tiles,).  Blocks: deltas (C, tile), weights (C,), global (tile,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def resolve_interpret(interpret=None) -> bool:
    """None -> backend default: compiled Pallas on TPU/GPU, interpret
    elsewhere (the CPU hosts have no Mosaic lowering)."""
    if interpret is None:
        return jax.default_backend() not in ("tpu", "gpu")
    return bool(interpret)


def _agg_kernel(g_ref, d_ref, w_ref, o_ref):
    g = g_ref[0].astype(jnp.float32)              # (tile,)
    d = d_ref[0].astype(jnp.float32)              # (C, tile)
    w = w_ref[0].astype(jnp.float32)              # (C,)
    denom = w.sum()
    num = jnp.einsum("c,ct->t", w, d)
    upd = jnp.where(denom > 0, num / jnp.maximum(denom, 1e-9), 0.0)
    o_ref[0] = (g + upd).astype(o_ref.dtype)


def masked_agg(global_tiled, deltas_tiled, weights_tiled, *,
               interpret=None):
    """global (T, tile); deltas (T, C, tile); weights (T, C) -> (T, tile).

    ``interpret=None`` resolves from the backend (compiled on TPU/GPU,
    interpreter on CPU) — see :func:`resolve_interpret`.
    """
    interpret = resolve_interpret(interpret)
    t, tile = global_tiled.shape
    c = deltas_tiled.shape[1]
    return pl.pallas_call(
        _agg_kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, c, tile), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, tile), global_tiled.dtype),
        interpret=interpret,
    )(global_tiled, deltas_tiled, weights_tiled)
