"""Pure-jnp oracle for flash decode."""
from __future__ import annotations

import jax.numpy as jnp

from ...models.attention import decode_attend


def flash_decode_ref(q, k, v, valid_len):
    """Kernel layout (BH,1,hd)/(BHkv,S,hd) -> (BH,1,hd)."""
    bh = q.shape[0]
    n_rep = bh // k.shape[0]
    kq = jnp.repeat(k, n_rep, axis=0)
    vq = jnp.repeat(v, n_rep, axis=0)
    o = decode_attend(q[:, :, None], kq[:, :, None], vq[:, :, None],
                      valid_len)
    return o[:, :, 0]


def flash_decode_paged_ref(q, k_pool, v_pool, page_table, valid_len):
    """Paged kernel layout: q (BH,1,hd); pools (Hkv,P,ps,hd);
    page_table (B,MP); valid_len (BH,) -> (BH,1,hd).

    Gathers each sequence's pages into the dense layout and defers to
    the dense oracle."""
    bh, _, hd = q.shape
    hkv, _, ps, _ = k_pool.shape
    b, mp = page_table.shape
    kd = jnp.moveaxis(k_pool[:, page_table], 0, 1)    # (B,Hkv,MP,ps,hd)
    vd = jnp.moveaxis(v_pool[:, page_table], 0, 1)
    kd = kd.reshape(b * hkv, mp * ps, hd)
    vd = vd.reshape(b * hkv, mp * ps, hd)
    return flash_decode_ref(q, kd, vd, valid_len)
