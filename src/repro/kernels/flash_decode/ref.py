"""Pure-jnp oracle for flash decode."""
from __future__ import annotations

import jax.numpy as jnp

from ...models.attention import decode_attend


def flash_decode_ref(q, k, v, valid_len):
    """Kernel layout (BH,1,hd)/(BHkv,S,hd) -> (BH,1,hd)."""
    bh = q.shape[0]
    n_rep = bh // k.shape[0]
    kq = jnp.repeat(k, n_rep, axis=0)
    vq = jnp.repeat(v, n_rep, axis=0)
    o = decode_attend(q[:, :, None], kq[:, :, None], vq[:, :, None],
                      valid_len)
    return o[:, :, 0]
