"""Pallas TPU flash decode: one query token vs a block-partitioned KV cache.

serve_step's hot loop for 32k–500k contexts.  Grid (batch·q_heads,
kv_blocks) with the kv dim sequential; partial (m, l, acc) accumulators
in VMEM combine the per-block softmax contributions — the classic
partial-softmax decode combine, here expressed blockwise for VMEM
streaming.  Validity masking (cache positions >= valid_len) covers both
the full-cache and the ring-buffer (sliding-window) cases: ring order
does not matter to softmax(QK)V, so ops.py maps a window decode to
valid_len = min(step+1, window).

On a real mesh, the KV cache is sequence-sharded and each shard's
(m, l, acc) partials are combined with a small psum (launch/serve.py);
the kernel is the per-shard worker.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, blk_k, n_kv):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[0]
    k_start = ki * blk_k

    @pl.when(k_start < valid)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (1, hd)
        k = k_ref[0].astype(jnp.float32)                 # (blk_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, blk_k), 1)
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(pt_ref, q_ref, k_ref, v_ref, valid_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale, page_size,
                         n_pages):
    """Same partial-softmax combine as ``_decode_kernel``; the KV blocks
    arrive through the page table (``pt_ref`` drives the BlockSpec index
    maps, so only the pages a sequence owns are ever DMA'd)."""
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[0]
    k_start = ki * page_size

    @pl.when(k_start < valid)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (1, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_paged(q, k_pool, v_pool, page_table, valid_len, *,
                       interpret=False):
    """Paged flash decode: gather K/V pages through the page table.

    q (BH, 1, hd); pools (Hkv, P, ps, hd) — the shared physical page pool;
    page_table (B, MP) int32 maps logical page j of sequence b to a
    physical page; valid_len (BH,) int32.  Returns o (BH, 1, hd).

    The page table is a scalar-prefetch operand
    (``pltpu.PrefetchScalarGridSpec``): BlockSpec index maps read it to
    source each grid step's KV block, so the kernel streams exactly the
    pages a sequence owns — the paged counterpart of ``flash_decode``'s
    contiguous blocks.  Validity masking is identical (ring callers
    pre-clamp ``valid_len``); pages at ki ≥ ceil(valid/ps) are skipped by
    the same ``@pl.when`` guard, so the trash page 0 behind unallocated
    page-table entries is never read on the compute path.
    """
    from jax.experimental.pallas import tpu as pltpu

    bh, _, hd = q.shape
    hkv, _, ps, _ = k_pool.shape
    b, mp = page_table.shape
    h = bh // b
    n_rep = h // hkv
    scale = 1.0 / math.sqrt(hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, mp),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda bi, ki, pt: (bi, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda bi, ki, pt: ((bi % h) // n_rep,
                                             pt[bi // h, ki], 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda bi, ki, pt: ((bi % h) // n_rep,
                                             pt[bi // h, ki], 0, 0)),
            pl.BlockSpec((1,), lambda bi, ki, pt: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda bi, ki, pt: (bi, 0, 0)),
        scratch_shapes=[_vmem((1,), jnp.float32), _vmem((1,), jnp.float32),
                        _vmem((1, hd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, page_size=ps,
                          n_pages=mp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, 1, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), q, k_pool, v_pool, valid_len)


def flash_decode(q, k, v, valid_len, *, blk_k=512, interpret=False):
    """q (BH, 1, hd); k/v (BHkv, S, hd); valid_len (BH,) int32.

    Returns o (BH, 1, hd)."""
    bh, _, hd = q.shape
    bhkv, sk, _ = k.shape
    n_rep = bh // bhkv
    blk_k = min(blk_k, sk)
    nk = sk // blk_k
    scale = 1.0 / math.sqrt(hd)

    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, blk_k=blk_k, n_kv=nk),
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, ki, n_rep=n_rep: (b // n_rep, ki, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, ki, n_rep=n_rep: (b // n_rep, ki, 0)),
            pl.BlockSpec((1,), lambda b, ki: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, hd), q.dtype),
        scratch_shapes=[_vmem((1,), jnp.float32), _vmem((1,), jnp.float32),
                        _vmem((1, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, valid_len)
