"""Model-layout wrapper for the flash decode kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_decode


def decode_attention(q, k_cache, v_cache, valid_len, *, window: int = 0,
                     blk_k: int = 512, interpret: bool = True):
    """q (B,1,H,hd); caches (B,S,Hkv,hd); valid_len (B,).

    ``window > 0`` means the cache is a ring buffer of that size: validity
    becomes min(valid_len, window) and no positional mask is needed.
    """
    b, _, h, hd = q.shape
    hkv = k_cache.shape[2]
    if window > 0:
        valid_len = jnp.minimum(valid_len, window)
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, 1, hd)
    kk = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, -1, hd)
    vk = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, -1, hd)
    valid = jnp.repeat(valid_len.astype(jnp.int32), h)
    o = flash_decode(qk, kk, vk, valid, blk_k=blk_k, interpret=interpret)
    return o.reshape(b, h, 1, hd).transpose(0, 2, 1, 3)
