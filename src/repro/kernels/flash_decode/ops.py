"""Model-layout wrappers for the flash decode kernels (dense + paged)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..masked_agg.kernel import resolve_interpret
from .kernel import flash_decode, flash_decode_paged


def decode_attention(q, k_cache, v_cache, valid_len, *, window: int = 0,
                     blk_k: int = 512, interpret: bool = True):
    """q (B,1,H,hd); caches (B,S,Hkv,hd); valid_len (B,).

    ``window > 0`` means the cache is a ring buffer of that size: validity
    becomes min(valid_len, window) and no positional mask is needed.
    """
    b, _, h, hd = q.shape
    hkv = k_cache.shape[2]
    if window > 0:
        valid_len = jnp.minimum(valid_len, window)
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, 1, hd)
    kk = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, -1, hd)
    vk = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, -1, hd)
    valid = jnp.repeat(valid_len.astype(jnp.int32), h)
    o = flash_decode(qk, kk, vk, valid, blk_k=blk_k, interpret=interpret)
    return o.reshape(b, h, 1, hd).transpose(0, 2, 1, 3)


def paged_decode_attention(q, k_pool, v_pool, page_table, valid_len, *,
                           interpret: Optional[bool] = None):
    """Single-token attention through a page table (serving engine hot op).

    q (B,1,H,hd); pools (P, ps, Hkv, hd) — the engine's shared physical
    page pool; page_table (B, MP) int32; valid_len (B,) — callers
    pre-clamp to the ring allocation for sliding-window layers.

    ``interpret`` resolves from the backend like the other kernels
    (``masked_agg.kernel.resolve_interpret``): on CPU the pure-jnp
    gather reference runs (bitwise-equal to the dense decode path —
    tested); on TPU/GPU the Pallas ``flash_decode_paged`` kernel gathers
    K/V pages through the page table without ever materializing the
    dense view.
    """
    if resolve_interpret(interpret):
        # jnp reference (lazy import: models.attention imports this module)
        from ...models.attention import decode_attend_paged
        return decode_attend_paged(q, k_pool, v_pool, page_table, valid_len)
    b, _, h, hd = q.shape
    hkv = k_pool.shape[2]
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, 1, hd)
    kp = k_pool.transpose(2, 0, 1, 3)            # (Hkv, P, ps, hd)
    vp = v_pool.transpose(2, 0, 1, 3)
    valid = jnp.repeat(valid_len.astype(jnp.int32), h)
    o = flash_decode_paged(qk, kp, vp, page_table, valid)
    return o.reshape(b, h, 1, hd).transpose(0, 2, 1, 3)
