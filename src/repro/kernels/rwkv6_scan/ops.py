"""Model-layout wrapper for the rwkv6 WKV scan kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import rwkv6_scan


def wkv(r, k, v, log_decay, u, *, chunk: int = 16, interpret: bool = True):
    """Model layout: r,k,log_decay (B,S,H,dk); v (B,S,H,dv); u (H,dk).

    Returns (o (B,S,H,dv), state (B,H,dk,dv))."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, -1)

    uu = jnp.broadcast_to(u, (b, h, dk)).reshape(b * h, dk)
    o, state = rwkv6_scan(fold(r), fold(k), fold(v), fold(log_decay), uu,
                          chunk=chunk, interpret=interpret)
    o = o.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
    return o, state.reshape(b, h, dk, dv)
