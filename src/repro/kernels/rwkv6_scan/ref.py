"""Pure-jnp oracle for the rwkv6 scan kernel: the exact per-token
recurrence (same convention as models/linear_scan.py, decay_on='k')."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.linear_scan import LOG_DECAY_FLOOR


def rwkv6_scan_ref(r, k, v, log_decay, u):
    """Kernel layout: r,k,log_decay (BH,S,dk); v (BH,S,dv); u (BH,dk).

    Returns (o (BH,S,dv), state (BH,dk,dv) float32)."""
    bh, s, dk = r.shape
    dv = v.shape[-1]
    ld = jnp.clip(log_decay.astype(jnp.float32), LOG_DECAY_FLOOR, 0.0)
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    uf = u.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, dt = xs                  # (BH, dk) / (BH, dv)
        out = jnp.einsum("bi,bij->bj", rt, state)
        out = out + jnp.einsum("bi,bi->b", rt, uf * kt)[:, None] * vt
        state = jnp.exp(dt)[..., None] * state + \
            jnp.einsum("bi,bj->bij", kt, vt)
        return state, out

    xs = tuple(x.swapaxes(0, 1) for x in (rf, kf, vf, ld))
    state0 = jnp.zeros((bh, dk, dv), jnp.float32)
    state, outs = jax.lax.scan(step, state0, xs)
    return outs.swapaxes(0, 1).astype(r.dtype), state
