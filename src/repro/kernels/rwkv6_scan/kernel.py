"""Pallas TPU chunked RWKV-6 WKV recurrence with data-dependent decay.

The (dk × dv) per-head state lives in VMEM scratch across the sequential
time-chunk grid dimension; each chunk is processed with MXU matmuls
(the chunked gated-linear-attention form, two-sided log-normalized —
same math as models/linear_scan.py, which is this kernel's oracle).

Grid: (batch·heads, n_chunks), chunk dim sequential.  Chunk length and
dk/dv default to 16/64 — (64, 64) state + (16, 64) operand tiles keep
the working set well inside VMEM while the matmuls stay MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG_DECAY_FLOOR = -5.0


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _wkv_kernel(r_ref, k_ref, v_ref, d_ref, u_ref, o_ref, state_ref,
                s_scr, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)          # (c, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (c, dv)
    d = jnp.clip(d_ref[0].astype(jnp.float32), LOG_DECAY_FLOOR, 0.0)
    u = u_ref[0].astype(jnp.float32)          # (1, dk)

    cum = jnp.cumsum(d, axis=0)
    total = cum[-1:, :]
    cum_prev = cum - d
    qh = r * jnp.exp(cum_prev - total)
    kh = k * jnp.exp(total - cum)
    att = jax.lax.dot_general(qh, kh, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(col < row, att, 0.0)      # strict lower triangle
    intra = jax.lax.dot(att, v, preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)
    intra = intra + diag * v
    inter = jax.lax.dot(r * jnp.exp(cum_prev), s_scr[...],
                        preferred_element_type=jnp.float32)
    o_ref[0] = (inter + intra).astype(o_ref.dtype)
    s_scr[...] = jnp.exp(total).T * s_scr[...] + jax.lax.dot_general(
        kh, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        state_ref[0] = s_scr[...]


def rwkv6_scan(r, k, v, log_decay, u, *, chunk=16, interpret=False):
    """r,k (BH, S, dk); v (BH, S, dv); log_decay (BH, S, dk); u (BH, dk).

    Returns (o (BH, S, dv), state (BH, dk, dv) float32)."""
    bh, s, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"S {s} % chunk {chunk}")
    nc = s // chunk

    return pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, n_chunks=nc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, dk), lambda b, ci: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), r.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[_vmem((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_decay, u)
