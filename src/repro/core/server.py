"""Round orchestration (paper Alg. 1) — the FEDn-combiner role.

The ``Server`` drives rounds at the Python level: handing shards to the
compiled ``round_step``, evaluation and history.  Everything numerically
heavy is inside the jitted round step; everything *situational* —
straggler dropout, comm accounting, logging, checkpointing — is a
composable :class:`ServerHook` rather than an inlined branch, so
deployments mix and match without touching the loop.

Hook call order per round::

    on_round_start(server, round_idx, weights) -> weights   (may reweight)
    ... compiled round step ...
    on_round_end(server, record, metrics)                   (may annotate)

If every client drops (all weights zero) the round is a recorded no-op:
the global params are untouched and the ``RoundRecord`` carries
``skipped=True`` with zero participants.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.compileguard import CompileGuard
from . import codecs, comm
from .federation import FLConfig
from .masking import UnitAssignment
from .strategies import (NormTelemetry, SelectionContext, SelectionStrategy,
                         resolve_strategy)
from .topology import Topology, resolve_topology


@dataclasses.dataclass
class RoundRecord:
    round: int
    loss: float
    eval_metric: Optional[float]
    seconds: float
    uplink_bytes: float
    trained_params: float
    n_participants: int = 0
    skipped: bool = False
    # the round's post-hook client weights (dropped clients are 0) —
    # sync rounds carry one entry per client, async flushes one per
    # buffered update; hooks and accounting read participation off this
    effective_weights: Optional[List[float]] = None
    # buffered-async flush annotations (zero on synchronous rounds)
    staleness_mean: float = 0.0
    staleness_max: float = 0.0
    sim_time: float = 0.0
    # bytes that crossed the WAN (or left a client) without landing in
    # the aggregate: quarantined uploads, duplicate deliveries, updates
    # lost in transit (DESIGN.md §14) — keeps the comm tables honest
    # under faults; wasted ⊆ uplink only for quarantined entries
    wasted_bytes: float = 0.0
    # every client dropped/crashed: the round was a recorded no-op.
    # Unlike the legacy NaN loss, dropped rounds carry loss 0.0 and are
    # excluded from EMAs and convergence summaries explicitly
    dropped: bool = False


class ServerHook:
    """Override any subset; defaults are no-ops."""

    def on_round_start(self, server: "Server", round_idx: int,
                       weights: jnp.ndarray) -> Optional[jnp.ndarray]:
        """Return new weights to reweight/drop clients, or None."""
        return None

    def on_round_end(self, server: "Server", record: RoundRecord,
                     metrics: Optional[Dict]) -> None:
        pass

    def on_fit_end(self, server: "Server",
                   history: List[RoundRecord]) -> None:
        pass


class StragglerDropout(ServerHook):
    """Simulated stragglers: each client independently drops with
    probability ``rate``; dropped clients contribute weight 0.  Draws
    from the server's key stream (reproducible per server seed)."""

    def __init__(self, rate: float):
        self.rate = rate

    def on_round_start(self, server, round_idx, weights):
        if self.rate <= 0.0:
            # a rate-0 hook must be a true no-op: drawing from the key
            # stream anyway would desync a rate=0 run from a no-hook
            # run and break bit-exact comparisons
            return None
        keep = jax.random.bernoulli(server.next_key(), 1.0 - self.rate,
                                    (server.fl.n_clients,))
        return weights * keep.astype(jnp.float32)


class CommAccounting(ServerHook):
    """Exact per-round transfer accounting (paper Table 4) from the
    round's selection matrix — fills ``uplink_bytes``/``trained_params``
    on the record.  The byte math is the server's topology plugin's
    (``Topology.round_bytes``), not hard-coded hub formulas: ``uplink``
    is whatever crosses that topology's WAN boundary (hub: client
    uploads; hierarchical: edge->hub partial aggregates; gossip: peer
    replica exchange)."""

    def on_round_end(self, server, record, metrics):
        if record.skipped or metrics is None:
            return
        # bill at wire width: the codec's encoded per-unit byte table
        # (identical to the fp32 table for codec "none").  Wasted bytes
        # (quarantine/duplicates) use the same table — a discarded
        # upload cost its *encoded* size, not fp32 width.
        ub = server.wire_unit_bytes()
        counts = comm.unit_param_counts(server.assign,
                                        server.global_params())
        if "entry_sel" in metrics:
            # buffered-async flush: one entry per buffered update, the
            # topology's buffered byte math (only flushed deltas cross
            # the WAN under hierarchical edges)
            entry_sel = np.asarray(metrics["entry_sel"])
            entry_sel = self._mask_dropped(entry_sel, record)
            record.uplink_bytes = server.topology.buffered_round_bytes(
                entry_sel, np.asarray(metrics["entry_clients"]), ub,
                server.fl)["uplink"]
            record.trained_params = float(np.einsum("cu,u->", entry_sel,
                                                    counts))
            # wasted: bytes the engine saw leave a client but never
            # aggregate (duplicates, in-transit loss) plus entries the
            # validation gate quarantined at flush time
            wasted = float(metrics.get("dropped_bytes", 0.0))
            quar = metrics.get("quarantined")
            if quar is not None:
                qm = (np.asarray(quar) > 0).astype(entry_sel.dtype)
                wasted += server.topology.buffered_round_bytes(
                    entry_sel * qm[:, None],
                    np.asarray(metrics["entry_clients"]), ub,
                    server.fl)["uplink"]
            record.wasted_bytes = wasted
            return
        sel = np.asarray(metrics["sel"])
        if sel.shape[1] != server.assign.n_units:
            # legacy no-assign shim emits a (C, 1) pseudo-unit: the
            # whole model ships for every participating client
            n_up = self._mask_dropped(np.ones((sel.shape[0], 1),
                                              sel.dtype), record).sum()
            record.uplink_bytes = float(ub.sum()) * float(n_up)
            record.trained_params = float(np.einsum("u->", counts)) \
                * float(n_up)
            return
        # bill only clients that actually uploaded: rows zeroed by
        # straggler dropout (effective weight 0) ship nothing
        sel = self._mask_dropped(sel, record)
        record.uplink_bytes = server.topology.round_bytes(
            sel, ub, server.fl)["uplink"]
        record.trained_params = float(np.einsum("cu,u->", sel, counts))
        quar = metrics.get("quarantined")
        if quar is not None:
            # quarantined clients uploaded (billed above) but their
            # deltas were discarded by the validation gate
            qm = (np.asarray(quar) > 0).astype(sel.dtype)
            record.wasted_bytes = server.topology.round_bytes(
                sel * qm[:, None], ub, server.fl)["uplink"]

    @staticmethod
    def _mask_dropped(sel: np.ndarray, record) -> np.ndarray:
        eff = record.effective_weights
        if eff is None or len(eff) != sel.shape[0]:
            return sel
        keep = (np.asarray(eff, np.float32) > 0).astype(sel.dtype)
        return sel * keep[:, None]


class RoundLogger(ServerHook):
    """Print a one-line round summary every ``every`` rounds.

    ``base`` anchors the cadence: a resumed run (non-zero history base,
    e.g. after ``Federation.restore``) logs on the same relative cadence
    as a fresh one — rounds ``base``, ``base+every``, ... — and the
    final round (``total - 1``) always prints."""

    def __init__(self, every: int = 1, total: Optional[int] = None,
                 base: int = 0):
        self.every = max(1, every)
        self.total = total
        self.base = base

    def on_round_end(self, server, record, metrics):
        if record.skipped:
            # a skipped round is an anomaly worth one line regardless
            # of cadence — silent no-op rounds read as hangs
            print(f"  round {record.round:>4d} SKIPPED "
                  f"(all clients dropped)")
            return
        last = self.total is not None and record.round == self.total - 1
        if (record.round - self.base) % self.every and not last:
            return
        line = f"  round {record.round:>4d}"
        line += f" loss={record.loss:.4f}"
        if record.eval_metric is not None:
            line += f" eval={record.eval_metric:.4f}"
        line += f" uplink={record.uplink_bytes/1e6:.1f}MB"
        if record.wasted_bytes > 0.0:
            line += f" wasted={record.wasted_bytes/1e6:.1f}MB"
        if record.sim_time > 0.0:          # buffered-async flush
            line += (f" t_sim={record.sim_time:.1f}"
                     f" stale={record.staleness_mean:.2f}")
        print(line)


class Checkpointer(ServerHook):
    """Persist restartable server state every ``every`` rounds (and at
    fit end)."""

    def __init__(self, path: str, every: int = 0):
        self.path = path
        self.every = every

    def _save(self, server, pending_record=None):
        from ..ckpt import save_server_state
        save_server_state(self.path, server,
                          pending_record=pending_record)

    def on_round_end(self, server, record, metrics):
        # end hooks run before history.append, so the in-flight record
        # rides along as pending_record — without it the checkpoint
        # would pair post-round params/keys with pre-round history and
        # a resume would silently re-run the round
        if self.every and (record.round + 1) % self.every == 0:
            self._save(server, pending_record=record)

    def on_fit_end(self, server, history):
        self._save(server)


class Server:
    """``params`` is the topology *state*: the single global model for
    star topologies (hub, hierarchical), the stacked per-client replica
    tree for gossip.  ``global_params()`` is always the single-model
    view (what ``eval_fn`` sees and what accounting sizes against).
    Callers passing plain model params get them lifted into state via
    ``Topology.init_state`` (identity for star topologies)."""

    def __init__(self, round_step: Callable, assign: UnitAssignment,
                 fl: FLConfig, params, *, eval_fn: Optional[Callable] = None,
                 seed: int = 0, dropout_rate: float = 0.0,
                 hooks: Sequence[ServerHook] = (),
                 topology: Optional[Topology] = None,
                 strategy: Union[str, SelectionStrategy, None] = None):
        # the round step donates its params argument: run_round always
        # reassigns self.params from the output, so the old state is
        # dead at the call and XLA aliases it into the result instead
        # of allocating a second model-sized buffer.  CompileGuard
        # (repro.analysis.compileguard) holds the path to ONE compiled
        # program and names the retrace-triggering argument otherwise.
        self.round_step = CompileGuard(round_step, name="round_step",
                                       max_programs=1, donate_argnums=(0,))
        self.assign = assign
        self.fl = fl
        self.topology = resolve_topology(topology if topology is not None
                                         else fl.topology)
        # own the state outright (donation invalidates the buffers we
        # pass in — a caller-held reference to the init params must
        # survive the first round)
        self.params = jax.tree_util.tree_map(
            jnp.array, self.topology.init_state(params, fl))
        if getattr(fl, "client_shards", 0):
            # the sharded round step commits its params output to the
            # (client,) mesh; committing the initial params the same way
            # keeps round 1 and round 2 on one compiled program (the
            # uncommitted->committed flip would otherwise retrace — and
            # trip the guard)
            from jax.sharding import NamedSharding, PartitionSpec
            from ..launch.mesh import make_client_mesh
            self.params = jax.device_put(
                self.params,
                NamedSharding(make_client_mesh(fl.client_shards),
                              PartitionSpec()))
        self.eval_fn = eval_fn
        self.key = jax.random.PRNGKey(seed)
        # the scored-selection engine (DESIGN.md §11): the server owns
        # the strategy's SelectionState pytree and threads it through
        # the compiled round step; stateless strategies keep sel_state
        # None and the round step is called exactly as before.  The
        # strategy instance is read off the round step itself when the
        # builder attached it (the instance actually baked into the
        # trace — an explicit strategy= override may differ from
        # fl.strategy), falling back to resolving the config name.
        baked = getattr(round_step, "selection_strategy", None)
        if strategy is not None:
            self.strategy = resolve_strategy(strategy, fl.synchronized)
        elif baked is not None:
            self.strategy = baked
        else:
            self.strategy = resolve_strategy(fl.strategy, fl.synchronized)
        self.sel_ctx = SelectionContext(
            n_clients=fl.n_clients, n_units=assign.n_units,
            n_train=fl.resolve_n_train(assign.n_units),
            score_ema=fl.score_ema)
        self.sel_state = self.strategy.init_state(self.sel_ctx)
        self.hooks: List[ServerHook] = [CommAccounting()]
        if dropout_rate > 0.0:
            self.hooks.append(StragglerDropout(dropout_rate))
        self.hooks.extend(hooks)
        self.history: List[RoundRecord] = []
        self.sel_history: List[np.ndarray] = []
        self._ubytes = None
        self._wire_ubytes = None
        # codec axis (core/codecs.py): the server owns the per-client
        # error-feedback residual of a stateful codec and threads it
        # through the compiled round step (None for stateless codecs);
        # checkpointed alongside sel_state for bit-exact resume
        self.codec = codecs.resolve_codec(getattr(fl, "codec", "none"))
        self.codec_state = codecs.init_codec_state(
            self.codec, self.global_params(), fl.n_clients)
        # fault axis (core/faults.py): set by the Federation facade
        # when FLConfig.faults is non-empty; owns every seeded fault
        # draw (numpy SeedSequence domain — never the jax key stream)
        self.fault_injector = None
        # buffered-async engine (core/async_agg.py); attached by the
        # Federation facade when FLConfig.async_buffer > 0
        self.async_engine = None
        # chunk-streamed cohort engine (core/cohort.py); attached when
        # FLConfig.n_registered/cohort_chunk switch the round loop over
        self.cohort_engine = None
        # history_cap retention (DESIGN.md §13): rounds trimmed off the
        # front of sel_history fold their byte/param totals here so
        # comm_summary stays exact while memory stays O(cap * cohort)
        self._sel_base = 0
        self._comm_totals = {"uplink": 0.0, "trained": 0.0, "rounds": 0}

    def next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def global_params(self):
        """Single-model view of the topology state."""
        return self.topology.global_params(self.params, self.fl)

    def unit_bytes(self) -> np.ndarray:
        if self._ubytes is None:
            self._ubytes = comm.unit_bytes(self.assign, self.global_params())
        return self._ubytes

    def wire_unit_bytes(self) -> np.ndarray:
        """Per-unit *encoded* uplink bytes under the active codec —
        what CommAccounting bills (== ``unit_bytes`` for codec
        ``none``, so non-codec accounting is unchanged)."""
        if self._wire_ubytes is None:
            self._wire_ubytes = codecs.codec_unit_bytes(
                self.codec, self.assign, self.global_params(), self.fl)
        return self._wire_ubytes

    def add_hook(self, hook: ServerHook) -> "Server":
        self.hooks.append(hook)
        return self

    def run_round(self, client_batches, weights=None) -> RoundRecord:
        """client_batches: pytree with (C, steps, ...) leaves."""
        if self.async_engine is not None:
            raise RuntimeError(
                "server is in buffered-async mode (FLConfig.async_buffer "
                "> 0); a synchronous round would desync the engine's "
                "version/key bookkeeping — use run()/Federation.fit")
        if self.cohort_engine is not None:
            raise RuntimeError(
                "server is in cohort-engine mode (FLConfig.n_registered/"
                "cohort_chunk); a plain round would desync the engine's "
                "fleet/key bookkeeping — use run()/Federation.fit")
        t0 = time.perf_counter()
        r = len(self.history)
        rk = self.next_key()
        c = self.fl.n_clients
        if weights is None:
            weights = jnp.ones((c,), jnp.float32)
        for hook in self.hooks:
            new_w = hook.on_round_start(self, r, weights)
            if new_w is not None:
                weights = new_w
        n_part = int(np.count_nonzero(np.asarray(weights)))
        eff_w = [float(x) for x in np.asarray(weights)]
        if n_part == 0:
            # every client dropped: a FedAvg denominator of zero — the
            # round is a recorded no-op, global params unchanged.  The
            # record carries loss 0.0 + dropped=True (not NaN: a NaN
            # here used to leak into logs and loss EMAs)
            rec = RoundRecord(r, 0.0, None,
                              time.perf_counter() - t0, 0.0, 0.0,
                              n_participants=0, skipped=True,
                              dropped=True, effective_weights=eff_w)
            self.sel_history.append(
                np.zeros((c, self.assign.n_units), np.float32))
            metrics = None
        else:
            step_kw = {}
            inj = self.fault_injector
            if inj is not None and inj.has_delta:
                plan = inj.corrupt_plan(r, range(c))
                step_kw["fault_plan"] = {
                    "mode": jnp.asarray(plan["mode"]),
                    "scale": jnp.asarray(plan["scale"])}
            if self.codec_state is not None:
                # stateful codec: thread the EF residual through the
                # step; the new residual rides the metrics back out
                step_kw["codec_state"] = self.codec_state
            if self.sel_state is not None:
                self.params, metrics = self.round_step(
                    self.params, client_batches, weights, rk,
                    self.sel_state, **step_kw)
            else:
                self.params, metrics = self.round_step(
                    self.params, client_batches, weights, rk, **step_kw)
            if "codec_state" in metrics:
                self.codec_state = metrics.pop("codec_state")
            self.sel_history.append(np.asarray(metrics["sel"]))
            ev = None
            if self.eval_fn is not None:
                ev = float(self.eval_fn(self.global_params()))
            rec = RoundRecord(r, float(metrics["loss_mean"]), ev,
                              time.perf_counter() - t0, 0.0, 0.0,
                              n_participants=n_part,
                              effective_weights=eff_w)
        # fold the round's norm telemetry into the selection state
        # BEFORE the end-of-round hooks run, so a Checkpointer hook
        # saves the post-round state (bit-exact mid-fit resume)
        self.update_sel_state(self._round_telemetry(r, metrics, eff_w))
        for hook in self.hooks:
            hook.on_round_end(self, rec, metrics)
        rec.seconds = time.perf_counter() - t0
        self.history.append(rec)
        self._trim_history()
        return rec

    def _trim_history(self) -> None:
        """Enforce ``FLConfig.history_cap``: fold selection rows older
        than the cap into running uplink/params totals and drop them,
        bounding accounting memory at O(cap * cohort) for long fits
        while keeping ``comm_summary`` exact."""
        cap = getattr(self.fl, "history_cap", 0)
        if not cap:
            return
        while len(self.sel_history) > cap:
            s = self.sel_history.pop(0)
            i = self._sel_base
            rec = self.history[i] if i < len(self.history) else None
            eff = rec.effective_weights if rec is not None else None
            if eff is not None and len(eff) == s.shape[0]:
                s = s * (np.asarray(eff, np.float32) > 0
                         ).astype(s.dtype)[:, None]
            if s.shape[1] == self.assign.n_units:
                counts = comm.unit_param_counts(self.assign,
                                                self.global_params())
                self._comm_totals["uplink"] += self.topology.round_bytes(
                    s, self.wire_unit_bytes(), self.fl)["uplink"]
                self._comm_totals["trained"] += float(
                    np.einsum("cu,u->", s, counts))
                self._comm_totals["rounds"] += 1
            if rec is not None:
                # the O(cohort) weight list already served accounting;
                # null it so long fits keep O(1) state per old round
                rec.effective_weights = None
            self._sel_base += 1

    def _round_telemetry(self, round_idx: int, metrics: Optional[Dict],
                         eff_w: Sequence[float]):
        """One sync round's NormTelemetry, or None (stateless strategy,
        skipped round, or off-cadence under FLConfig.score_every).
        Dropped clients (effective weight 0) shipped nothing and
        contribute no telemetry, matching the aggregation."""
        if self.sel_state is None or metrics is None \
                or round_idx % self.fl.score_every != 0:
            return None
        active = (np.asarray(eff_w, np.float32) > 0).astype(np.float32)
        sq = np.asarray(metrics["unit_sqnorm"], np.float32)
        sel = np.asarray(metrics["sel"], np.float32)
        counts = (sel * active[:, None]).sum(0)
        # synchronous participants all carry weight 1, so the weighted
        # and raw counts coincide (staleness confidence = 1)
        return NormTelemetry(unit_sqnorm=(sq * active[:, None]).sum(0),
                             unit_count=counts, unit_raw_count=counts)

    def update_sel_state(self, telemetry) -> None:
        """Advance the scored-selection state one round/flush (no-op for
        stateless strategies).  The async engine calls this per flush
        with staleness-weighted telemetry."""
        if self.sel_state is not None:
            self.sel_state = self.strategy.update_state(
                self.sel_state, self.sel_ctx, telemetry)

    def attach_async_engine(self, engine) -> "Server":
        """Switch the server to buffered-async rounds: ``run`` drives
        the engine's flush loop (one history record per flush) and
        ``comm_summary`` uses its per-flush buffered accounting."""
        self.async_engine = engine
        return self

    def attach_cohort_engine(self, engine) -> "Server":
        """Switch the server to chunk-streamed cohort rounds
        (core/cohort.py): ``run`` drives the engine's round loop; its
        records are ordinary sync records, so accounting/summary need
        no special casing."""
        self.cohort_engine = engine
        return self

    def run(self, rounds: int, batch_fn: Callable[[int], Any],
            weights=None, log_every: int = 0) -> List[RoundRecord]:
        if self.async_engine is not None:
            # buffered-async mode: batch_fn is indexed by each client's
            # own dispatch window, not a shared round counter
            return self.async_engine.run(rounds, batch_fn,
                                         weights=weights,
                                         log_every=log_every)
        if self.cohort_engine is not None:
            # cohort-engine mode: batch_fn(round_idx, client_ids) loads
            # one chunk of the sampled cohort at a time
            return self.cohort_engine.run(rounds, batch_fn,
                                          weights=weights,
                                          log_every=log_every)
        extra = [RoundLogger(log_every, total=len(self.history) + rounds,
                             base=len(self.history))] \
            if log_every else []
        self.hooks.extend(extra)
        try:
            for r in range(rounds):
                self.run_round(batch_fn(r), weights)
        finally:
            for h in extra:
                self.hooks.remove(h)
        for hook in self.hooks:
            hook.on_fit_end(self, self.history)
        return self.history

    def _wasted_summary(self) -> Dict[str, float]:
        """Fault-accounting columns (DESIGN.md §14), from the per-round
        records CommAccounting already filled."""
        per_round = [r.wasted_bytes for r in self.history]
        total = float(np.sum(per_round)) if per_round else 0.0
        return {"total_wasted_bytes": total,
                "avg_wasted_bytes": total / max(1, len(per_round))}

    def comm_summary(self) -> Dict[str, float]:
        if self.async_engine is not None and self.async_engine.started:
            return self.async_engine.comm_summary()
        if self._sel_base:
            return dict(self._capped_summary(), **self._wasted_summary())
        if not self.sel_history:
            return {"avg_uplink_bytes": 0.0, "avg_trained_params": 0.0,
                    "total_uplink_bytes": 0.0, "reduction_vs_full": 0.0,
                    "total_wasted_bytes": 0.0, "avg_wasted_bytes": 0.0}
        # selection rows of clients whose effective weight was zeroed
        # (straggler dropout) shipped nothing — mask them out so the
        # run summary matches the per-round records
        masked = []
        for i, s in enumerate(self.sel_history):
            eff = self.history[i].effective_weights \
                if i < len(self.history) else None
            if eff is not None and len(eff) == s.shape[0]:
                s = s * (np.asarray(eff, np.float32) > 0
                         ).astype(s.dtype)[:, None]
            masked.append(s)
        hist = np.stack(masked)
        if hist.shape[2] != self.assign.n_units:   # legacy no-assign shim
            per_round = [r.uplink_bytes for r in self.history]
            return dict({"avg_uplink_bytes": float(np.mean(per_round)),
                         "avg_trained_params": float(np.mean(
                             [r.trained_params for r in self.history])),
                         "total_uplink_bytes": float(np.sum(per_round)),
                         "reduction_vs_full": 0.0},
                        **self._wasted_summary())
        sum_kw = {}
        if self.codec.name != "none":
            # bill the run at encoded wire width; custom topologies
            # without the wire_ubytes parameter keep working when no
            # codec is configured
            sum_kw["wire_ubytes"] = self.wire_unit_bytes()
        return dict(self.topology.summary(self.assign,
                                          self.global_params(),
                                          hist, self.fl, **sum_kw),
                    **self._wasted_summary())

    def _capped_summary(self) -> Dict[str, float]:
        """``comm_summary`` with ``history_cap`` trimming active: the
        folded totals of trimmed rounds plus the retained window,
        through the same per-round ``Topology.round_bytes`` math — the
        result matches the uncapped summary up to float accumulation
        order (regression-tested)."""
        ub = self.unit_bytes()
        wub = self.wire_unit_bytes()
        counts = comm.unit_param_counts(self.assign, self.global_params())
        up = self._comm_totals["uplink"]
        tp = self._comm_totals["trained"]
        n = self._comm_totals["rounds"]
        for i, s in enumerate(self.sel_history):
            rec_i = self._sel_base + i
            eff = self.history[rec_i].effective_weights \
                if rec_i < len(self.history) else None
            if eff is not None and len(eff) == s.shape[0]:
                s = s * (np.asarray(eff, np.float32) > 0
                         ).astype(s.dtype)[:, None]
            up += self.topology.round_bytes(s, wub, self.fl)["uplink"]
            tp += float(np.einsum("cu,u->", s, counts))
            n += 1
        if not n:
            return {"avg_uplink_bytes": 0.0, "avg_trained_params": 0.0,
                    "total_uplink_bytes": 0.0, "reduction_vs_full": 0.0}
        # full-model uplink is a per-round constant given the cohort
        # shape, so the reduction needs no retained history
        c = self.sel_history[0].shape[0] if self.sel_history \
            else self.fl.n_clients
        full = self.topology.round_bytes(
            np.ones((c, self.assign.n_units), np.float32), ub,
            self.fl)["uplink"]
        return {
            "avg_uplink_bytes": up / n,
            "avg_trained_params": tp / n,
            "total_uplink_bytes": up,
            "reduction_vs_full": 1.0 - (up / n) / full if full else 0.0,
        }
