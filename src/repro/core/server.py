"""Round orchestration (paper Alg. 1) — the FEDn-combiner role.

The ``Server`` drives rounds at the Python level: per-round client
sampling, handing shards to the compiled ``round_step``, evaluation,
straggler dropout simulation, comm accounting and history.  Everything
numerically heavy is inside the jitted round step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pytree as pt
from . import comm
from .federation import FLConfig, build_round_step
from .masking import UnitAssignment


@dataclasses.dataclass
class RoundRecord:
    round: int
    loss: float
    eval_metric: Optional[float]
    seconds: float
    uplink_bytes: float
    trained_params: float


class Server:
    def __init__(self, round_step: Callable, assign: UnitAssignment,
                 fl: FLConfig, params, *, eval_fn: Optional[Callable] = None,
                 seed: int = 0, dropout_rate: float = 0.0):
        self.round_step = jax.jit(round_step)
        self.assign = assign
        self.fl = fl
        self.params = params
        self.eval_fn = eval_fn
        self.key = jax.random.PRNGKey(seed)
        self.dropout_rate = dropout_rate
        self.history: List[RoundRecord] = []
        self.sel_history: List[np.ndarray] = []
        self._ubytes = None

    def _unit_bytes(self):
        if self._ubytes is None:
            self._ubytes = comm.unit_bytes(self.assign, self.params)
        return self._ubytes

    def run_round(self, client_batches, weights=None) -> RoundRecord:
        """client_batches: pytree with (C, steps, ...) leaves."""
        t0 = time.perf_counter()
        r = len(self.history)
        self.key, rk = jax.random.split(self.key)
        c = self.fl.n_clients
        if weights is None:
            weights = jnp.ones((c,), jnp.float32)
        if self.dropout_rate > 0.0:
            # straggler simulation: dropped clients contribute weight 0
            self.key, dk = jax.random.split(self.key)
            keep = jax.random.bernoulli(dk, 1.0 - self.dropout_rate, (c,))
            weights = weights * keep.astype(jnp.float32)
        self.params, metrics = self.round_step(self.params, client_batches,
                                               weights, rk)
        sel = np.asarray(metrics["sel"])
        self.sel_history.append(sel)
        ub = self._unit_bytes()
        if sel.shape[1] == self.assign.n_units:
            hub = comm.hub_round_bytes(sel, ub)
            uplink = hub["uplink"]
            trained = float(np.einsum(
                "cu,u->", sel, comm.unit_param_counts(self.assign,
                                                      self.params)))
        else:  # full-model baseline records full transfer
            uplink = float(ub.sum()) * c
            trained = float(pt.param_count(self.params)) * c
        ev = None
        if self.eval_fn is not None:
            ev = float(self.eval_fn(self.params))
        rec = RoundRecord(r, float(metrics["loss_mean"]), ev,
                          time.perf_counter() - t0, uplink, trained)
        self.history.append(rec)
        return rec

    def run(self, rounds: int, batch_fn: Callable[[int], Any],
            weights=None, log_every: int = 0) -> List[RoundRecord]:
        for r in range(rounds):
            rec = self.run_round(batch_fn(r), weights)
            if log_every and (r % log_every == 0 or r == rounds - 1):
                print(f"  round {rec.round:>4d} loss={rec.loss:.4f}"
                      + (f" eval={rec.eval_metric:.4f}"
                         if rec.eval_metric is not None else "")
                      + f" uplink={rec.uplink_bytes/1e6:.1f}MB")
        return self.history

    def comm_summary(self) -> Dict[str, float]:
        ub = self._unit_bytes()
        hist = np.stack(self.sel_history) if self.sel_history else \
            np.zeros((0, self.fl.n_clients, self.assign.n_units))
        if hist.size and hist.shape[2] == self.assign.n_units:
            return comm.table4_row(self.assign, self.params, hist)
        return {"avg_uplink_bytes": float(ub.sum()) * self.fl.n_clients,
                "avg_trained_params": float(pt.param_count(self.params)),
                "total_uplink_bytes": float(ub.sum()) * self.fl.n_clients *
                max(len(self.history), 1),
                "reduction_vs_full": 0.0}
