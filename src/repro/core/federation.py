"""Federated round as a single compiled step (DESIGN.md §4).

``build_round_step`` closes over the model loss, unit assignment and a
**registered selection strategy** (core/strategies.py) and returns

    round_step(global_params, client_batches, weights, round_key)
        -> (new_global_params, metrics)

where ``client_batches`` leaves carry (C, local_steps, ...) and the
client dim maps onto the ``client`` mesh axis under pjit (cross-device
mode) or onto pods (cross-silo).  Everything inside — selection, masked
local training, participation-weighted aggregation — is one XLA program;
the cross-client reduce in the aggregation is the only cross-client
collective.

Strategies whose ``dense`` flag is set (the ``full`` baseline) skip the
per-unit masking and aggregate with plain FedAvg — the same trace the
old dedicated full-model path compiled, so results are bit-exact with
the conventional baseline.  There is no separate full-model builder any
more; ``build_fullmodel_round_step`` survives only as a deprecation
shim delegating to the ``full`` strategy.

Topology (cross_device vs cross_silo) changes nothing here; it changes
the mesh view the step is pjit-ed with (launch/mesh.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from .aggregation import masked_fedavg, fedavg
from .client import local_update
from .masking import UnitAssignment, mask_tree
from .strategies import (SelectionContext, SelectionStrategy,
                         resolve_strategy)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int
    n_train_units: int = 0        # N_l in the paper
    strategy: str = "uniform"     # any registered strategy name
    synchronized: bool = False    # beyond-paper collective shrinking
    lr: float = 1e-2              # paper: 0.01
    optimizer: str = "adam"       # paper: ADAM
    prox_mu: float = 0.0          # >0 -> FedProx
    always_train_head: bool = False
    # alternative to n_train_units when the unit count isn't known yet
    # (the paper's 25%/50%/75% settings); resolved against the unit
    # assignment by build_round_step
    train_fraction: Optional[float] = None

    def resolve_n_train(self, n_units: int) -> int:
        if self.train_fraction is not None:
            from .freezing import n_train_from_fraction
            return n_train_from_fraction(n_units, self.train_fraction)
        return self.n_train_units


def build_round_step(loss_fn: Callable, assign: UnitAssignment,
                     fl: FLConfig, loss_kwargs: Optional[Dict] = None,
                     *, strategy: Union[str, SelectionStrategy, None] = None,
                     scores: Optional[jnp.ndarray] = None):
    """Returns the jit-able round_step function.

    ``strategy`` overrides ``fl.strategy`` with a name or an instance
    (e.g. one constructed in user code and never registered).
    """
    strat = resolve_strategy(strategy if strategy is not None
                             else fl.strategy, fl.synchronized)
    n_train = fl.resolve_n_train(assign.n_units)
    if not strat.dense and not 1 <= n_train <= assign.n_units:
        raise ValueError(
            f"n_train={n_train} out of range for {assign.n_units} units; "
            "set FLConfig.n_train_units or train_fraction")
    ctx = SelectionContext(n_clients=fl.n_clients, n_units=assign.n_units,
                           n_train=n_train, scores=scores)

    def round_step(global_params, client_batches, weights, round_key):
        sel = strat.select(round_key, ctx)
        if fl.always_train_head:
            sel = sel.at[:, -1].set(1.0)

        if strat.dense:
            # every unit trained: unmasked local step + plain FedAvg —
            # bit-exact with the conventional-FedAvg baseline trace
            ones_mask = jax.tree_util.tree_map(
                lambda x: jnp.ones((), jnp.float32), global_params)

            def one_client_dense(batches):
                return local_update(loss_fn, global_params, ones_mask,
                                    batches, lr=fl.lr,
                                    optimizer=fl.optimizer,
                                    prox_mu=fl.prox_mu,
                                    loss_kwargs=loss_kwargs)

            deltas, metrics = jax.vmap(one_client_dense)(client_batches)
            new_params = fedavg(global_params, deltas, weights)
        else:
            def one_client(sel_row, batches):
                mask = mask_tree(assign, sel_row, global_params)
                return local_update(loss_fn, global_params, mask, batches,
                                    lr=fl.lr, optimizer=fl.optimizer,
                                    prox_mu=fl.prox_mu,
                                    loss_kwargs=loss_kwargs)

            deltas, metrics = jax.vmap(one_client)(sel, client_batches)
            new_params = masked_fedavg(global_params, deltas, sel, weights,
                                       assign)
        out_metrics = {
            "loss_mean": metrics["loss_mean"].mean(),
            "loss_per_client": metrics["loss_mean"],
            "sel": sel,
        }
        return new_params, out_metrics

    return round_step


def build_fullmodel_round_step(loss_fn: Callable, fl: FLConfig,
                               loss_kwargs: Optional[Dict] = None,
                               assign: Optional[UnitAssignment] = None):
    """Deprecated shim: the conventional FedAvg baseline is now the
    registered ``full`` strategy on the unified path.

    ``assign`` is optional for call-site compatibility; without it the
    selection matrix in the metrics is (C, 1) as before (a single
    pseudo-unit covering the whole model).
    """
    warnings.warn(
        "build_fullmodel_round_step is deprecated; use "
        "build_round_step with FLConfig(strategy='full') or "
        "Federation.from_config instead", DeprecationWarning, stacklevel=2)
    if assign is None:
        assign = UnitAssignment(1, None, ("model",))
    fl = dataclasses.replace(fl, strategy="full",
                             n_train_units=assign.n_units,
                             prox_mu=0.0, always_train_head=False)
    return build_round_step(loss_fn, assign, fl, loss_kwargs)
