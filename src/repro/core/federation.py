"""Federated round as a single compiled step (DESIGN.md §4).

``build_round_step`` closes over the model loss, unit assignment and
strategy and returns

    round_step(global_params, client_batches, weights, round_key)
        -> (new_global_params, metrics)

where ``client_batches`` leaves carry (C, local_steps, ...) and the
client dim maps onto the ``client`` mesh axis under pjit (cross-device
mode) or onto pods (cross-silo).  Everything inside — selection, masked
local training, participation-weighted aggregation — is one XLA program;
the cross-client reduce in the aggregation is the only cross-client
collective.

Topology (cross_device vs cross_silo) changes nothing here; it changes
the mesh view the step is pjit-ed with (launch/mesh.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import freezing
from .aggregation import masked_fedavg, fedavg
from .client import local_update
from .masking import UnitAssignment, mask_tree

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int
    n_train_units: int            # N_l in the paper
    strategy: str = "uniform"     # uniform | fixed_last | weighted | full
    synchronized: bool = False    # beyond-paper collective shrinking
    lr: float = 1e-2              # paper: 0.01
    optimizer: str = "adam"       # paper: ADAM
    prox_mu: float = 0.0          # >0 -> FedProx
    always_train_head: bool = False


def build_round_step(loss_fn: Callable, assign: UnitAssignment,
                     fl: FLConfig, loss_kwargs: Optional[Dict] = None):
    """Returns the jit-able round_step function."""

    def round_step(global_params, client_batches, weights, round_key):
        sel = freezing.select_clients(
            round_key, fl.n_clients, assign.n_units, fl.n_train_units,
            strategy=fl.strategy, synchronized=fl.synchronized)
        if fl.always_train_head:
            sel = sel.at[:, -1].set(1.0)

        def one_client(sel_row, batches):
            mask = mask_tree(assign, sel_row, global_params)
            return local_update(loss_fn, global_params, mask, batches,
                                lr=fl.lr, optimizer=fl.optimizer,
                                prox_mu=fl.prox_mu, loss_kwargs=loss_kwargs)

        deltas, metrics = jax.vmap(one_client)(sel, client_batches)
        new_params = masked_fedavg(global_params, deltas, sel, weights,
                                   assign)
        out_metrics = {
            "loss_mean": metrics["loss_mean"].mean(),
            "loss_per_client": metrics["loss_mean"],
            "sel": sel,
        }
        return new_params, out_metrics

    return round_step


def build_fullmodel_round_step(loss_fn: Callable, fl: FLConfig,
                               loss_kwargs: Optional[Dict] = None):
    """Conventional FedAvg baseline (every unit trained, plain average)."""

    def round_step(global_params, client_batches, weights, round_key):
        ones_mask = jax.tree_util.tree_map(
            lambda x: jnp.ones((), jnp.float32), global_params)

        def one_client(batches):
            return local_update(loss_fn, global_params, ones_mask, batches,
                                lr=fl.lr, optimizer=fl.optimizer,
                                loss_kwargs=loss_kwargs)

        deltas, metrics = jax.vmap(one_client)(client_batches)
        new_params = fedavg(global_params, deltas, weights)
        return new_params, {"loss_mean": metrics["loss_mean"].mean(),
                            "loss_per_client": metrics["loss_mean"],
                            "sel": jnp.ones((fl.n_clients, 1))}

    return round_step
