"""Federated round as a single compiled step (DESIGN.md §4).

``build_round_step`` closes over the model loss, unit assignment and a
**registered selection strategy** (core/strategies.py) and returns

    round_step(global_params, client_batches, weights, round_key)
        -> (new_global_params, metrics)

where ``client_batches`` leaves carry (C, local_steps, ...) and the
client dim maps onto the ``client`` mesh axis under pjit (cross-device
mode) or onto pods (cross-silo).  Everything inside — selection, masked
local training, participation-weighted aggregation — is one XLA program;
the cross-client reduce in the aggregation is the only cross-client
collective.

Strategies whose ``dense`` flag is set (the ``full`` baseline) skip the
per-unit masking and aggregate with plain FedAvg — the same trace the
old dedicated full-model path compiled, so results are bit-exact with
the conventional baseline.  There is no separate full-model builder any
more; ``build_fullmodel_round_step`` survives only as a deprecation
shim delegating to the ``full`` strategy.

Topology is a second plugin axis (core/topology.py): ``fl.topology``
names a registered :class:`Topology` plugin that owns the aggregation
stage of the round step (hub star, hierarchical two-stage, gossip
mixing), its byte accounting and its mesh view.  ``build_round_step``
here is a thin resolver that delegates to the plugin — the ``hub``
default compiles the identical trace this module compiled before the
topology layer existed (bit-exact, regression-tested).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax.numpy as jnp

from .masking import UnitAssignment
from .strategies import SelectionStrategy
from .topology import Topology, resolve_topology

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int
    n_train_units: int = 0        # N_l in the paper
    strategy: str = "uniform"     # any registered strategy name
    synchronized: bool = False    # beyond-paper collective shrinking
    lr: float = 1e-2              # paper: 0.01
    optimizer: str = "adam"       # paper: ADAM
    prox_mu: float = 0.0          # >0 -> FedProx
    always_train_head: bool = False
    # alternative to n_train_units when the unit count isn't known yet
    # (the paper's 25%/50%/75% settings); resolved against the unit
    # assignment by build_round_step
    train_fraction: Optional[float] = None
    # federation topology: any registered Topology plugin name
    # (core/topology.py: "hub" | "hierarchical" | "gossip" | custom)
    topology: str = "hub"
    # edge-aggregator count for the hierarchical topology; None means
    # ~sqrt(n_clients) so neither tier degenerates
    n_edges: Optional[int] = None
    # packed trained-unit round path (DESIGN.md §7): carry only the
    # round's selected slot rows through local training, optimizer
    # state and the cross-client reduce.  Dense-masked stays the
    # default; packed is regression-tested bit-comparable against it.
    packed: bool = False
    # fused Pallas aggregation (kernels/masked_agg): "auto" compiles
    # the kernel on TPU/GPU and keeps the jnp reference elsewhere;
    # "on" forces the kernel (interpreter on CPU), "off" the reference.
    # The packed path has its own segment-sum reduce and ignores this.
    fused_agg: str = "auto"
    # semi-async buffered aggregation (core/async_agg.py, DESIGN.md §8):
    # >0 switches the round loop to FedBuff-style flush rounds — the
    # server buffers this many packed per-client updates (tagged with
    # their origin round) and applies them as one global step.  0 keeps
    # the synchronous loop.
    async_buffer: int = 0
    # stale-delta reweighting rule (register_staleness registry):
    # "polynomial" = FedBuff's 1/(1+s)^alpha, "constant" = no decay
    staleness: str = "polynomial"
    staleness_alpha: float = 0.5
    # simulated client-latency distribution for the async scheduler:
    # "none" | "exponential[:scale]" | "lognormal[:sigma]" |
    # "pareto[:alpha]" (heavy-tailed straggler regime); draws are pure
    # functions of (seed, client, dispatch), so runs replay bit-exactly
    client_delay_dist: str = "none"
    # scored selection (DESIGN.md §11): EMA decay for the per-unit
    # gradient-norm scores a stateful strategy (score_weighted, ...)
    # maintains — s' = score_ema * s + (1 - score_ema) * observed_norm
    score_ema: float = 0.9
    # state-update cadence: fold telemetry into the selection state
    # every this many rounds/flushes (1 = every round; the round
    # counter advances regardless)
    score_every: int = 1
    # --- fleet-scale cohort engine (core/cohort.py, DESIGN.md §13) ---
    # registered fleet size R: >0 attaches the CohortEngine, which
    # samples an n_clients-sized cohort out of R registered clients
    # every round (host state stays O(R) scalars + O(cohort) arrays)
    n_registered: int = 0
    # stream the cohort through the round in chunks of this many
    # clients (0 = single shot); must divide n_clients.  Any chunking
    # is bitwise-equal to the single-shot vmapped round.
    cohort_chunk: int = 0
    # registered ClientSampler name: which R-fleet clients form the
    # round's cohort ("uniform" | "loss_proportional" |
    # "telemetry_driven" | custom)
    client_sampler: str = "uniform"
    # EMA decay of the fleet's per-client loss/grad-norm signals the
    # scored samplers read
    sampler_ema: float = 0.9
    # split the in-flight cohort's local training over this many device
    # groups of the (client,) mesh via shard_map (0 = plain vmap on one
    # device); rows are bitwise independent of the split
    client_shards: int = 0
    # CommAccounting retention cap: keep at most this many rounds of
    # per-client selection rows on the host (0 = unbounded).  Older
    # rounds fold into running totals, so comm_summary stays exact
    # while accounting memory stays O(cap * cohort)
    history_cap: int = 0
    # --- fault injection + defenses (core/faults.py, DESIGN.md §14) ---
    # chaos spec "name:prob[,name:prob[:param]]" over the registered
    # fault kinds (crash, nan, inf, bitflip, scale, duplicate, torn,
    # kill).  "" = no injection.  A spec that names delta faults — even
    # at rate 0 — compiles the corruption transform and validation gate
    # into the packed round step (both bitwise identities at rate 0)
    faults: str = ""
    # validation-gate norm threshold: quarantine any upload whose total
    # valid-slot delta L2 norm exceeds this (0 = finiteness check only,
    # and the gate is compiled in only when delta faults are configured)
    max_delta_norm: float = 0.0
    # async-path permanent packet loss: each (client, seq) update is
    # lost with this probability (seeded, DelayScheduler draw domain) —
    # the engine re-dispatches the client, nothing enters the buffer
    client_drop_prob: float = 0.0
    # crash handling: bounded resampling attempts per crashed cohort
    # slot (common/retry.py jittered backoff) before the slot degrades
    # to a zero-weight hole in the round
    fault_retries: int = 3
    # --- uplink compression codec axis (core/codecs.py, DESIGN.md §16) ---
    # registered codec applied to packed trained-slot deltas before they
    # cross the WAN: "none" | "qint8" | "qint4" | "topk_ef" | custom.
    # "none" compiles no transform at all (bitwise-equal to pre-codec
    # rounds); the others multiply a lossy factor on the structural
    # freeze reduction and CommAccounting bills encoded wire bytes.
    codec: str = "none"
    # top-k keep fraction per slot row for the topk_ef codec
    # (k = max(1, ceil(codec_topk * row_params)))
    codec_topk: float = 0.1

    def __post_init__(self):
        # validate the knobs whose misuse only surfaces rounds later
        # (a train_fraction of 25 instead of 0.25 "works" until the
        # resolved n_train overruns the unit count) at build time
        if self.n_clients < 1:
            raise ValueError(
                f"n_clients must be >= 1, got {self.n_clients}")
        if self.n_train_units < 0:
            raise ValueError(
                f"n_train_units must be >= 0 (0 = use train_fraction), "
                f"got {self.n_train_units}")
        if self.lr <= 0.0:
            raise ValueError(f"lr must be > 0, got {self.lr}")
        if self.prox_mu < 0.0:
            raise ValueError(
                f"prox_mu must be >= 0 (0 = plain FedAvg), got "
                f"{self.prox_mu}")
        if self.async_buffer < 0:
            raise ValueError(
                f"async_buffer must be >= 0 (0 = synchronous), got "
                f"{self.async_buffer}")
        if self.staleness_alpha < 0.0:
            raise ValueError(
                f"staleness_alpha must be >= 0, got "
                f"{self.staleness_alpha}")
        if self.train_fraction is not None \
                and not 0.0 < self.train_fraction <= 1.0:
            raise ValueError(
                f"train_fraction must be in (0, 1] (the paper's 25%/50%/"
                f"75% settings are 0.25/0.5/0.75), got {self.train_fraction}")
        if not 0.0 <= self.score_ema < 1.0:
            raise ValueError(
                f"score_ema must be in [0, 1) (EMA decay; 0 = no "
                f"smoothing), got {self.score_ema}")
        if self.score_every < 1:
            raise ValueError(
                f"score_every must be >= 1, got {self.score_every}")
        if self.n_registered and self.n_registered < self.n_clients:
            raise ValueError(
                f"n_registered={self.n_registered} must be >= the "
                f"cohort size n_clients={self.n_clients} (0 = cohort "
                f"is the whole fleet)")
        if self.cohort_chunk:
            if self.cohort_chunk < 0 or self.n_clients % self.cohort_chunk:
                valid = [d for d in range(1, self.n_clients + 1)
                         if self.n_clients % d == 0]
                raise ValueError(
                    f"cohort_chunk={self.cohort_chunk} must divide the "
                    f"cohort of {self.n_clients} clients so every chunk "
                    f"compiles to one static shape; valid chunk sizes: "
                    f"{valid}")
        if self.client_shards:
            width = self.cohort_chunk or self.n_clients
            if self.client_shards < 0 or width % self.client_shards:
                raise ValueError(
                    f"client_shards={self.client_shards} must divide "
                    f"the vmapped cohort width {width} "
                    f"({'chunk size' if self.cohort_chunk else 'cohort'})")
        if not 0.0 <= self.sampler_ema < 1.0:
            raise ValueError(
                f"sampler_ema must be in [0, 1), got {self.sampler_ema}")
        if self.history_cap < 0:
            raise ValueError(
                f"history_cap must be >= 0 (0 = unbounded), got "
                f"{self.history_cap}")
        if self.history_cap and self.async_buffer:
            raise ValueError(
                "history_cap with async_buffer is not supported yet: "
                "buffered flush accounting keeps per-flush entry rows; "
                "cap the sync/cohort paths or leave history uncapped")
        if self.uses_cohort_engine() and self.async_buffer:
            raise ValueError(
                "the cohort engine (n_registered/cohort_chunk) and the "
                "buffered-async engine (async_buffer) both own the "
                "round loop — set one of them, not both")
        if self.max_delta_norm < 0.0:
            raise ValueError(
                f"max_delta_norm must be >= 0 (0 = finiteness gate "
                f"only), got {self.max_delta_norm}")
        if self.fault_retries < 0:
            raise ValueError(
                f"fault_retries must be >= 0, got {self.fault_retries}")
        if not 0.0 <= self.client_drop_prob < 1.0:
            raise ValueError(
                f"client_drop_prob must be in [0, 1), got "
                f"{self.client_drop_prob}")
        if self.client_drop_prob > 0.0 and not self.async_buffer:
            raise ValueError(
                "client_drop_prob models lost async updates; it needs "
                "the buffered engine (async_buffer > 0)")
        if not 0.0 < self.codec_topk <= 1.0:
            raise ValueError(
                f"codec_topk must be in (0, 1] (keep fraction per slot "
                f"row), got {self.codec_topk}")
        if self.codec != "none":
            # resolve at config time so typos fail before any compile
            from .codecs import resolve_codec
            cd = resolve_codec(self.codec)
            if not self.packed:
                raise ValueError(
                    "codecs transform packed trained-slot deltas: set "
                    "packed=True")
            if self.topology == "gossip":
                raise ValueError(
                    "the gossip topology exchanges full model replicas "
                    "and has no packed uplink; codecs need hub or "
                    "hierarchical")
            if cd.stateful and self.uses_cohort_engine():
                raise ValueError(
                    "error-feedback codec state is per in-flight client; "
                    "the chunked cohort engine streams stateless chunks — "
                    "use qint8/qint4 there, or drop "
                    "n_registered/cohort_chunk")
        if self.faults or self.max_delta_norm:
            # fail at config time, not rounds later: parse the spec and
            # check each fault's seam has a round path that can host it
            from .faults import parse_faults
            parsed = parse_faults(self.faults)
            if any(f.seam == "delta" for f in parsed) \
                    or self.max_delta_norm > 0.0:
                if not self.packed:
                    raise ValueError(
                        "delta faults and max_delta_norm run inside the "
                        "packed scatter-accumulate: set packed=True")
                if self.topology == "gossip":
                    raise ValueError(
                        "delta faults need a packed aggregation path; "
                        "the gossip topology has none")
            if any(f.seam == "delivery" for f in parsed) \
                    and not self.async_buffer:
                raise ValueError(
                    "delivery faults (duplicate, torn) perturb the "
                    "BufferedAggregator: set async_buffer > 0")
            if any(f.name == "torn" for f in parsed) and not self.packed:
                raise ValueError(
                    "torn delivery corrupts packed payload bytes; the "
                    "validation gate that catches it runs on the packed "
                    "path: set packed=True")

    def uses_cohort_engine(self) -> bool:
        """Whether Federation attaches the chunk-streaming CohortEngine
        (core/cohort.py) instead of the plain synchronous loop."""
        return bool(self.n_registered or self.cohort_chunk)

    def resolve_fused_agg(self) -> bool:
        """Whether the round step should aggregate through the fused
        Pallas kernel (resolved once at build time)."""
        if self.fused_agg == "auto":
            import jax
            return jax.default_backend() in ("tpu", "gpu")
        if self.fused_agg in ("on", "off"):
            return self.fused_agg == "on"
        raise ValueError(
            f"fused_agg must be 'auto', 'on' or 'off', got "
            f"{self.fused_agg!r}")

    def resolve_n_train(self, n_units: int) -> int:
        if self.train_fraction is not None:
            from .freezing import n_train_from_fraction
            return n_train_from_fraction(n_units, self.train_fraction)
        return self.n_train_units

    def resolve_n_slots(self, n_units: int) -> int:
        """Static slot budget of the packed round path (DESIGN.md §7):
        the trained-unit count plus the optional always-trained head —
        the one formula every packed/buffered shape derives from."""
        return min(n_units, self.resolve_n_train(n_units)
                   + (1 if self.always_train_head else 0))

    def resolve_n_edges(self) -> int:
        if self.n_edges is not None:
            if not 1 <= self.n_edges <= self.n_clients:
                raise ValueError(f"n_edges={self.n_edges} out of range "
                                 f"for {self.n_clients} clients")
            return self.n_edges
        return max(1, round(self.n_clients ** 0.5))


def build_round_step(loss_fn: Callable, assign: UnitAssignment,
                     fl: FLConfig, loss_kwargs: Optional[Dict] = None,
                     *, strategy: Union[str, SelectionStrategy, None] = None,
                     scores: Optional[jnp.ndarray] = None,
                     topology: Union[str, Topology, None] = None):
    """Returns the jit-able round_step function.

    ``strategy`` overrides ``fl.strategy`` and ``topology`` overrides
    ``fl.topology`` with a name or an instance (e.g. one constructed in
    user code and never registered).  For stateful topologies (gossip)
    the step maps topology *state* -> state; ``Topology.init_state`` /
    ``global_params`` convert to and from a single model.
    """
    topo = resolve_topology(topology if topology is not None
                            else fl.topology)
    return topo.build_round_step(loss_fn, assign, fl, loss_kwargs,
                                 strategy=strategy, scores=scores)


def build_fullmodel_round_step(loss_fn: Callable, fl: FLConfig,
                               loss_kwargs: Optional[Dict] = None,
                               assign: Optional[UnitAssignment] = None):
    """Deprecated shim: the conventional FedAvg baseline is now the
    registered ``full`` strategy on the unified path.

    ``assign`` is optional for call-site compatibility; without it the
    selection matrix in the metrics is (C, 1) as before (a single
    pseudo-unit covering the whole model).
    """
    warnings.warn(
        "build_fullmodel_round_step is deprecated; use "
        "build_round_step with FLConfig(strategy='full') or "
        "Federation.from_config instead", DeprecationWarning, stacklevel=2)
    if assign is None:
        assign = UnitAssignment(1, None, ("model",))
    fl = dataclasses.replace(fl, strategy="full",
                             n_train_units=assign.n_units,
                             prox_mu=0.0, always_train_head=False)
    return build_round_step(loss_fn, assign, fl, loss_kwargs)
