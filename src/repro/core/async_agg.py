"""FedBuff-style semi-asynchronous buffered aggregation (DESIGN.md §8).

The synchronous round loop stalls on the slowest client — the exact
straggler regime ``StragglerDropout`` simulates by discarding work.
Buffered async aggregation (Nguyen et al. 2022, PAPERS.md) keeps that
work instead: clients train on whatever global snapshot they last
pulled, the server buffers their **packed trained-slot deltas** as they
arrive, and once ``FLConfig.async_buffer`` updates have accumulated the
buffer flushes into the global model as one "round".  Stale deltas are
down-weighted by a registered staleness rule (``@register_staleness``;
the default is FedBuff's polynomial ``1/(1+s)^a``).

Three properties anchor the design:

* **Buffering is cheap** because entries hold only the packed
  ``(n_slots, …)`` slot buffers of the round's trained units (DESIGN.md
  §7) — a buffered update costs ~``n_train/U`` of the model, so holding
  stale work is as cheap as shipping it.
* **A flush is a synchronous round in disguise**: it feeds the stacked
  buffer through the same ``masked_fedavg_packed`` /
  ``hierarchical_masked_fedavg_packed`` scatter-accumulate the sync
  packed round step uses, with entries drained in canonical
  ``(client, seq)`` order — so a flush whose entries all carry zero
  staleness is **bitwise equal** to the synchronous packed round step
  (regression-tested across topologies × strategies, incl. stragglers).
* **Everything is deterministic under a seed**: per-version selection
  keys come off the server's key stream, and the simulated-delay
  scheduler draws each client's latency as a pure function of
  ``(seed, client, seq)`` — clients report back out of order, but the
  same order every run, and checkpoint restore rebuilds the buffer,
  per-client round tags and in-flight work bit-exactly.

The engine computes client updates *eagerly at dispatch* with the same
width-C vmapped trace the sync packed round compiles (rows of a batched
local update are bitwise independent of their cohort, so dispatch
grouping is free to differ); simulated wall-clock comes from the
scheduler, not host time, so the benchmarks compare sync vs. buffered
on the axis the paper cares about — time-to-accuracy under stragglers.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.compileguard import CompileGuard
from .registry import unknown_name_message

PyTree = Any


# ---------------------------------------------------------------------------
# staleness reweighting registry (mirrors strategies/topologies)

_STALENESS: Dict[str, Callable[[np.ndarray, float], np.ndarray]] = {}


class UnknownStalenessError(ValueError):
    pass


def register_staleness(fn: Optional[Callable] = None, *,
                       name: Optional[str] = None):
    """Register ``fn(staleness, alpha) -> weight`` (vectorized over a
    float array of staleness values).  Usable bare or with ``name=``::

        @register_staleness
        def polynomial(s, alpha): ...
    """
    def _register(f):
        _STALENESS[name or f.__name__] = f
        return f
    return _register(fn) if fn is not None else _register


def unregister_staleness(name: str):
    _STALENESS.pop(name, None)


def registered_staleness() -> Tuple[str, ...]:
    return tuple(sorted(_STALENESS))


def get_staleness(name: str) -> Callable[[np.ndarray, float], np.ndarray]:
    try:
        return _STALENESS[name]
    except KeyError:
        raise UnknownStalenessError(unknown_name_message(
            "staleness rule", name, _STALENESS)) from None


@register_staleness
def polynomial(s: np.ndarray, alpha: float) -> np.ndarray:
    """FedBuff's polynomial decay ``1/(1+s)^alpha`` — exactly 1.0 at
    s=0, so a zero-staleness flush leaves client weights untouched."""
    return 1.0 / np.power(1.0 + np.asarray(s, np.float64), alpha)


@register_staleness
def constant(s: np.ndarray, alpha: float) -> np.ndarray:
    """No reweighting (FedAsync's naive baseline): stale deltas count
    at full weight."""
    return np.ones_like(np.asarray(s, np.float64))


def staleness_weights(weights: np.ndarray, staleness: np.ndarray,
                      rule: str, alpha: float) -> np.ndarray:
    """Per-entry effective weights: ``w * rule(s, alpha)`` in float64,
    rounded once to float32 (exact pass-through where the factor is 1)."""
    factor = get_staleness(rule)(np.asarray(staleness, np.float64), alpha)
    return (np.asarray(weights, np.float32) * factor).astype(np.float32)


# ---------------------------------------------------------------------------
# simulated-delay scheduler

_DELAY_DEFAULTS = {"none": 0.0, "fixed": 0.0, "exponential": 1.0,
                   "lognormal": 1.0, "pareto": 1.5}


def parse_delay_dist(spec: str) -> Tuple[str, float]:
    """``"name"`` or ``"name:param"`` -> (name, param).

    ``none``/``fixed`` — unit delay (deterministic completion order);
    ``exponential:scale`` — light tail; ``lognormal:sigma`` — moderate
    tail; ``pareto:alpha`` — heavy tail (the straggler regime; smaller
    alpha = heavier tail, delays ``1 + Pareto(alpha)``).
    """
    name, _, param = str(spec).partition(":")
    if name not in _DELAY_DEFAULTS:
        raise ValueError(
            f"unknown client_delay_dist {spec!r}; one of "
            f"{', '.join(sorted(_DELAY_DEFAULTS))} (optionally ':param')")
    return name, float(param) if param else _DELAY_DEFAULTS[name]


class DelayScheduler:
    """Seeded per-client latency model with **stateless** draws: the
    delay of client ``c``'s ``seq``-th dispatch is a pure function of
    ``(seed, c, seq)`` — no mutable RNG state, so checkpoint restore
    needs only the per-client dispatch counters to reproduce every
    future draw (the per-client key stream of DESIGN.md §8)."""

    def __init__(self, dist: str = "none", seed: int = 0,
                 drop_prob: float = 0.0):
        self.dist, self.param = parse_delay_dist(dist)
        self.seed = int(seed)
        if not 0.0 <= drop_prob < 1.0:
            raise ValueError(
                f"drop_prob must be in [0, 1), got {drop_prob}")
        self.drop_prob = float(drop_prob)

    def dropped(self, client: int, seq: int) -> bool:
        """Permanent in-transit loss of client ``c``'s ``seq``-th update
        (not just delay).  Drawn in its own tag domain so enabling drops
        never shifts the delay draws — a drop_prob=0 run replays the
        plain scheduler bit-exactly without drawing at all."""
        if self.drop_prob <= 0.0:
            return False
        rng = np.random.default_rng(np.random.SeedSequence(
            (self.seed, 0xD70B, int(client), int(seq))))
        return float(rng.random()) < self.drop_prob

    def delay(self, client: int, seq: int) -> float:
        if self.dist in ("none", "fixed"):
            return 1.0
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, int(client), int(seq))))
        if self.dist == "exponential":
            return 0.05 + float(rng.exponential(self.param))
        if self.dist == "lognormal":
            return float(rng.lognormal(0.0, self.param))
        # pareto: heavy-tailed with minimum 1 (a round never takes less
        # than one unit of work)
        return 1.0 + float(rng.pareto(self.param))


# ---------------------------------------------------------------------------
# buffered updates + the aggregator

@dataclasses.dataclass
class BufferedUpdate:
    """One client's completed dispatch: the packed trained-slot delta
    tagged with its origin version (the global model it trained from)."""
    client: int
    seq: int                 # the client's dispatch counter (batch window)
    version: int             # global model version at dispatch time
    t_done: float            # simulated completion time
    weight: float            # data weight at dispatch (0 = dropped)
    loss: float
    sel_row: np.ndarray      # (U,) trained-unit selection
    pdelta: PyTree           # packed (L, ...) slot deltas / dense scalars
    rows: PyTree             # (L,) slot -> macro-row indices
    valid: PyTree            # (L,) slot masks / scalar participation
    # per-unit squared gradient norms of this update's local training
    # (scored selection, DESIGN.md §11); None when scoring is off
    unit_sqnorm: Optional[np.ndarray] = None


def _stack_entries(entries: Sequence[BufferedUpdate]):
    """Stack per-entry pytrees into leading-B arrays (jnp, on device)."""
    stack = lambda trees: jax.tree_util.tree_map(
        lambda *xs: jnp.asarray(np.stack([np.asarray(x) for x in xs])),
        *trees)
    return (stack([e.pdelta for e in entries]),
            stack([e.rows for e in entries]),
            stack([e.valid for e in entries]),
            jnp.asarray(np.stack([e.sel_row for e in entries])))


class BufferedAggregator:
    """The FedBuff combiner role: hold packed updates, flush when full.

    ``flush_fn(global, pdeltas, rows, valid, sel, weights, client_ids)``
    is the topology's buffered aggregation (``build_buffered_flush``) —
    the same scatter-accumulate as the sync packed round.  Entries are
    drained in canonical ``(client, seq)`` order so the flush is
    independent of arrival order (and bit-equal to a synchronous round
    when every entry has zero staleness).
    """

    def __init__(self, buffer_size: int, staleness: str, alpha: float,
                 flush_fn: Callable, gated: bool = False):
        if buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {buffer_size}")
        get_staleness(staleness)          # fail fast on unknown rules
        self.buffer_size = buffer_size
        self.staleness = staleness
        self.alpha = alpha
        # gated flush_fns (the validation gate wrapped around the
        # topology's buffered aggregation, session.py) return
        # (new_params, quarantined) instead of bare params
        self.gated = gated
        # the flush donates global_params: run_flush reassigns
        # server.params from the flush output, so the pre-flush state
        # is dead at the call and aliases into the new params in place
        self._flush = CompileGuard(flush_fn, name="async_flush",
                                   max_programs=1, donate_argnums=(0,))
        self.entries: List[BufferedUpdate] = []
        # duplicate-delivery defense: per-client seq watermark.  Each
        # client has at most one dispatch in flight, so its seqs arrive
        # strictly increasing — any (client, seq) at or below the
        # watermark is a redelivery and is rejected
        self._last_seq: Dict[int, int] = {}

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def ready(self) -> bool:
        return len(self.entries) >= self.buffer_size

    def push(self, update: BufferedUpdate) -> bool:
        """Accept ``update`` into the buffer; False = duplicate
        delivery (seq at/below the client's watermark), discarded."""
        last = self._last_seq.get(update.client)
        if last is not None and update.seq <= last:
            return False
        self._last_seq[update.client] = update.seq
        self.entries.append(update)
        return True

    def flush(self, global_params: PyTree, version: int
              ) -> Tuple[PyTree, Dict[str, Any]]:
        """Apply the buffered updates to ``global_params`` and clear."""
        entries = sorted(self.entries, key=lambda e: (e.client, e.seq))
        self.entries = []
        s = np.asarray([version - e.version for e in entries], np.float64)
        w = np.asarray([e.weight for e in entries], np.float32)
        factor = get_staleness(self.staleness)(s, self.alpha)
        eff = (w * factor).astype(np.float32)
        pdeltas, rows, valid, sel = _stack_entries(entries)
        clients = np.asarray([e.client for e in entries], np.int32)
        out = self._flush(global_params, pdeltas, rows, valid, sel,
                          jnp.asarray(eff), jnp.asarray(clients))
        if self.gated:
            new_params, quarantined = out
        else:
            new_params, quarantined = out, None
        stats = {
            "entry_sel": np.asarray(sel),
            "entry_clients": clients,
            "staleness": s,
            "staleness_factor": factor,
            "effective_weights": eff,
            "losses": np.asarray([e.loss for e in entries], np.float32),
        }
        if quarantined is not None:
            stats["quarantined"] = np.asarray(quarantined)
        if all(e.unit_sqnorm is not None for e in entries):
            stats["entry_sqnorm"] = np.stack(
                [np.asarray(e.unit_sqnorm, np.float32) for e in entries])
        return new_params, stats


# ---------------------------------------------------------------------------
# compiled pieces

def build_cohort_step(loss_fn: Callable, assign, fl,
                      loss_kwargs: Optional[Dict] = None, *,
                      strategy=None, scores=None):
    """The async engine's two compiled programs.

    Returns ``(select_fn, cohort_fn, n_slots)``:

    * ``select_fn(key[, sel_state]) -> sel (C, U)`` — the version's
      per-client trained-unit selection (one key per version off the
      server stream; strategies fold per-client keys internally).  For
      stateful (scored) strategies the engine threads the server's live
      :class:`SelectionState` in as the second argument;
    * ``cohort_fn(global_params, sel, client_batches) -> (pdeltas,
      rows, valid, metrics)`` — the sync packed round step's selection +
      vmapped packed local training, **without** the aggregation stage
      (that happens at flush time, from the buffer).  ``metrics``
      carries per-client ``loss_mean`` and, for stateful strategies,
      the ``unit_sqnorm`` gradient-norm telemetry (DESIGN.md §11) —
      the same hook, and bitwise the same values, as the sync round.

    The vmapped trace is ``client.packed_cohort_fn`` — the identical
    trace ``_star_round_step``'s packed branch and the chunked cohort
    engine run (optionally shard_map'd over the ``(client,)`` mesh via
    ``fl.client_shards``) — so a row here is bitwise the row the
    synchronous round would have computed.
    """
    from .client import packed_cohort_fn
    from .masking import slot_plan
    from .topology import _cohort_runner, _live_ctx, _selection_setup
    strat, ctx = _selection_setup(assign, fl, strategy, scores)
    if strat.dense:
        raise ValueError(
            "async buffered rounds carry packed trained-slot deltas; the "
            "dense 'full' strategy has nothing to pack — use a partial "
            "strategy (train_fraction < 1)")
    n_slots = fl.resolve_n_slots(ctx.n_units)
    scoring = strat.stateful
    run_cohort = _cohort_runner(fl, fl.n_clients)
    cohort_stage = packed_cohort_fn(loss_fn, assign, fl, loss_kwargs,
                                    scoring=scoring)

    def select(key, sel_state=None):
        sel = strat.select(key, _live_ctx(ctx, sel_state))
        if fl.always_train_head:
            sel = sel.at[:, -1].set(1.0)
        return sel

    # codec axis (core/codecs.py): encode/decode at dispatch time — the
    # buffer holds DECODED deltas (billing uses encoded wire bytes).
    # codec "none" keeps the original three-argument trace bitwise.
    from . import codecs as _codecs
    codec = _codecs.resolve_codec(fl.codec)
    codec_fn = _codecs.build_codec_transform(codec, assign, fl)

    if codec_fn is None:
        def cohort(global_params, sel, client_batches):
            rows, valid = jax.vmap(
                lambda s: slot_plan(assign, s, n_slots, global_params))(sel)
            pdeltas, metrics = run_cohort(cohort_stage, global_params, rows,
                                          valid, client_batches)
            out = {"loss_mean": metrics["loss_mean"]}
            if scoring:
                out["unit_sqnorm"] = metrics["unit_sqnorm"]
            return pdeltas, rows, valid, out
    else:
        def cohort(global_params, sel, client_batches, codec_key,
                   codec_state=None, codec_decay=None):
            rows, valid = jax.vmap(
                lambda s: slot_plan(assign, s, n_slots, global_params))(sel)
            pdeltas, metrics = run_cohort(cohort_stage, global_params, rows,
                                          valid, client_batches)
            # residual gating for dispatched-vs-not happens host-side
            # (the engine merges only dispatched clients' rows back),
            # so every in-trace row counts as an upload here
            ones = jnp.ones((fl.n_clients,), jnp.float32)
            pdeltas, new_state = codec_fn(pdeltas, rows, valid, ones,
                                          codec_key, codec_state,
                                          codec_decay)
            out = {"loss_mean": metrics["loss_mean"]}
            if scoring:
                out["unit_sqnorm"] = metrics["unit_sqnorm"]
            if new_state is not None:
                out["codec_state"] = new_state
            return pdeltas, rows, valid, out

    return (CompileGuard(select, name="async_select", max_programs=1),
            CompileGuard(cohort, name="async_cohort", max_programs=1),
            n_slots)


def slot_template(assign, params, n_slots: int) -> Dict[str, Any]:
    """ShapeDtypeStructs of one packed update's ``pdelta``/``rows``/
    ``valid`` pytrees — the single source of buffered-entry shapes for
    dry-run flush compiles and checkpoint restore templates."""
    from .masking import slot_plan, slot_gather

    def one(p):
        rows, valid = slot_plan(
            assign, jnp.zeros((assign.n_units,), jnp.float32), n_slots, p)
        return {"pdelta": slot_gather(assign, p, rows),
                "rows": rows, "valid": valid}
    return jax.eval_shape(one, params)


def flush_arg_specs(assign, params, fl) -> Tuple[Any, ...]:
    """ShapeDtypeStructs of one flush call's buffer arguments — what a
    dry-run compile of the buffered flush program feeds ``jit``."""
    tpl = slot_template(assign, params, fl.resolve_n_slots(assign.n_units))
    b = fl.async_buffer
    lead = lambda tree: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((b,) + x.shape, x.dtype), tree)
    return (lead(tpl["pdelta"]), lead(tpl["rows"]), lead(tpl["valid"]),
            jax.ShapeDtypeStruct((b, assign.n_units), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32))


# ---------------------------------------------------------------------------
# the engine

class AsyncRoundEngine:
    """Drives FedBuff-style semi-async rounds for a :class:`Server`.

    One engine "round" = one buffer flush.  Between flushes the
    simulated-delay scheduler pops client completions in time order;
    each completion pushes its packed update into the buffer and
    immediately re-dispatches the client against the *current* global
    model (so in-flight work goes stale exactly when flushes land
    mid-flight).  Per-version selection keys come off the server key
    stream — a zero-staleness flush consumes the same key the sync
    round loop would have.
    """

    def __init__(self, server, assign, fl, *, select_fn, cohort_fn,
                 flush_fn, seed: int = 0, gated: bool = False):
        self.server = server
        self.assign = assign
        self.fl = fl
        self.select_fn = select_fn
        self.cohort_fn = cohort_fn
        self.n_slots = fl.resolve_n_slots(assign.n_units)
        self.buffer = BufferedAggregator(fl.async_buffer, fl.staleness,
                                         fl.staleness_alpha, flush_fn,
                                         gated=gated)
        self.scheduler = DelayScheduler(fl.client_delay_dist, seed=seed,
                                        drop_prob=fl.client_drop_prob)
        # codec axis: stochastic-rounding keys come off a dedicated
        # fold_in stream indexed by a dispatch counter (checkpointed, so
        # restores replay the identical key sequence); a stateful
        # codec's canonical EF residual lives on the Server — the engine
        # merges back only the rows of clients it actually dispatched,
        # and tracks per-client residual age for staleness decay
        from . import codecs as _codecs
        self.codec = _codecs.resolve_codec(fl.codec)
        self._codec_base = jax.random.fold_in(
            jax.random.PRNGKey(seed), _codecs.CODEC_KEY_TAG)
        self._codec_dispatch = 0
        self._codec_version = (np.zeros(fl.n_clients, np.int64)
                               if self.codec.stateful else None)
        # bytes clients uploaded since the last flush that never landed
        # in the buffer (in-transit loss, crashes, rejected duplicates)
        self._wasted = 0.0
        self.started = False
        self.version = 0
        self.clock = 0.0
        self.seq = np.zeros(fl.n_clients, np.int64)
        self.pending: List[Tuple[float, int, int]] = []   # (t, client, seq)
        self.inflight: Dict[Tuple[int, int], BufferedUpdate] = {}
        self.flush_clients: List[np.ndarray] = []
        self._sel: Optional[np.ndarray] = None

    # -- dispatch ---------------------------------------------------------

    def _begin_version(self):
        key = self.server.next_key()
        st = self.server.sel_state
        # scored strategies select against the live state — stale
        # in-flight work keeps the selection its dispatch version saw
        sel = self.select_fn(key) if st is None else self.select_fn(key, st)
        self._sel = np.asarray(sel, np.float32)

    def _dispatch(self, clients: Sequence[int], weights: np.ndarray,
                  batch_fn: Callable[[int], Any]):
        """Start local training for ``clients`` at the current version.

        Runs ONE width-C vmapped cohort step (each client on its own
        batch window) and keeps the dispatched clients' rows — rows of
        a batched local update are bitwise independent of the rest of
        the cohort, so only the kept rows matter; the full width keeps
        the trace identical to the synchronous round's.
        """
        batches = _mixed_window_batches(batch_fn, list(self.seq))
        gp = self.server.global_params()
        sel = jnp.asarray(self._sel)
        if self.codec.name == "none":
            pdeltas, rows, valid, mets = self.cohort_fn(gp, sel, batches)
        else:
            ck = jax.random.fold_in(self._codec_base, self._codec_dispatch)
            self._codec_dispatch += 1
            if self.codec.stateful:
                decay = jnp.asarray(self._codec_decay(), jnp.float32)
                pdeltas, rows, valid, mets = self.cohort_fn(
                    gp, sel, batches, ck, self.server.codec_state, decay)
                new_state = mets.pop("codec_state")
                # only dispatched clients transmitted: merge their
                # residual rows back, discard the rest of the width-C
                # computation (those clients sent nothing)
                idx = jnp.asarray([int(c) for c in clients], jnp.int32)
                self.server.codec_state = jax.tree_util.tree_map(
                    lambda old, new: old.at[idx].set(new[idx]),
                    self.server.codec_state, new_state)
                self._codec_version[np.asarray(idx)] = self.version
            else:
                pdeltas, rows, valid, mets = self.cohort_fn(
                    gp, sel, batches, ck)
        losses = mets["loss_mean"]
        sqnorm = mets.get("unit_sqnorm")
        take = lambda tree, c: jax.tree_util.tree_map(
            lambda x: np.asarray(x[c]), tree)
        for c in clients:
            c = int(c)
            seq = int(self.seq[c])
            t_done = self.clock + self.scheduler.delay(c, seq)
            upd = BufferedUpdate(
                client=c, seq=seq, version=self.version, t_done=t_done,
                weight=float(weights[c]), loss=float(losses[c]),
                sel_row=self._sel[c].copy(),
                pdelta=take(pdeltas, c), rows=take(rows, c),
                valid=take(valid, c),
                unit_sqnorm=np.asarray(sqnorm[c], np.float32)
                if sqnorm is not None else None)
            heapq.heappush(self.pending, (t_done, c, seq))
            self.inflight[(c, seq)] = upd
            self.seq[c] += 1

    # -- the flush loop ---------------------------------------------------

    def run_flush(self, batch_fn: Callable[[int], Any],
                  weights) -> "RoundRecord":
        from .server import RoundRecord
        server = self.server
        t0 = time.perf_counter()
        r = len(server.history)
        w = jnp.asarray(weights, jnp.float32)
        for hook in server.hooks:
            new_w = hook.on_round_start(server, r, w)
            if new_w is not None:
                w = new_w
        w_np = np.asarray(w, np.float32)
        if not self.started:
            self.started = True
            self._begin_version()
            self._dispatch(range(self.fl.n_clients), w_np, batch_fn)
        trigger = None
        inj = server.fault_injector
        while not self.buffer.ready:
            t_done, c, seq = heapq.heappop(self.pending)
            self.clock = max(self.clock, t_done)
            upd = self.inflight.pop((c, seq))
            if self.scheduler.dropped(c, seq) or \
                    (inj is not None and inj.crashed_async(c, seq)):
                # the update never arrives (in-transit loss / client
                # crash): the client's upload is wasted work and the
                # engine re-dispatches it against the current version
                self._wasted += self._entry_bytes(upd)
                self._dispatch([c], w_np, batch_fn)
                continue
            if inj is not None:
                upd = inj.perturb_update(upd)     # torn/corrupt delivery
                accepted = self.buffer.push(upd)
                if accepted and inj.duplicated(c, seq) \
                        and not self.buffer.push(upd):
                    # duplicate delivery: the redelivered bytes crossed
                    # the WAN, the watermark defense rejected them
                    self._wasted += self._entry_bytes(upd)
            else:
                self.buffer.push(upd)
            if self.buffer.ready:
                trigger = c           # re-dispatched at the NEW version
            else:
                self._dispatch([c], w_np, batch_fn)
        new_params, stats = self.buffer.flush(server.global_params(),
                                              self.version)
        server.params = new_params    # star topologies: state == params
        self.version += 1
        # stale telemetry decays with the SAME staleness factor the
        # aggregation applied to its delta; the state must advance
        # before the next version's selection is drawn
        server.update_sel_state(self._flush_telemetry(r, stats))
        self._begin_version()
        if trigger is not None:
            self._dispatch([trigger], w_np, batch_fn)

        ev = None
        if server.eval_fn is not None:
            ev = float(server.eval_fn(server.global_params()))
        s = stats["staleness"]
        eff = stats["effective_weights"]
        rec = RoundRecord(
            r, float(stats["losses"].mean()), ev,
            time.perf_counter() - t0, 0.0, 0.0,
            # like the sync loop: dropped (weight-0) entries aggregate
            # nothing and don't count as participants
            n_participants=int(np.unique(
                stats["entry_clients"][eff > 0]).size),
            effective_weights=[float(x) for x in eff],
            staleness_mean=float(s.mean()), staleness_max=float(s.max()),
            sim_time=float(self.clock))
        server.sel_history.append(stats["entry_sel"])
        self.flush_clients.append(stats["entry_clients"])
        metrics = {"entry_sel": stats["entry_sel"],
                   "entry_clients": stats["entry_clients"],
                   "staleness": s, "loss_per_entry": stats["losses"],
                   "dropped_bytes": self._wasted}
        if "quarantined" in stats:
            metrics["quarantined"] = stats["quarantined"]
        self._wasted = 0.0     # billed to this flush's record
        for hook in server.hooks:
            hook.on_round_end(server, rec, metrics)
        rec.seconds = time.perf_counter() - t0
        server.history.append(rec)
        return rec

    def _codec_decay(self) -> np.ndarray:
        """(C,) residual staleness factors: a client's EF residual ages
        by the model versions since it last transmitted, decayed by the
        run's registered staleness rule (the same rule the aggregation
        applies to stale deltas; 1.0 at age 0, matching the sync path)."""
        rule = get_staleness(self.fl.staleness)
        age = np.maximum(self.version - self._codec_version, 0)
        return rule(age.astype(np.float64),
                    self.fl.staleness_alpha).astype(np.float32)

    def _entry_bytes(self, upd: BufferedUpdate) -> float:
        """Upload cost of one packed update (the client's trained-unit
        bytes at *encoded* wire width — hub math; good enough for the
        wasted-bytes column).  Billing fp32 width here under a codec
        was the PR 8 accounting bug this replaces."""
        return float((np.asarray(upd.sel_row, np.float32)
                      * self.server.wire_unit_bytes()).sum())

    def _flush_telemetry(self, flush_idx: int, stats: Dict[str, Any]):
        """One flush's staleness-weighted NormTelemetry, or None.

        Each buffered entry's per-unit squared norms and unit counts
        are weighted by its staleness factor (dropped entries — data
        weight 0 — excluded); the unweighted counts ride along so
        ``ScoredStrategy.update_state`` can scale its EMA step by the
        weighted/raw ratio — a stale update moves the score EMA by the
        same factor the aggregation applied to its delta.
        """
        if self.server.sel_state is None or "entry_sqnorm" not in stats \
                or flush_idx % self.fl.score_every != 0:
            return None
        from .strategies import NormTelemetry
        active = (stats["effective_weights"] > 0)
        if "quarantined" in stats:
            # a quarantined entry's delta was discarded by the gate;
            # its telemetry must not steer selection scores either
            active = active & (stats["quarantined"] <= 0)
        f = np.where(active, stats["staleness_factor"],
                     0.0).astype(np.float32)
        raw = active.astype(np.float32)
        return NormTelemetry(
            unit_sqnorm=(stats["entry_sqnorm"] * f[:, None]).sum(0),
            unit_count=(stats["entry_sel"] * f[:, None]).sum(0),
            unit_raw_count=(stats["entry_sel"] * raw[:, None]).sum(0))

    def run(self, flushes: int, batch_fn: Callable[[int], Any],
            weights=None, log_every: int = 0):
        from .server import RoundLogger
        server = self.server
        if weights is None:
            weights = jnp.ones((self.fl.n_clients,), jnp.float32)
        extra = [RoundLogger(log_every,
                             total=len(server.history) + flushes,
                             base=len(server.history))] if log_every else []
        server.hooks.extend(extra)
        try:
            for _ in range(flushes):
                self.run_flush(batch_fn, weights)
        finally:
            for h in extra:
                server.hooks.remove(h)
        for hook in server.hooks:
            hook.on_fit_end(server, server.history)
        return server.history

    # -- run-level accounting --------------------------------------------

    def comm_summary(self) -> Dict[str, float]:
        from . import comm
        server = self.server
        if not server.sel_history:
            return {"avg_uplink_bytes": 0.0, "avg_trained_params": 0.0,
                    "total_uplink_bytes": 0.0, "reduction_vs_full": 0.0,
                    "total_wasted_bytes": 0.0, "avg_wasted_bytes": 0.0}
        ub = server.unit_bytes()
        # flushed uplink bills at encoded wire width; the reduction
        # denominator (a full fp32 entry per buffered slot) stays fp32
        # so the reported reduction composes freeze × codec
        wub = server.wire_unit_bytes()
        counts = comm.unit_param_counts(self.assign, server.global_params())
        ups, fulls, tps = [], [], []
        for entry_sel, clients, rec in zip(server.sel_history,
                                           self.flush_clients,
                                           server.history):
            es = np.asarray(entry_sel)
            eff = np.asarray(rec.effective_weights, np.float32)
            es = es * (eff > 0).astype(es.dtype)[:, None]
            ups.append(server.topology.buffered_round_bytes(
                es, clients, wub, self.fl)["uplink"])
            fulls.append(server.topology.buffered_round_bytes(
                np.ones_like(es), clients, ub, self.fl)["uplink"])
            tps.append(float(np.einsum("bu,u->", es, counts)))
        total_full = float(np.sum(fulls))
        return {
            "avg_uplink_bytes": float(np.mean(ups)),
            "avg_trained_params": float(np.mean(tps)),
            "total_uplink_bytes": float(np.sum(ups)),
            "reduction_vs_full": 1.0 - float(np.sum(ups)) / total_full
            if total_full else 0.0,
            "avg_staleness": float(np.mean(
                [r.staleness_mean for r in server.history])),
            "sim_time": float(self.clock),
            "total_wasted_bytes": float(np.sum(
                [r.wasted_bytes for r in server.history])),
            "avg_wasted_bytes": float(np.mean(
                [r.wasted_bytes for r in server.history])),
        }

    # -- checkpoint state (ckpt/store.py) ---------------------------------

    def _entry_template(self, scored: bool):
        tpl = slot_template(self.assign, self.server.global_params(),
                            self.n_slots)
        tpl["sel_row"] = jax.ShapeDtypeStruct((self.assign.n_units,),
                                              jnp.float32)
        if scored:
            tpl["unit_sqnorm"] = jax.ShapeDtypeStruct(
                (self.assign.n_units,), jnp.float32)
        return tpl

    @staticmethod
    def _update_meta(u: BufferedUpdate) -> Dict[str, Any]:
        return {"client": int(u.client), "seq": int(u.seq),
                "version": int(u.version), "t_done": float(u.t_done),
                "weight": float(u.weight), "loss": float(u.loss)}

    @staticmethod
    def _update_arrays(u: BufferedUpdate) -> Dict[str, Any]:
        out = {"pdelta": u.pdelta, "rows": u.rows, "valid": u.valid,
               "sel_row": u.sel_row}
        if u.unit_sqnorm is not None:
            out["unit_sqnorm"] = u.unit_sqnorm
        return out

    def checkpoint_state(self) -> Tuple[Dict[str, Any], PyTree]:
        """(json metadata, array pytree) capturing buffer contents,
        per-client round tags and in-flight (delay-scheduled) work."""
        inflight = [self.inflight[k] for k in sorted(self.inflight)]
        meta = {
            "version": int(self.version),
            "clock": float(self.clock),
            "seq": [int(x) for x in self.seq],
            "scored": self.server.sel_state is not None,
            "buffer": [self._update_meta(u) for u in self.buffer.entries],
            "inflight": [self._update_meta(u) for u in inflight],
            "flush_clients": [np.asarray(c).tolist()
                              for c in self.flush_clients],
            # fault-axis state: the dedup watermark and wasted bytes
            # accumulated since the last flush (both empty/zero in
            # fault-free runs, so old checkpoints restore cleanly)
            "last_seq": {str(c): int(s)
                         for c, s in self.buffer._last_seq.items()},
            "wasted_pending": float(self._wasted),
        }
        if self.codec.name != "none":
            # codec-axis replay state: the stochastic-rounding key
            # counter, plus (stateful codecs) each client's residual age
            meta["codec_dispatch"] = int(self._codec_dispatch)
            if self.codec.stateful:
                meta["codec_version"] = [int(x)
                                         for x in self._codec_version]
        arrays = {
            "sel": self._sel,
            "buffer": [self._update_arrays(u) for u in self.buffer.entries],
            "inflight": [self._update_arrays(u) for u in inflight],
        }
        return meta, arrays

    def arrays_template(self, meta: Dict[str, Any]) -> PyTree:
        tpl = self._entry_template(bool(meta.get("scored")))
        return {
            "sel": jax.ShapeDtypeStruct(
                (self.fl.n_clients, self.assign.n_units), jnp.float32),
            "buffer": [tpl for _ in meta["buffer"]],
            "inflight": [tpl for _ in meta["inflight"]],
        }

    def restore_state(self, meta: Dict[str, Any], arrays: PyTree):
        def updates(metas, arrs):
            out = []
            for m, a in zip(metas, arrs):
                out.append(BufferedUpdate(
                    client=int(m["client"]), seq=int(m["seq"]),
                    version=int(m["version"]), t_done=float(m["t_done"]),
                    weight=float(m["weight"]), loss=float(m["loss"]),
                    sel_row=np.asarray(a["sel_row"], np.float32),
                    pdelta=jax.tree_util.tree_map(np.asarray, a["pdelta"]),
                    rows=jax.tree_util.tree_map(np.asarray, a["rows"]),
                    valid=jax.tree_util.tree_map(np.asarray, a["valid"]),
                    unit_sqnorm=np.asarray(a["unit_sqnorm"], np.float32)
                    if "unit_sqnorm" in a else None))
            return out

        if len(meta["buffer"]) >= self.buffer.buffer_size:
            raise ValueError(
                f"checkpoint buffer holds {len(meta['buffer'])} entries, "
                f">= this run's async_buffer={self.buffer.buffer_size}; "
                "restore with the original buffer size")
        if self.codec.name != "none" and "codec_dispatch" not in meta:
            raise ValueError(
                f"this run uses codec {self.codec.name!r} but the "
                "checkpoint carries no codec replay state; restore with "
                "the codec the checkpoint was written under")
        if self.codec.name == "none" and "codec_dispatch" in meta:
            raise ValueError(
                "checkpoint carries codec replay state but this run has "
                "codec 'none'; restore with the original codec config")
        if self.codec.stateful and "codec_version" not in meta:
            raise ValueError(
                f"stateful codec {self.codec.name!r} needs the "
                "checkpoint's per-client residual ages (codec_version); "
                "this checkpoint has none")
        self._codec_dispatch = int(meta.get("codec_dispatch", 0))
        if self.codec.stateful:
            self._codec_version = np.asarray(meta["codec_version"],
                                             np.int64)
        self.version = int(meta["version"])
        self.clock = float(meta["clock"])
        self.seq = np.asarray(meta["seq"], np.int64)
        self.buffer._last_seq = {int(c): int(s) for c, s in
                                 meta.get("last_seq", {}).items()}
        self._wasted = float(meta.get("wasted_pending", 0.0))
        self._sel = np.asarray(arrays["sel"], np.float32)
        self.buffer.entries = updates(meta["buffer"], arrays["buffer"])
        self.inflight = {(u.client, u.seq): u
                         for u in updates(meta["inflight"],
                                          arrays["inflight"])}
        self.pending = [(u.t_done, u.client, u.seq)
                        for u in self.inflight.values()]
        heapq.heapify(self.pending)
        self.flush_clients = [np.asarray(c, np.int32)
                              for c in meta["flush_clients"]]
        self.started = True


def _mixed_window_batches(batch_fn: Callable[[int], Any],
                          windows: Sequence[int]) -> PyTree:
    """Assemble a (C, steps, ...) cohort batch where client ``c`` rides
    its OWN batch window ``windows[c]`` (clients progress through their
    local streams at their own pace in async rounds).

    ``batch_fn(w)`` returns the full-cohort batches of window ``w``
    (the sync loop's per-round loader contract).
    """
    windows = [int(w) for w in windows]
    per = {w: batch_fn(w) for w in sorted(set(windows))}
    rows = [jax.tree_util.tree_map(lambda x, c=c, w=w: x[c], per[w])
            for c, w in enumerate(windows)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rows)
