"""Federation topologies as registered plugins (DESIGN.md §6).

PR 1 made *what each client trains* pluggable (core/strategies.py);
this module makes *how updates flow between nodes* pluggable the same
way.  A **topology** owns three cross-layer responsibilities:

1. the compiled round step — where its aggregation stage lives
   (``build_round_step``; hub and hierarchical share the star skeleton
   and differ only in the aggregation callback, gossip carries
   per-client replicas instead of one global model);
2. its exact byte accounting (``round_bytes``/``summary`` route
   ``CommAccounting`` and ``Server.comm_summary`` through the plugin
   instead of hard-coded hub math — core/comm.py has the formulas);
3. its mesh view (``make_mesh``: launch/mesh.py grows an edge-group
   axis carve-out for hierarchical).

Registered plugins:

* ``hub`` — the paper's FEDn combiner star (the default).  Its round
  step is the exact trace PR 1 compiled, so results are bit-exact with
  the pre-topology path (regression-tested).
* ``hierarchical`` — clients partitioned under ``FLConfig.n_edges``
  edge aggregators; two-stage masked FedAvg (per-edge partial
  aggregates, then hub combine) inside the single compiled round step.
  Only the per-edge selection *union* crosses the edge->hub WAN link,
  compounding the paper's partial-update savings.
* ``gossip`` — hubless peer averaging over a doubly-stochastic ring
  mixing matrix; per-client parameter replicas are the server state and
  are carried across rounds (``stateful = True``).

Adding a topology is a subclass + ``@register_topology`` — no change to
``federation.py``, ``Server``, launchers or benchmarks.

The star topologies additionally own the **sparse round step**
(DESIGN.md §7): ``FLConfig.packed`` swaps the masked local update and
aggregation for their packed slot-buffer variants (bit-exact,
regression-tested), and ``FLConfig.fused_agg`` routes the aggregation
stage through the fused Pallas kernel (``kernels/masked_agg``) with
the tiling plan hoisted to build time.

They also own the **buffered-async flush** (DESIGN.md §8, the third
plugin axis in ``core/async_agg.py``): ``build_buffered_flush`` is the
topology's aggregation stage over a stacked buffer of packed updates,
and ``buffered_round_bytes`` its per-flush byte math (hierarchical:
only flushed per-edge partials cross the WAN).  Gossip has no global
model to buffer against and rejects ``FLConfig.async_buffer``.
"""
from __future__ import annotations

from typing import (Any, Callable, ClassVar, Dict, Optional, Tuple, Type,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses

from . import comm
from .aggregation import (fedavg, gate_packed_updates,
                          hierarchical_edge_partials,
                          hierarchical_masked_fedavg,
                          hierarchical_masked_fedavg_packed, masked_fedavg,
                          masked_fedavg_packed, packed_acc_init,
                          packed_accumulate, packed_finalize)
from .client import local_update, packed_cohort_fn
from .masking import (UnitAssignment, dense_norm_hook, mask_tree,
                      slot_plan)
from .registry import unknown_name_message
from .strategies import SelectionContext, resolve_strategy

PyTree = Any


def ring_mixing_matrix(n: int) -> np.ndarray:
    """Doubly-stochastic Metropolis weights on a ring of ``n`` peers.

    n=1 -> identity; n=2 -> exact pair averaging; n>=3 -> 1/3 self +
    1/3 to each ring neighbour.  Rows AND columns sum to one, so the
    uniform average of the replicas is invariant under mixing.
    """
    if n < 1:
        raise ValueError("ring needs at least one peer")
    if n == 1:
        return np.ones((1, 1), np.float32)
    if n == 2:
        return np.full((2, 2), 0.5, np.float32)
    w = np.eye(n, dtype=np.float32) / 3.0
    w += np.roll(np.eye(n, dtype=np.float32), 1, axis=1) / 3.0
    w += np.roll(np.eye(n, dtype=np.float32), -1, axis=1) / 3.0
    return w


def _selection_setup(assign: UnitAssignment, fl, strategy, scores):
    """Shared preamble of every topology's round step: resolve the
    strategy, validate n_train, build the static selection context."""
    strat = resolve_strategy(strategy if strategy is not None
                             else fl.strategy, fl.synchronized)
    n_train = fl.resolve_n_train(assign.n_units)
    if not strat.dense and not 1 <= n_train <= assign.n_units:
        raise ValueError(
            f"n_train={n_train} out of range for {assign.n_units} units; "
            "set FLConfig.n_train_units or train_fraction")
    ctx = SelectionContext(n_clients=fl.n_clients, n_units=assign.n_units,
                           n_train=n_train, scores=scores,
                           score_ema=getattr(fl, "score_ema", 0.9))
    return strat, ctx


def _live_ctx(ctx: SelectionContext, sel_state) -> SelectionContext:
    """Swap the build-time context for the round's live selection state
    (traced arrays) when the server threads one in."""
    if sel_state is None:
        return ctx
    return dataclasses.replace(ctx, scores=sel_state.scores,
                               state=sel_state)


def _cohort_runner(fl, width: int) -> Callable:
    """How a round step runs its vmapped cohort stage: directly on one
    device, or split over the ``(client,)`` mesh when
    ``fl.client_shards`` is set (DESIGN.md §13).  ``run(fn, gp,
    *per_client)`` — ``gp`` replicated, everything else carrying a
    leading ``width`` client axis.  Rows of a batched local update are
    bitwise independent of their cohort, so both paths agree exactly.
    """
    shards = getattr(fl, "client_shards", 0)
    if not shards:
        return lambda fn, gp, *per_client: fn(gp, *per_client)
    from ..launch.mesh import shard_over_clients

    def run(fn, gp, *per_client):
        return shard_over_clients(fn, shards, width)(gp, *per_client)

    return run


def _star_round_step(loss_fn: Callable, assign: UnitAssignment, fl,
                     loss_kwargs: Optional[Dict], *, strategy, scores,
                     aggregate: Callable, aggregate_dense: Callable,
                     aggregate_packed: Optional[Callable] = None):
    """The star-topology skeleton: selection -> vmapped masked local
    training -> a topology-supplied aggregation stage.

    ``aggregate(global_params, deltas, sel, weights)`` is the masked
    path; ``aggregate_dense`` the dense (full-strategy) path.  The hub
    plugin passes ``masked_fedavg``/``fedavg`` so its trace is exactly
    the pre-topology round step (bit-exactness is regression-tested).

    With ``fl.packed`` (DESIGN.md §7) local training and aggregation
    run on packed slot buffers instead: ``aggregate_packed(g,
    packed_deltas, rows, valid, sel, weights)`` reduces only the
    ``n_slots`` trained units per client.  The slot budget ``n_slots``
    is static (``n_train`` plus the optional always-trained head), so
    all packed shapes are static under vmap/scan.

    Stateful (scored) strategies get two extra wires (DESIGN.md §11),
    both compiled out entirely for stateless strategies (their trace is
    the pre-scoring trace, bit-exact): the optional ``sel_state``
    argument threads the live :class:`SelectionState` into the
    selection context, and the metrics carry ``unit_sqnorm`` — (C, U)
    per-client per-unit squared gradient norms accumulated by the
    local-update norm hook from gradients the step already
    materialized.
    """
    strat, ctx = _selection_setup(assign, fl, strategy, scores)
    use_packed = fl.packed and not strat.dense
    if use_packed and aggregate_packed is None:
        raise ValueError(
            f"topology {fl.topology!r} has no packed aggregation path; "
            "set FLConfig.packed=False")
    # the fault axis (core/faults.py): delta corruption + the validation
    # gate are compiled into the packed branch only — both are bitwise
    # identities when untripped, so a zero-rate chaos config keeps the
    # plain trace's numbers exactly
    from . import faults as _faults
    inject_on = _faults.delta_faults_configured(fl)
    gate_on = _faults.gate_enabled(fl)
    if (inject_on or gate_on) and not use_packed:
        raise ValueError(
            "delta faults / the validation gate run inside the packed "
            "scatter-accumulate; set FLConfig.packed=True (or drop "
            "delta faults and max_delta_norm)")
    n_slots = fl.resolve_n_slots(ctx.n_units)
    scoring = strat.stateful
    run_cohort = _cohort_runner(fl, fl.n_clients)
    packed_cohort = packed_cohort_fn(loss_fn, assign, fl, loss_kwargs,
                                     scoring=scoring)
    # the codec axis (core/codecs.py): encode/decode round-trips the
    # packed deltas before they "cross the WAN" (= before corruption /
    # gating / aggregation).  codec "none" builds no transform and the
    # trace is bitwise the pre-codec one.
    from . import codecs as _codecs
    codec = _codecs.resolve_codec(fl.codec)
    codec_fn = _codecs.build_codec_transform(codec, assign, fl)

    def dense_cohort(gp, client_batches):
        hook = dense_norm_hook(assign) if scoring else None
        ones_mask = jax.tree_util.tree_map(
            lambda x: jnp.ones((), jnp.float32), gp)

        def one_client_dense(batches):
            return local_update(loss_fn, gp, ones_mask, batches, lr=fl.lr,
                                optimizer=fl.optimizer, prox_mu=fl.prox_mu,
                                loss_kwargs=loss_kwargs, norm_hook=hook)

        return jax.vmap(one_client_dense)(client_batches)

    def masked_cohort(gp, sel, client_batches):
        hook = dense_norm_hook(assign) if scoring else None

        def one_client(sel_row, batches):
            mask = mask_tree(assign, sel_row, gp)
            return local_update(loss_fn, gp, mask, batches, lr=fl.lr,
                                optimizer=fl.optimizer, prox_mu=fl.prox_mu,
                                loss_kwargs=loss_kwargs, norm_hook=hook)

        return jax.vmap(one_client)(sel, client_batches)

    def round_step(global_params, client_batches, weights, round_key,
                   sel_state=None, fault_plan=None, codec_state=None):
        c = _live_ctx(ctx, sel_state)
        sel = strat.select(round_key, c)
        if fl.always_train_head:
            sel = sel.at[:, -1].set(1.0)

        quarantined = None
        new_codec_state = None
        if strat.dense:
            # every unit trained: unmasked local step + the topology's
            # dense aggregation — for hub, bit-exact with the
            # conventional-FedAvg baseline trace
            deltas, metrics = run_cohort(dense_cohort, global_params,
                                         client_batches)
            new_params = aggregate_dense(global_params, deltas, sel, weights)
        elif use_packed:
            rows, valid = jax.vmap(
                lambda s: slot_plan(assign, s, n_slots, global_params))(sel)
            pdeltas, metrics = run_cohort(packed_cohort, global_params,
                                          rows, valid, client_batches)
            if codec_fn is not None:
                ck = jax.random.fold_in(round_key, _codecs.CODEC_KEY_TAG)
                decay = jnp.ones((fl.n_clients,), jnp.float32)
                pdeltas, new_codec_state = codec_fn(
                    pdeltas, rows, valid, weights, ck, codec_state, decay)
            if inject_on:
                if fault_plan is None:
                    fault_plan = {
                        "mode": jnp.zeros((fl.n_clients,), jnp.int32),
                        "scale": jnp.ones((fl.n_clients,), jnp.float32)}
                pdeltas = _faults.chaos_inject(pdeltas, fault_plan["mode"],
                                               fault_plan["scale"])
            if gate_on:
                pdeltas, weights, quarantined = gate_packed_updates(
                    assign, pdeltas, valid, weights, fl.max_delta_norm)
            new_params = aggregate_packed(global_params, pdeltas, rows,
                                          valid, sel, weights)
        else:
            deltas, metrics = run_cohort(masked_cohort, global_params,
                                         sel, client_batches)
            new_params = aggregate(global_params, deltas, sel, weights)
        out_metrics = {
            "loss_mean": metrics["loss_mean"].mean(),
            "loss_per_client": metrics["loss_mean"],
            "sel": sel,
        }
        if scoring:
            out_metrics["unit_sqnorm"] = metrics["unit_sqnorm"]
        if quarantined is not None:
            out_metrics["quarantined"] = quarantined
        if new_codec_state is not None:
            out_metrics["codec_state"] = new_codec_state
        return new_params, out_metrics

    # the Server derives state ownership from the strategy actually
    # baked into this step (a strategy= override might differ from
    # fl.strategy; re-resolving the name there would silently desync)
    round_step.selection_strategy = strat
    return round_step


def _fused_hub_aggregate(assign: UnitAssignment) -> Callable:
    """Masked FedAvg through the fused Pallas kernel, with the per-leaf
    tiling plan hoisted out of the traced function: built once at the
    first trace (shapes only) and reused for every retrace/call."""
    from ..kernels.masked_agg.ops import build_agg_plan, masked_fedavg_fused
    cache: Dict[str, Any] = {}

    def aggregate(g, d, sel, w):
        if "plan" not in cache:
            cache["plan"] = build_agg_plan(assign, g)
        return masked_fedavg_fused(g, d, sel, w, assign,
                                   plan=cache["plan"])

    return aggregate


def _fused_hier_aggregate(assign: UnitAssignment, mem) -> Callable:
    """Two-stage masked FedAvg with the hub combine running through the
    fused kernel: jnp per-edge partial means (stage 1), then the Pallas
    combine over edges with the per-edge weight mass as ``wsel``."""
    from ..kernels.masked_agg.ops import build_agg_plan, masked_combine_fused
    cache: Dict[str, Any] = {}

    def aggregate(g, d, sel, w):
        if "plan" not in cache:
            cache["plan"] = build_agg_plan(assign, g)
        means, e_den = hierarchical_edge_partials(d, sel, w, assign, mem)
        return masked_combine_fused(g, means, e_den, assign,
                                    plan=cache["plan"])

    return aggregate


class Topology:
    """Base class for federation-topology plugins.

    Subclasses set ``name`` and implement the three responsibilities:
    ``build_round_step`` (aggregation stage), ``round_bytes``/``summary``
    (exact accounting) and ``make_mesh`` (device view).  ``stateful``
    declares that the server state is not a single global model —
    ``init_state``/``global_params`` convert between the two (identity
    for star topologies).
    """

    name: ClassVar[str] = ""
    stateful: ClassVar[bool] = False

    # -- server state -----------------------------------------------------

    def init_state(self, params: PyTree, fl) -> PyTree:
        return params

    def global_params(self, state: PyTree, fl) -> PyTree:
        return state

    # -- the compiled round ----------------------------------------------

    def build_round_step(self, loss_fn: Callable, assign: UnitAssignment,
                         fl, loss_kwargs: Optional[Dict] = None, *,
                         strategy=None, scores=None):
        raise NotImplementedError

    def build_buffered_flush(self, assign: UnitAssignment, fl):
        """The topology's buffered-async aggregation stage (DESIGN.md
        §8): ``flush(global, pdeltas, rows, valid, sel, weights,
        client_ids) -> new_global`` over a stacked ``(B, ...)`` buffer
        of packed updates — the same scatter-accumulate as the sync
        packed round, so a zero-staleness flush is bit-exact with it.
        Star topologies implement this; stateful ones (gossip) have no
        global model to buffer against.
        """
        raise ValueError(
            f"topology {self.name!r} has no buffered-async path; set "
            "FLConfig.async_buffer=0 or use hub/hierarchical")

    def build_chunk_agg(self, assign: UnitAssignment, fl):
        """The topology's chunk-streamed aggregation stage (DESIGN.md
        §13): ``(init, accumulate, finalize)`` over the packed carry
        primitives of core/aggregation.py.  ``init(global) -> acc``;
        ``accumulate(acc, pdeltas, rows, valid, weights, positions) ->
        acc`` folds one chunk of packed uploads (``positions`` are the
        chunk's cohort positions, in order); ``finalize(global, acc,
        sel, weights) -> new_global`` applies the full-cohort
        denominators.  Streaming any chunking of the cohort in order
        reproduces the single-shot packed aggregate bitwise.
        """
        raise ValueError(
            f"topology {self.name!r} has no chunked cohort path; set "
            "FLConfig.cohort_chunk=0/n_registered=0 or use "
            "hub/hierarchical")

    # -- exact byte accounting -------------------------------------------

    def round_bytes(self, sel: np.ndarray, ubytes: np.ndarray,
                    fl) -> Dict[str, float]:
        raise NotImplementedError

    def buffered_round_bytes(self, entry_sel: np.ndarray,
                             client_ids: np.ndarray, ubytes: np.ndarray,
                             fl) -> Dict[str, float]:
        """Per-flush byte math for buffered async rounds (one
        ``entry_sel`` row per buffered update)."""
        raise ValueError(
            f"topology {self.name!r} has no buffered-async accounting")

    def summary(self, assign: UnitAssignment, params: PyTree,
                sel_history: np.ndarray, fl,
                wire_ubytes: Optional[np.ndarray] = None) -> Dict[str, float]:
        """Run-level comm summary; same core keys for every topology.

        ``wire_ubytes`` (the codec-encoded per-unit byte table) bills
        the per-round uplink at wire width; the ``reduction_vs_full``
        denominator stays the fp32 full-model round, so the reported
        reduction composes the structural freeze factor with the codec's
        compression factor.
        """
        ub = comm.unit_bytes(assign, params)
        wub = ub if wire_ubytes is None else wire_ubytes
        counts = comm.unit_param_counts(assign, params)
        hist = np.asarray(sel_history)
        per_round = [self.round_bytes(s, wub, fl)["uplink"] for s in hist]
        per_round_params = np.einsum("rcu,u->r", hist, counts)
        full = self.round_bytes(np.ones_like(hist[0]), ub, fl)["uplink"]
        return {
            "avg_uplink_bytes": float(np.mean(per_round)),
            "avg_trained_params": float(per_round_params.mean()),
            "total_uplink_bytes": float(np.sum(per_round)),
            "reduction_vs_full": 1.0 - float(np.mean(per_round)) / full
            if full else 0.0,
        }

    # -- mesh view --------------------------------------------------------

    def make_mesh(self, fl, *, multi_pod: bool = False):
        from ..launch.mesh import make_fl_mesh
        return make_fl_mesh(fl.n_clients, multi_pod=multi_pod)

    def __repr__(self):
        return f"{type(self).__name__}(name={self.name!r})"


# ---------------------------------------------------------------------------
# registry (mirrors core/strategies.py)

_REGISTRY: Dict[str, Topology] = {}


class UnknownTopologyError(ValueError):
    pass


def register_topology(obj: Union[Type[Topology], Topology], *,
                      name: Optional[str] = None):
    """Register a topology class (instantiated with no args) or instance.

    Usable as a decorator::

        @register_topology
        class Mine(Topology):
            name = "mine"
            ...
    """
    topo = obj() if isinstance(obj, type) else obj
    key = name or topo.name
    if not key:
        raise ValueError(f"topology {obj!r} has no name")
    _REGISTRY[key] = topo
    return obj


def unregister_topology(name: str):
    _REGISTRY.pop(name, None)


def registered_topologies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_topology(name: str) -> Topology:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownTopologyError(unknown_name_message(
            "topology", name, _REGISTRY)) from None


def resolve_topology(spec: Union[str, Topology, None]) -> Topology:
    """Name or instance -> instance (None -> the hub default)."""
    if spec is None:
        return get_topology("hub")
    return get_topology(spec) if isinstance(spec, str) else spec


# ---------------------------------------------------------------------------
# built-in topologies

@register_topology
class Hub(Topology):
    """The paper's FEDn combiner star: every client talks to one hub.

    The default — its round step is the identical trace the
    pre-topology ``build_round_step`` compiled (bit-exact).
    """
    name = "hub"

    def build_round_step(self, loss_fn, assign, fl, loss_kwargs=None, *,
                         strategy=None, scores=None):
        if fl.resolve_fused_agg():
            aggregate = _fused_hub_aggregate(assign)
        else:
            aggregate = lambda g, d, sel, w: masked_fedavg(g, d, sel, w,
                                                           assign)
        return _star_round_step(
            loss_fn, assign, fl, loss_kwargs, strategy=strategy,
            scores=scores,
            aggregate=aggregate,
            aggregate_dense=lambda g, d, sel, w: fedavg(g, d, w),
            aggregate_packed=lambda g, d, r, v, sel, w:
                masked_fedavg_packed(g, d, r, v, sel, w, assign))

    def build_buffered_flush(self, assign, fl):
        def flush(g, pdeltas, rows, valid, sel, weights, client_ids):
            return masked_fedavg_packed(g, pdeltas, rows, valid, sel,
                                        weights, assign)
        return flush

    def build_chunk_agg(self, assign, fl):
        def init(g):
            return packed_acc_init(assign, g)

        def accumulate(acc, pdeltas, rows, valid, weights, positions):
            return packed_accumulate(assign, acc, pdeltas, rows, valid,
                                     weights)

        def finalize(g, acc, sel, weights):
            return packed_finalize(assign, g, acc, sel, weights)

        return init, accumulate, finalize

    def round_bytes(self, sel, ubytes, fl):
        return comm.hub_round_bytes(
            sel, ubytes,
            downlink="selected" if fl.synchronized else "full")

    def buffered_round_bytes(self, entry_sel, client_ids, ubytes, fl):
        return comm.buffered_hub_round_bytes(
            entry_sel, ubytes,
            downlink="selected" if fl.synchronized else "full")

    def summary(self, assign, params, sel_history, fl, wire_ubytes=None):
        # the exact Table 4 reproduction, unchanged from PR 1; a codec's
        # wire byte table rebills the uplink terms at encoded width
        return comm.table4_row(assign, params, sel_history,
                               wire_ubytes=wire_ubytes)


@register_topology
class Hierarchical(Topology):
    """Edge aggregators between clients and hub (FLConfig.n_edges).

    Clients are partitioned into contiguous edge groups; each edge
    reduces its clients' masked deltas into per-unit partial aggregates
    and only the per-edge selection union crosses the edge->hub WAN
    link — ``round_bytes`` reports that WAN term as ``uplink``.
    """
    name = "hierarchical"

    def build_round_step(self, loss_fn, assign, fl, loss_kwargs=None, *,
                         strategy=None, scores=None):
        mem = jnp.asarray(comm.edge_membership(fl.n_clients,
                                               fl.resolve_n_edges()))
        if fl.resolve_fused_agg():
            agg = _fused_hier_aggregate(assign, mem)
        else:
            agg = lambda g, d, sel, w: hierarchical_masked_fedavg(
                g, d, sel, w, assign, mem)
        return _star_round_step(
            loss_fn, assign, fl, loss_kwargs, strategy=strategy,
            scores=scores, aggregate=agg, aggregate_dense=agg,
            aggregate_packed=lambda g, d, r, v, sel, w:
                hierarchical_masked_fedavg_packed(g, d, r, v, sel, w,
                                                  assign, mem))

    def build_buffered_flush(self, assign, fl):
        mem = jnp.asarray(comm.edge_membership(fl.n_clients,
                                               fl.resolve_n_edges()))

        def flush(g, pdeltas, rows, valid, sel, weights, client_ids):
            # (E, B) membership: entry j reduces at its client's edge
            return hierarchical_masked_fedavg_packed(
                g, pdeltas, rows, valid, sel, weights, assign,
                mem[:, client_ids])
        return flush

    def build_chunk_agg(self, assign, fl):
        mem = jnp.asarray(comm.edge_membership(
            fl.n_clients, fl.resolve_n_edges())).astype(jnp.float32)
        edge_of = jnp.argmax(mem, axis=0)                     # (C,)

        def init(g):
            return packed_acc_init(assign, g, n_edges=mem.shape[0])

        def accumulate(acc, pdeltas, rows, valid, weights, positions):
            # each chunk client lands in its edge's stage-1 partial
            return packed_accumulate(assign, acc, pdeltas, rows, valid,
                                     weights, edge_idx=edge_of[positions])

        def finalize(g, acc, sel, weights):
            return packed_finalize(assign, g, acc, sel, weights,
                                   membership=mem)

        return init, accumulate, finalize

    def round_bytes(self, sel, ubytes, fl):
        mem = comm.edge_membership(fl.n_clients, fl.resolve_n_edges())
        return comm.hierarchical_round_bytes(
            sel, ubytes, mem,
            downlink="selected" if fl.synchronized else "full")

    def buffered_round_bytes(self, entry_sel, client_ids, ubytes, fl):
        mem = comm.edge_membership(fl.n_clients, fl.resolve_n_edges())
        return comm.buffered_hierarchical_round_bytes(
            entry_sel, client_ids, ubytes, mem,
            downlink="selected" if fl.synchronized else "full")

    def make_mesh(self, fl, *, multi_pod: bool = False):
        from ..launch.mesh import make_hier_fl_mesh
        return make_hier_fl_mesh(fl.resolve_n_edges(), fl.n_clients,
                                 multi_pod=multi_pod)


@register_topology
class Gossip(Topology):
    """Hubless peer averaging over a doubly-stochastic ring.

    The server state is a stacked pytree of per-client replicas
    (leading C axis) carried across rounds.  Per round each client runs
    masked local training from its OWN replica, then replicas mix:
    ``x' = W @ x`` with the ring Metropolis matrix W.  W is doubly
    stochastic, so the uniform replica average — ``global_params`` — is
    exactly preserved by mixing and drifts only through local training.
    Client data weights reweight nothing here (mixing is fixed);
    zero-weight clients (stragglers) skip their local update but still
    mix.
    """
    name = "gossip"
    stateful = True

    def init_state(self, params, fl):
        c = fl.n_clients
        return jax.tree_util.tree_map(
            lambda x: jnp.tile(x[None], (c,) + (1,) * jnp.ndim(x)), params)

    def global_params(self, state, fl):
        return jax.tree_util.tree_map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype),
            state)

    def build_round_step(self, loss_fn, assign, fl, loss_kwargs=None, *,
                         strategy=None, scores=None):
        if fl.packed:
            raise ValueError(
                "packed round path: gossip mixing blends full replicas, "
                "so there is nothing to pack — use hub or hierarchical")
        if getattr(fl, "client_shards", 0):
            raise ValueError(
                "client_shards: gossip carries per-client replicas as "
                "server state and mixes them with a ring matmul — the "
                "cohort cannot shard over the client mesh axis; use "
                "hub or hierarchical")
        strat, ctx = _selection_setup(assign, fl, strategy, scores)
        mix = jnp.asarray(ring_mixing_matrix(fl.n_clients))
        scoring = strat.stateful

        def round_step(state, client_batches, weights, round_key,
                       sel_state=None):
            sel = strat.select(round_key, _live_ctx(ctx, sel_state))
            if fl.always_train_head:
                sel = sel.at[:, -1].set(1.0)
            active = (weights > 0).astype(jnp.float32)       # (C,)
            hook = dense_norm_hook(assign) if scoring else None

            def one_client(params_c, sel_row, batches):
                mask = mask_tree(assign, sel_row, params_c)
                return local_update(loss_fn, params_c, mask, batches,
                                    lr=fl.lr, optimizer=fl.optimizer,
                                    prox_mu=fl.prox_mu,
                                    loss_kwargs=loss_kwargs,
                                    norm_hook=hook)

            deltas, metrics = jax.vmap(one_client)(state, sel,
                                                   client_batches)
            trained = jax.tree_util.tree_map(
                lambda x, d: x + (d * active.reshape(
                    (-1,) + (1,) * (d.ndim - 1))).astype(x.dtype),
                state, deltas)
            mixed = jax.tree_util.tree_map(
                lambda x: jnp.tensordot(
                    mix, x.astype(jnp.float32), axes=(1, 0)).astype(x.dtype),
                trained)
            out_metrics = {
                "loss_mean": metrics["loss_mean"].mean(),
                "loss_per_client": metrics["loss_mean"],
                "sel": sel,
            }
            if scoring:
                out_metrics["unit_sqnorm"] = metrics["unit_sqnorm"]
            return mixed, out_metrics

        round_step.selection_strategy = strat
        return round_step

    def round_bytes(self, sel, ubytes, fl):
        return comm.gossip_round_bytes(sel, ubytes)

    def summary(self, assign, params, sel_history, fl, wire_ubytes=None):
        # codecs are rejected for gossip at config time (no packed
        # uplink), so wire_ubytes can only be the fp32 table here
        out = Topology.summary(self, assign, params, sel_history, fl,
                               wire_ubytes)
        hist = np.asarray(sel_history)
        ub = comm.unit_bytes(assign, params)
        out["degree"] = comm.gossip_round_bytes(hist[0], ub)["degree"]
        return out
