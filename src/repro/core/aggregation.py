"""Server-side aggregation.

* ``fedavg``          — Eq. (1), unchanged from McMahan et al.
* ``masked_fedavg``   — participation-weighted per-unit FedAvg: when
  clients ship disjoint layer subsets, each unit averages only over the
  clients that trained it (the paper's "minor modifications to the FEDn
  aggregation server").  Units nobody trained keep the global value.
* ``fedprox`` client proximal term lives in core/client.py.

All functions take client deltas stacked along a leading client axis
(the ``client`` mesh axis under pjit; the sum lowers to the cross-client
reduce — see launch/dryrun.py).  The fused Pallas variant is
``kernels/masked_agg``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .masking import UnitAssignment, mask_tree, apply_mask

PyTree = Any


def fedavg(global_params, deltas, weights) -> PyTree:
    """deltas: pytree with leading client dim C; weights (C,) data sizes."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)

    def one(g, d):
        wd = jnp.tensordot(w.astype(jnp.float32),
                           d.astype(jnp.float32), axes=(0, 0))
        return (g.astype(jnp.float32) + wd).astype(g.dtype)

    return jax.tree_util.tree_map(one, global_params, deltas)


def masked_fedavg(global_params, deltas, sel, weights,
                  assign: UnitAssignment) -> PyTree:
    """Participation-weighted per-unit FedAvg.

    sel (C, U) 0/1; for each unit u:
        new_u = global_u + sum_c w_c sel_cu delta_cu / sum_c w_c sel_cu
    Units with zero participation keep the global value exactly.
    """
    wf = weights.astype(jnp.float32)

    def one(lu, g, d):
        # per-client scalar (or per-macro vector) participation mask
        if lu.kind == "scalar":
            m = sel[:, lu.base]                                  # (C,)
        else:
            nm = g.shape[0]
            idx = lu.base + lu.stride * jnp.arange(nm)
            m = sel[:, idx]                                      # (C, nm)
        wm = m * wf.reshape((-1,) + (1,) * (m.ndim - 1))         # (C[,nm])
        denom = wm.sum(0)                                        # ([nm])
        num = jnp.tensordot(wm, d.astype(jnp.float32), axes=(0, 0)) \
            if m.ndim == 1 else \
            jnp.einsum("cm,cm...->m...", wm, d.astype(jnp.float32))
        denom_b = jnp.reshape(denom, jnp.shape(denom) +
                              (1,) * (num.ndim - jnp.ndim(denom)))
        upd = jnp.where(denom_b > 0, num / jnp.maximum(denom_b, 1e-9), 0.0)
        return (g.astype(jnp.float32) + upd).astype(g.dtype)

    from .masking import _is_leafunit
    return jax.tree_util.tree_map(one, assign.leaf_units, global_params,
                                  deltas, is_leaf=_is_leafunit)
