"""Server-side aggregation.

* ``fedavg``          — Eq. (1), unchanged from McMahan et al.
* ``masked_fedavg``   — participation-weighted per-unit FedAvg: when
  clients ship disjoint layer subsets, each unit averages only over the
  clients that trained it (the paper's "minor modifications to the FEDn
  aggregation server").  Units nobody trained keep the global value.
* ``hierarchical_masked_fedavg`` — the same average computed in two
  genuine stages: per-edge partial numerator/denominator sums (each edge
  aggregator reduces its own clients), then a hub combine over edges.
  Partial weighted sums are associative, so the result matches the flat
  hub average up to reduce ordering — but the staging is real: only the
  per-edge partial aggregates (one slot per unit some edge client
  trained) cross the edge->hub boundary (core/comm.py accounts this).
* ``masked_fedavg_packed`` / ``hierarchical_masked_fedavg_packed`` —
  the same averages computed from **packed slot buffers** (DESIGN.md
  §7): each client contributes only its ``(n_slots, …)`` trained rows
  plus a ``(C, L)`` slot->row index, and the combiner scatter-
  accumulates client uploads in client order — the collective moves
  ~``n_slots/U`` of the model instead of a full-size masked tree, and
  the accumulate shares XLA's fused multiply-add with the dense
  einsum, so packed == dense holds bitwise.
* ``packed_acc_init`` / ``packed_accumulate`` / ``packed_finalize`` —
  the packed combiners factored into carry primitives: a float32
  numerator carry, strict client-order scatter-accumulate, and a
  denominator-side combine.  The cohort engine (core/cohort.py)
  streams chunked cohorts through these, and the single-shot packed
  functions above are literal init -> accumulate -> finalize
  compositions, so chunked == single-shot holds bitwise by
  construction.
* ``hierarchical_edge_partials`` — stage 1 of the two-stage average on
  its own (per-edge partial means + weight mass), so the hub combine
  can run through the fused Pallas kernel (``kernels/masked_agg``).
* ``fedprox`` client proximal term lives in core/client.py.

All functions take client deltas stacked along a leading client axis
(the ``client`` mesh axis under pjit; the sum lowers to the cross-client
reduce — see launch/dryrun.py).  The fused Pallas variant is
``kernels/masked_agg``.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .masking import UnitAssignment, mask_tree, apply_mask

PyTree = Any


def packed_acc_init(assign: UnitAssignment, global_params,
                    n_edges: Optional[int] = None) -> PyTree:
    """Zero partial-aggregate carry for the packed scatter-accumulate.

    One float32 numerator buffer per leaf: ``g.shape`` for hub
    aggregation, ``(n_edges,) + g.shape`` when the per-edge stage-1
    partials are kept separate (hierarchical).  This is the state the
    cohort engine carries across chunks (DESIGN.md §13); denominators
    are functions of ``sel``/``weights`` alone and live in
    ``packed_finalize``.
    """
    lead = () if n_edges is None else (int(n_edges),)

    def one(lu, g):
        return jnp.zeros(lead + tuple(g.shape), jnp.float32)

    from .masking import _is_leafunit
    return jax.tree_util.tree_map(one, assign.leaf_units, global_params,
                                  is_leaf=_is_leafunit)


def packed_accumulate(assign: UnitAssignment, acc, packed_deltas, rows,
                      valid, weights, edge_idx: Optional[jnp.ndarray] = None
                      ) -> PyTree:
    """Scatter-accumulate a block of packed client uploads into ``acc``.

    Clients land strictly in their stacked order (the FEDn server
    accumulating uploads one by one), so accumulating a cohort in one
    call or streamed through any chunking of the same order produces
    the identical float sequence — chunked == single-shot holds
    bitwise by construction.  Stacked-leaf entries are ``(K, L, ...)``
    slot deltas with ``rows``/``valid (K, L)``; scalar leaves carry
    dense ``(K, ...)`` deltas with ``valid (K,)`` participation.  With
    ``edge_idx (K,)`` each client lands in its edge's stage-1 partial
    instead of the flat hub numerator.
    """
    wf = weights.astype(jnp.float32)

    def one(lu, a, d, r, v):
        df = d.astype(jnp.float32)
        if lu.kind == "scalar":
            wm = v * wf                                       # (K,)
            if edge_idx is None:
                def accumulate(num, xs):
                    wm_c, d_c = xs
                    return num + wm_c * d_c, None

                num, _ = jax.lax.scan(accumulate, a, (wm, df))
            else:
                def accumulate(num, xs):
                    e_c, wm_c, d_c = xs
                    return num.at[e_c].add(wm_c * d_c), None

                num, _ = jax.lax.scan(accumulate, a, (edge_idx, wm, df))
            return num
        wv = v * wf[:, None]                                  # (K, L)
        if edge_idx is None:
            nm = a.shape[0]
            shape1 = (nm,) + (1,) * (df.ndim - 2)

            def accumulate(num, xs):
                # scatter the client's RAW slot rows + weights to full
                # width, then one fused multiply-add: XLA contracts the
                # dense einsum with fma, so pre-rounding the w*delta
                # product would diverge in the last bit
                r_c, wv_c, d_c = xs
                d_full = jnp.zeros_like(num).at[r_c].set(d_c)
                w_full = jnp.zeros((nm,), jnp.float32).at[r_c].set(wv_c)
                return num + w_full.reshape(shape1) * d_full, None

            num, _ = jax.lax.scan(accumulate, a, (r, wv, df))
        else:
            wd = df * wv.reshape(wv.shape + (1,) * (df.ndim - 2))

            def accumulate(e_num, xs):
                e_c, r_c, wd_c = xs
                return e_num.at[e_c, r_c].add(wd_c), None

            num, _ = jax.lax.scan(accumulate, a, (edge_idx, r, wd))
        return num

    from .masking import _is_leafunit
    return jax.tree_util.tree_map(one, assign.leaf_units, acc,
                                  packed_deltas, rows, valid,
                                  is_leaf=_is_leafunit)


def packed_finalize(assign: UnitAssignment, global_params, acc, sel,
                    weights, membership: Optional[jnp.ndarray] = None
                    ) -> PyTree:
    """Combine accumulated packed numerators into new global params.

    ``sel (C, U)`` / ``weights (C,)`` cover the FULL cohort (every
    client whose upload was accumulated), so the per-unit denominators
    are the dense path's own expressions regardless of how the
    numerator was chunked.  With ``membership (E, C)`` the ``E``
    stage-1 partials are summed at the hub first (hierarchical stage
    2).  Units with zero participation keep the global value exactly.
    """
    wf = weights.astype(jnp.float32)

    def one(lu, g, num):
        if membership is not None:
            num = num.sum(axis=0)
        if lu.kind == "scalar":
            wm = sel[:, lu.base] * wf
            denom = (membership @ wm).sum(axis=0) if membership is not None \
                else wm.sum()
            upd = jnp.where(denom > 0, num / jnp.maximum(denom, 1e-9), 0.0)
            return (g.astype(jnp.float32) + upd).astype(g.dtype)
        nm = g.shape[0]
        idx = lu.base + lu.stride * jnp.arange(nm)
        if membership is not None:
            wm = sel[:, idx] * wf[:, None]
            denom = jnp.einsum("ec,cm->em", membership, wm).sum(axis=0)
        else:
            denom = (sel[:, idx] * wf[:, None]).sum(0)        # (nm,)
        den_b = denom.reshape((nm,) + (1,) * (num.ndim - 1))
        upd = jnp.where(den_b > 0, num / jnp.maximum(den_b, 1e-9), 0.0)
        return (g.astype(jnp.float32) + upd).astype(g.dtype)

    from .masking import _is_leafunit
    return jax.tree_util.tree_map(one, assign.leaf_units, global_params,
                                  acc, is_leaf=_is_leafunit)


def gate_packed_updates(assign: UnitAssignment, packed_deltas, valid,
                        weights, max_norm: float = 0.0
                        ) -> Tuple[PyTree, jnp.ndarray, jnp.ndarray]:
    """Packed-delta validation gate (DESIGN.md §14): quarantine hostile
    uploads *before* the scatter-accumulate sees them.

    A client is quarantined when any element of its **valid** slot rows
    is non-finite, or (``max_norm > 0``) when its weighted-valid delta
    sqnorm exceeds ``max_norm**2`` across all leaves.  Returns
    ``(clean_deltas, gated_weights, quarantined)``:

    * ``clean_deltas`` — quarantined clients' rows zeroed, and every
      non-finite element zeroed everywhere (the accumulate scatters
      padding rows with weight 0, and ``0 * NaN`` would still poison
      the numerator — a torn payload whose NaN tail lands on padding
      must not sink an otherwise-intact update);
    * ``gated_weights`` — ``weights * ok``: quarantined clients leave
      the per-unit denominators, so surviving weights renormalize
      exactly as if the client had never uploaded;
    * ``quarantined`` — (C,) float32 0/1 per client.

    Fault-free inputs make every select take its first branch
    (``where(True, d, 0) == d``; ``w * 1.0 == w`` for finite f32), so
    an enabled-but-untripped gate is BITWISE transparent — the property
    the zero-rate chaos tests pin down.
    """
    checks = []                                # (finite (C,), sq (C,))

    def vmask(lu, d, v):
        lead = 1 if lu.kind == "scalar" else 2
        return jnp.reshape(v != 0, v.shape + (1,) * (d.ndim - lead))

    def check(lu, d, v):
        c = d.shape[0]
        if not jnp.issubdtype(d.dtype, jnp.floating):
            checks.append((jnp.ones((c,), bool),
                           jnp.zeros((c,), jnp.float32)))
            return d
        vb = vmask(lu, d, v)
        fin = jnp.isfinite(d)
        # client health is judged on valid rows only: garbage in
        # weight-0 padding does not incriminate the upload
        finite = (fin | ~vb).reshape(c, -1).all(axis=1)
        df = jnp.where(vb & fin, d.astype(jnp.float32), 0.0)
        checks.append((finite, (df * df).reshape(c, -1).sum(axis=1)))
        return d

    from .masking import _is_leafunit
    jax.tree_util.tree_map(check, assign.leaf_units, packed_deltas,
                           valid, is_leaf=_is_leafunit)
    ok = checks[0][0]
    sq = checks[0][1]
    for f, s in checks[1:]:
        ok = ok & f
        sq = sq + s
    if max_norm > 0.0:
        ok = ok & (sq <= jnp.float32(max_norm) ** 2)
    okf = ok.astype(jnp.float32)

    def clean(lu, d, v):
        if not jnp.issubdtype(d.dtype, jnp.floating):
            return d
        keep = ok.reshape((d.shape[0],) + (1,) * (d.ndim - 1))
        return jnp.where(keep & jnp.isfinite(d), d, jnp.zeros_like(d))

    cleaned = jax.tree_util.tree_map(clean, assign.leaf_units,
                                     packed_deltas, valid,
                                     is_leaf=_is_leafunit)
    return cleaned, weights * okf, 1.0 - okf


def fedavg(global_params, deltas, weights) -> PyTree:
    """deltas: pytree with leading client dim C; weights (C,) data sizes."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)

    def one(g, d):
        wd = jnp.tensordot(w.astype(jnp.float32),
                           d.astype(jnp.float32), axes=(0, 0))
        return (g.astype(jnp.float32) + wd).astype(g.dtype)

    return jax.tree_util.tree_map(one, global_params, deltas)


def masked_fedavg(global_params, deltas, sel, weights,
                  assign: UnitAssignment) -> PyTree:
    """Participation-weighted per-unit FedAvg.

    sel (C, U) 0/1; for each unit u:
        new_u = global_u + sum_c w_c sel_cu delta_cu / sum_c w_c sel_cu
    Units with zero participation keep the global value exactly.
    """
    wf = weights.astype(jnp.float32)

    def one(lu, g, d):
        # per-client scalar (or per-macro vector) participation mask
        if lu.kind == "scalar":
            m = sel[:, lu.base]                                  # (C,)
        else:
            nm = g.shape[0]
            idx = lu.base + lu.stride * jnp.arange(nm)
            m = sel[:, idx]                                      # (C, nm)
        wm = m * wf.reshape((-1,) + (1,) * (m.ndim - 1))         # (C[,nm])
        denom = wm.sum(0)                                        # ([nm])
        num = jnp.tensordot(wm, d.astype(jnp.float32), axes=(0, 0)) \
            if m.ndim == 1 else \
            jnp.einsum("cm,cm...->m...", wm, d.astype(jnp.float32))
        denom_b = jnp.reshape(denom, jnp.shape(denom) +
                              (1,) * (num.ndim - jnp.ndim(denom)))
        upd = jnp.where(denom_b > 0, num / jnp.maximum(denom_b, 1e-9), 0.0)
        return (g.astype(jnp.float32) + upd).astype(g.dtype)

    from .masking import _is_leafunit
    return jax.tree_util.tree_map(one, assign.leaf_units, global_params,
                                  deltas, is_leaf=_is_leafunit)


def masked_fedavg_packed(global_params, packed_deltas, rows, valid, sel,
                         weights, assign: UnitAssignment) -> PyTree:
    """Participation-weighted FedAvg over packed slot buffers (§7).

    ``packed_deltas`` stacked-leaf entries are ``(C, L, ...)`` slot
    deltas with ``rows (C, L)`` macro indices and ``valid (C, L)``
    slot masks (from ``slot_plan`` under vmap); scalar leaves carry
    dense ``(C, ...)`` deltas.  The cross-client reduce only ever
    reads a client's ``n_slots`` trained rows — the combiner
    scatter-accumulates each client's slots in client order (the FEDn
    server accumulating uploads one by one), which is bit-identical to
    the dense einsum's sequential reduction, so packed == dense holds
    bitwise (regression-tested).  Per-unit denominators are functions
    of ``sel``/``weights`` alone and reuse the dense path's own
    expression.  Units with zero participation keep the global value
    exactly (zero denominator).

    Composed from ``packed_acc_init`` / ``packed_accumulate`` /
    ``packed_finalize`` — the same primitives the cohort engine streams
    chunks through, so the chunked path is this function by
    construction.
    """
    acc = packed_acc_init(assign, global_params)
    acc = packed_accumulate(assign, acc, packed_deltas, rows, valid, weights)
    return packed_finalize(assign, global_params, acc, sel, weights)


def hierarchical_masked_fedavg_packed(global_params, packed_deltas, rows,
                                      valid, sel, weights,
                                      assign: UnitAssignment,
                                      membership: jnp.ndarray) -> PyTree:
    """Two-stage (edge -> hub) FedAvg over packed slot buffers.

    Stage 1 scatter-accumulates each client's slots into its edge's
    partial aggregate (per-edge ``(E, nm, ...)`` buffers, clients in
    upload order); stage 2 sums the ``E`` partials at the hub — the
    same staging as ``hierarchical_masked_fedavg`` but reading only
    trained slots.  Per-edge denominators reuse the dense path's own
    ``sel``-based expression.
    """
    mem = membership.astype(jnp.float32)
    edge_of = jnp.argmax(mem, axis=0)                         # (C,)
    acc = packed_acc_init(assign, global_params, n_edges=mem.shape[0])
    acc = packed_accumulate(assign, acc, packed_deltas, rows, valid,
                            weights, edge_idx=edge_of)
    return packed_finalize(assign, global_params, acc, sel, weights,
                           membership=mem)


def hierarchical_edge_partials(deltas, sel, weights,
                               assign: UnitAssignment,
                               membership: jnp.ndarray
                               ) -> Tuple[PyTree, jnp.ndarray]:
    """Stage 1 of the two-stage masked FedAvg, exposed on its own.

    Returns ``(edge_means, e_den)``: per-edge partial *means* (pytree
    with a leading E axis; zero where an edge had no participant) and
    the per-edge per-unit weight mass ``e_den (E, U)``.  Feeding these
    to any flat combiner with ``wsel = e_den`` — in particular the
    fused Pallas ``masked_combine_fused`` — reproduces the hub combine:
    ``Σ_e e_den·mean / Σ_e e_den = Σ_e num / Σ_e den``.
    """
    wf = weights.astype(jnp.float32)
    mem = membership.astype(jnp.float32)
    wsel = sel * wf[:, None]                                  # (C, U)
    e_den = mem @ wsel                                        # (E, U)

    def one(lu, d):
        df = d.astype(jnp.float32)
        if lu.kind == "scalar":
            wm = sel[:, lu.base] * wf
            e_num = jnp.einsum("ec,c,c...->e...", mem, wm, df)
            den = e_den[:, lu.base]
        else:
            nm = df.shape[1]
            idx = lu.base + lu.stride * jnp.arange(nm)
            wm = sel[:, idx] * wf[:, None]
            e_num = jnp.einsum("ec,cm,cm...->em...", mem, wm, df)
            den = e_den[:, idx]
        den_b = jnp.reshape(den, den.shape + (1,) * (e_num.ndim - den.ndim))
        return jnp.where(den_b > 0, e_num / jnp.maximum(den_b, 1e-9), 0.0)

    from .masking import _is_leafunit
    means = jax.tree_util.tree_map(one, assign.leaf_units, deltas,
                                   is_leaf=_is_leafunit)
    return means, e_den


def hierarchical_masked_fedavg(global_params, deltas, sel, weights,
                               assign: UnitAssignment,
                               membership: jnp.ndarray) -> PyTree:
    """Two-stage participation-weighted FedAvg (edge aggregators -> hub).

    membership (E, C) 0/1: client c belongs to edge e (each client to
    exactly one edge).  Stage 1 computes, per edge, the partial weighted
    numerator and denominator over that edge's clients; stage 2 combines
    the E partial aggregates at the hub.  Units with zero participation
    anywhere keep the global value exactly, as in ``masked_fedavg``.
    """
    wf = weights.astype(jnp.float32)
    mem = membership.astype(jnp.float32)
    edge_of = jnp.argmax(mem, axis=0)                            # (C,)

    def one(lu, g, d):
        if lu.kind == "scalar":
            m = sel[:, lu.base]                                  # (C,)
        else:
            nm = g.shape[0]
            idx = lu.base + lu.stride * jnp.arange(nm)
            m = sel[:, idx]                                      # (C, nm)
        wm = m * wf.reshape((-1,) + (1,) * (m.ndim - 1))         # (C[,nm])
        df = d.astype(jnp.float32)
        if m.ndim == 1:
            # stage 1: per-edge partials, clients landing in upload
            # order — the same float sequence as the packed/chunked
            # scatter-accumulate (an (E,C)@(C,…) matmul reduces in a
            # different order and diverges in the last bit)
            def accumulate(e_num, xs):
                e_c, wm_c, d_c = xs
                return e_num.at[e_c].add(wm_c * d_c), None

            e_num, _ = jax.lax.scan(
                accumulate, jnp.zeros((mem.shape[0],) + df.shape[1:]),
                (edge_of, wm, df))
            e_den = mem @ wm                                     # (E,)
        else:
            e_num = jnp.einsum("ec,cm,cm...->em...", mem, wm, df)
            e_den = jnp.einsum("ec,cm->em", mem, wm)
        # stage 2: hub combine of the edge partial aggregates
        num = e_num.sum(axis=0)
        denom = e_den.sum(axis=0)
        denom_b = jnp.reshape(denom, jnp.shape(denom) +
                              (1,) * (num.ndim - jnp.ndim(denom)))
        upd = jnp.where(denom_b > 0, num / jnp.maximum(denom_b, 1e-9), 0.0)
        return (g.astype(jnp.float32) + upd).astype(g.dtype)

    from .masking import _is_leafunit
    return jax.tree_util.tree_map(one, assign.leaf_units, global_params,
                                  deltas, is_leaf=_is_leafunit)
