"""Server-side aggregation.

* ``fedavg``          — Eq. (1), unchanged from McMahan et al.
* ``masked_fedavg``   — participation-weighted per-unit FedAvg: when
  clients ship disjoint layer subsets, each unit averages only over the
  clients that trained it (the paper's "minor modifications to the FEDn
  aggregation server").  Units nobody trained keep the global value.
* ``hierarchical_masked_fedavg`` — the same average computed in two
  genuine stages: per-edge partial numerator/denominator sums (each edge
  aggregator reduces its own clients), then a hub combine over edges.
  Partial weighted sums are associative, so the result matches the flat
  hub average up to reduce ordering — but the staging is real: only the
  per-edge partial aggregates (one slot per unit some edge client
  trained) cross the edge->hub boundary (core/comm.py accounts this).
* ``fedprox`` client proximal term lives in core/client.py.

All functions take client deltas stacked along a leading client axis
(the ``client`` mesh axis under pjit; the sum lowers to the cross-client
reduce — see launch/dryrun.py).  The fused Pallas variant is
``kernels/masked_agg``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .masking import UnitAssignment, mask_tree, apply_mask

PyTree = Any


def fedavg(global_params, deltas, weights) -> PyTree:
    """deltas: pytree with leading client dim C; weights (C,) data sizes."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)

    def one(g, d):
        wd = jnp.tensordot(w.astype(jnp.float32),
                           d.astype(jnp.float32), axes=(0, 0))
        return (g.astype(jnp.float32) + wd).astype(g.dtype)

    return jax.tree_util.tree_map(one, global_params, deltas)


def masked_fedavg(global_params, deltas, sel, weights,
                  assign: UnitAssignment) -> PyTree:
    """Participation-weighted per-unit FedAvg.

    sel (C, U) 0/1; for each unit u:
        new_u = global_u + sum_c w_c sel_cu delta_cu / sum_c w_c sel_cu
    Units with zero participation keep the global value exactly.
    """
    wf = weights.astype(jnp.float32)

    def one(lu, g, d):
        # per-client scalar (or per-macro vector) participation mask
        if lu.kind == "scalar":
            m = sel[:, lu.base]                                  # (C,)
        else:
            nm = g.shape[0]
            idx = lu.base + lu.stride * jnp.arange(nm)
            m = sel[:, idx]                                      # (C, nm)
        wm = m * wf.reshape((-1,) + (1,) * (m.ndim - 1))         # (C[,nm])
        denom = wm.sum(0)                                        # ([nm])
        num = jnp.tensordot(wm, d.astype(jnp.float32), axes=(0, 0)) \
            if m.ndim == 1 else \
            jnp.einsum("cm,cm...->m...", wm, d.astype(jnp.float32))
        denom_b = jnp.reshape(denom, jnp.shape(denom) +
                              (1,) * (num.ndim - jnp.ndim(denom)))
        upd = jnp.where(denom_b > 0, num / jnp.maximum(denom_b, 1e-9), 0.0)
        return (g.astype(jnp.float32) + upd).astype(g.dtype)

    from .masking import _is_leafunit
    return jax.tree_util.tree_map(one, assign.leaf_units, global_params,
                                  deltas, is_leaf=_is_leafunit)


def hierarchical_masked_fedavg(global_params, deltas, sel, weights,
                               assign: UnitAssignment,
                               membership: jnp.ndarray) -> PyTree:
    """Two-stage participation-weighted FedAvg (edge aggregators -> hub).

    membership (E, C) 0/1: client c belongs to edge e (each client to
    exactly one edge).  Stage 1 computes, per edge, the partial weighted
    numerator and denominator over that edge's clients; stage 2 combines
    the E partial aggregates at the hub.  Units with zero participation
    anywhere keep the global value exactly, as in ``masked_fedavg``.
    """
    wf = weights.astype(jnp.float32)
    mem = membership.astype(jnp.float32)

    def one(lu, g, d):
        if lu.kind == "scalar":
            m = sel[:, lu.base]                                  # (C,)
        else:
            nm = g.shape[0]
            idx = lu.base + lu.stride * jnp.arange(nm)
            m = sel[:, idx]                                      # (C, nm)
        wm = m * wf.reshape((-1,) + (1,) * (m.ndim - 1))         # (C[,nm])
        df = d.astype(jnp.float32)
        if m.ndim == 1:
            # stage 1: per-edge partials
            e_num = jnp.einsum("ec,c,c...->e...", mem, wm, df)   # (E, ...)
            e_den = mem @ wm                                     # (E,)
        else:
            e_num = jnp.einsum("ec,cm,cm...->em...", mem, wm, df)
            e_den = jnp.einsum("ec,cm->em", mem, wm)
        # stage 2: hub combine of the edge partial aggregates
        num = e_num.sum(axis=0)
        denom = e_den.sum(axis=0)
        denom_b = jnp.reshape(denom, jnp.shape(denom) +
                              (1,) * (num.ndim - jnp.ndim(denom)))
        upd = jnp.where(denom_b > 0, num / jnp.maximum(denom_b, 1e-9), 0.0)
        return (g.astype(jnp.float32) + upd).astype(g.dtype)

    from .masking import _is_leafunit
    return jax.tree_util.tree_map(one, assign.leaf_units, global_params,
                                  deltas, is_leaf=_is_leafunit)
