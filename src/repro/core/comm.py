"""Network-transfer accounting (paper Table 4 / §4.2.5).

Byte counts are exact functions of the unit assignment and the selection
matrix — no simulation noise.  Two topologies:

* **hub** (the paper's FEDn combiner): per round,
    uplink_c   = Σ_u sel_cu · unit_bytes_u      (only trained layers ship)
    downlink_c = full model                     (server broadcasts globals)
  The paper's Table 4 reports the 10-client uplink sum.

* **collective** (pod FL, DESIGN.md §2): aggregation is an all-reduce
  over the client axis.  With *independent* per-client selection (paper
  semantics) every unit has ≥1 participant w.h.p., so the collective
  still moves the full model; with *synchronized* selection the reduce
  covers only the round's selected units — bytes shrink by exactly the
  frozen fraction.  This is the beyond-paper optimization measured in
  EXPERIMENTS.md §Perf (collective term).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .masking import UnitAssignment, unit_param_counts


def unit_bytes(assign: UnitAssignment, params, bytes_per_param: int = 4
               ) -> np.ndarray:
    return unit_param_counts(assign, params) * bytes_per_param


def hub_round_bytes(sel: np.ndarray, ubytes: np.ndarray,
                    include_downlink: bool = False) -> Dict[str, float]:
    """sel (C, U) 0/1 for one round."""
    sel = np.asarray(sel)
    uplink = float((sel @ ubytes).sum())
    total_model = float(ubytes.sum())
    downlink = total_model * sel.shape[0]
    out = {"uplink": uplink,
           "uplink_frac": uplink / (total_model * sel.shape[0]),
           "downlink": downlink}
    out["total"] = uplink + (downlink if include_downlink else 0.0)
    return out


def collective_round_bytes(sel: np.ndarray, ubytes: np.ndarray,
                           n_devices_per_client: int = 1
                           ) -> Dict[str, float]:
    """Bytes crossing the client-axis all-reduce boundary per round.

    A unit participates in the reduce iff ANY client selected it
    (independent selection -> almost all units; synchronized -> exactly
    the selected subset).  Ring all-reduce moves ~2x the payload.
    """
    sel = np.asarray(sel)
    active = sel.max(axis=0) > 0
    payload = float(ubytes[active].sum())
    return {"payload": payload,
            "ring_bytes": 2.0 * payload,
            "active_units": int(active.sum()),
            "frac_of_full": payload / float(ubytes.sum())}


def expected_uplink_fraction(n_units: int, n_train: int) -> float:
    """E[selected bytes]/total under uniform selection = n_train/U
    (unit sizes cancel in expectation)."""
    return n_train / n_units


def table4_row(assign: UnitAssignment, params, sel_history,
               bytes_per_param: int = 4) -> Dict[str, float]:
    """Reproduce one Table 4 cell from a run's selection history.

    sel_history: (rounds, C, U).  Returns average per-round uplink bytes
    and trained-parameter count across the history.
    """
    ub = unit_bytes(assign, params, bytes_per_param)
    counts = unit_param_counts(assign, params)
    hist = np.asarray(sel_history)
    per_round_bytes = np.einsum("rcu,u->r", hist, ub)
    per_round_params = np.einsum("rcu,u->r", hist, counts)
    return {
        "avg_uplink_bytes": float(per_round_bytes.mean()),
        "avg_trained_params": float(per_round_params.mean()),
        "total_uplink_bytes": float(per_round_bytes.sum()),
        "reduction_vs_full": 1.0 - float(per_round_bytes.mean()) /
        (float(ub.sum()) * hist.shape[1]),
    }
