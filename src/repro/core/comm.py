"""Network-transfer accounting (paper Table 4 / §4.2.5).

Byte counts are exact functions of the unit assignment and the selection
matrix — no simulation noise.  One function per topology (the plugin
layer in ``core/topology.py`` routes ``CommAccounting``/``comm_summary``
through these):

* **hub** (the paper's FEDn combiner): per round,
    uplink_c   = Σ_u sel_cu · unit_bytes_u      (only trained layers ship)
    downlink_c = full model                     (server broadcasts globals)
  The paper's Table 4 reports the 10-client uplink sum.  With
  ``downlink="selected"`` the server broadcasts only the units the round
  updated (exact, not approximate: aggregation changes *only* units
  somebody trained, so re-broadcasting the round's selection union keeps
  every client's copy of the global model current) — under synchronized
  selection that union is the shared subset, matching the collective-
  shrinking story instead of always charging the full model.

* **hierarchical** (edge aggregators -> hub): clients upload selected
  units to their edge aggregator (LAN); each edge forwards ONE partial
  aggregate per unit any of its clients trained (the per-edge selection
  union) over the WAN to the hub.  The edge->hub term is the paper's
  WAN bottleneck and is what ``uplink`` reports.

* **gossip** (hubless peer averaging): each client ships its replica to
  its out-neighbours in the mixing graph every round.  Mixing blends
  every entry of a replica, so partial-freezing does NOT shrink gossip
  traffic — the accounting makes that cost visible.

* **buffered** (semi-async rounds, DESIGN.md §8): per *flush* rather
  than per synchronous round.  ``buffered_hub_round_bytes`` bills one
  packed upload per buffered update; ``buffered_hierarchical_round_
  bytes`` bills client->edge LAN per update but edge->hub WAN only at
  flush time — one partial aggregate per unit in the edge's buffered
  union, i.e. only flushed deltas cross the WAN.

* **collective** (pod FL, DESIGN.md §2): aggregation is an all-reduce
  over the client axis.  With *independent* per-client selection (paper
  semantics) every unit has ≥1 participant w.h.p., so the collective
  still moves the full model; with *synchronized* selection the reduce
  covers only the round's selected units — bytes shrink by exactly the
  frozen fraction.  This is the beyond-paper optimization measured in
  EXPERIMENTS.md §Perf (collective term).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .masking import UnitAssignment, unit_param_counts


def unit_bytes(assign: UnitAssignment, params, bytes_per_param: int = 4
               ) -> np.ndarray:
    return unit_param_counts(assign, params) * bytes_per_param


def _safe_frac(num: float, denom: float) -> float:
    """Uplink fraction with the degenerate-round guard: a round where
    nothing could have shipped (zero effective clients/edges or an
    empty model) is a 0.0-fraction round, not a ZeroDivision/NaN."""
    return num / denom if denom > 0 else 0.0


def hub_round_bytes(sel: np.ndarray, ubytes: np.ndarray,
                    include_downlink: bool = False,
                    downlink: str = "full") -> Dict[str, float]:
    """sel (C, U) 0/1 for one round.

    ``downlink="full"``: the server broadcasts the whole model to every
    client (the paper's FEDn behaviour).  ``downlink="selected"``: the
    server broadcasts only the units the round's aggregation touched —
    the per-round selection union — which is sufficient to keep every
    client's global copy exact (frozen units never change server-side).
    Under synchronized selection the union equals the shared subset, so
    downlink shrinks by the same frozen fraction as uplink.
    """
    sel = np.asarray(sel)
    uplink = float((sel @ ubytes).sum())
    total_model = float(ubytes.sum())
    if downlink == "full":
        down = total_model * sel.shape[0]
    elif downlink == "selected":
        union = sel.max(axis=0) if sel.shape[0] else np.zeros(sel.shape[1])
        down = float(union @ ubytes) * sel.shape[0]
    else:
        raise ValueError(f"downlink must be 'full' or 'selected', "
                         f"got {downlink!r}")
    out = {"uplink": uplink,
           "uplink_frac": _safe_frac(uplink, total_model * sel.shape[0]),
           "downlink": down}
    out["total"] = uplink + (down if include_downlink else 0.0)
    return out


def edge_membership(n_clients: int, n_edges: int) -> np.ndarray:
    """(E, C) 0/1 — contiguous near-equal client groups per edge."""
    if not 1 <= n_edges <= n_clients:
        raise ValueError(f"n_edges={n_edges} out of range for "
                         f"{n_clients} clients")
    mem = np.zeros((n_edges, n_clients), np.float32)
    for e, grp in enumerate(np.array_split(np.arange(n_clients), n_edges)):
        mem[e, grp] = 1.0
    return mem


def hierarchical_round_bytes(sel: np.ndarray, ubytes: np.ndarray,
                             membership: np.ndarray,
                             include_downlink: bool = False,
                             downlink: str = "full") -> Dict[str, float]:
    """Two-stage accounting: client->edge (LAN) and edge->hub (WAN).

    Each edge uploads one partial aggregate per unit in its selection
    *union* — a unit trained by several of the edge's clients crosses
    the WAN once, which is where hierarchical beats the flat hub.
    ``uplink`` is the WAN (edge->hub) term.
    """
    sel = np.asarray(sel)
    membership = np.asarray(membership)
    n_edges, n_clients = membership.shape
    total_model = float(ubytes.sum())
    client_edge = float((sel @ ubytes).sum())
    # per-edge selection union: (E, U)
    union = (membership @ sel > 0).astype(np.float64)
    edge_hub = float((union @ ubytes).sum())
    if downlink == "full":
        down = total_model * (n_edges + n_clients)
    elif downlink == "selected":
        gu = sel.max(axis=0) if sel.shape[0] else np.zeros(sel.shape[1])
        down = float(gu @ ubytes) * (n_edges + n_clients)
    else:
        raise ValueError(f"downlink must be 'full' or 'selected', "
                         f"got {downlink!r}")
    out = {"uplink": edge_hub,
           "uplink_frac": _safe_frac(edge_hub, total_model * n_edges),
           "edge_hub_uplink": edge_hub,
           "client_edge_uplink": client_edge,
           "downlink": down}
    out["total"] = edge_hub + client_edge + (down if include_downlink
                                             else 0.0)
    return out


def buffered_hub_round_bytes(entry_sel: np.ndarray, ubytes: np.ndarray,
                             include_downlink: bool = False,
                             downlink: str = "full") -> Dict[str, float]:
    """Per-flush accounting for semi-async buffered rounds on the hub.

    ``entry_sel (B, U)`` has one row per *buffered update* in the flush
    (a client appears once per contributed update, not once per round).
    Each update crossed the client->hub WAN when its client reported
    back, carrying only its packed trained slots; each completing
    client then re-pulls the current global model, so downlink is one
    model per entry (``"selected"``: only the flush's selection union —
    aggregation changed nothing else).
    """
    entry_sel = np.asarray(entry_sel)
    # per-entry math is the hub round formula with entries as the
    # leading axis (a client appears once per buffered update)
    out = hub_round_bytes(entry_sel, ubytes, include_downlink, downlink)
    out["n_entries"] = float(entry_sel.shape[0])
    return out


def buffered_hierarchical_round_bytes(entry_sel: np.ndarray,
                                      client_ids: np.ndarray,
                                      ubytes: np.ndarray,
                                      membership: np.ndarray,
                                      include_downlink: bool = False,
                                      downlink: str = "full"
                                      ) -> Dict[str, float]:
    """Per-flush accounting for buffered rounds under edge aggregators.

    Clients stream their packed updates to their edge over the LAN as
    they complete; the edge *buffers* them and, at flush time, forwards
    ONE partial aggregate per unit in its buffered selection union —
    only flushed deltas ever cross the edge->hub WAN (``uplink``), so a
    unit trained by several buffered updates of one edge crosses once.
    """
    entry_sel = np.asarray(entry_sel)
    client_ids = np.asarray(client_ids, np.int64)
    membership = np.asarray(membership)
    n_edges = membership.shape[0]
    n_entries = entry_sel.shape[0]
    total_model = float(ubytes.sum())
    client_edge = float((entry_sel @ ubytes).sum())
    entry_mem = membership[:, client_ids] if n_entries \
        else np.zeros((n_edges, 0), membership.dtype)        # (E, B)
    union = (entry_mem @ entry_sel > 0).astype(np.float64)   # (E, U)
    edge_hub = float((union @ ubytes).sum())
    if downlink == "full":
        down = total_model * (n_edges + n_entries)
    elif downlink == "selected":
        gu = entry_sel.max(axis=0) if n_entries \
            else np.zeros(entry_sel.shape[1])
        down = float(gu @ ubytes) * (n_edges + n_entries)
    else:
        raise ValueError(f"downlink must be 'full' or 'selected', "
                         f"got {downlink!r}")
    out = {"uplink": edge_hub,
           "uplink_frac": _safe_frac(edge_hub, total_model * n_edges),
           "edge_hub_uplink": edge_hub,
           "client_edge_uplink": client_edge,
           "n_entries": float(n_entries),
           "downlink": down}
    out["total"] = edge_hub + client_edge + (down if include_downlink
                                             else 0.0)
    return out


def gossip_round_bytes(sel: np.ndarray, ubytes: np.ndarray,
                       degree: Optional[int] = None) -> Dict[str, float]:
    """Peer-exchange accounting for one gossip round.

    Every client ships its FULL replica to each of its ``degree``
    out-neighbours (ring default: 2, capped by C-1); the mixing step
    blends all entries of a replica, so selection cannot shrink the
    payload — ``uplink_frac`` is 1 by construction and ``sel`` only
    informs ``trained_params`` elsewhere.
    """
    sel = np.asarray(sel)
    n_clients = sel.shape[0]
    if degree is None:
        degree = min(2, max(n_clients - 1, 0))
    total_model = float(ubytes.sum())
    payload = total_model * n_clients * degree
    return {"uplink": payload,
            "uplink_frac": 1.0 if n_clients > 1 else 0.0,
            "peer_bytes": payload,
            "degree": float(degree),
            "downlink": 0.0,
            "total": payload}


def collective_round_bytes(sel: np.ndarray, ubytes: np.ndarray,
                           n_devices_per_client: int = 1
                           ) -> Dict[str, float]:
    """Bytes crossing the client-axis all-reduce boundary per round.

    A unit participates in the reduce iff ANY client selected it
    (independent selection -> almost all units; synchronized -> exactly
    the selected subset).  Ring all-reduce moves ~2x the payload.
    """
    sel = np.asarray(sel)
    active = sel.max(axis=0) > 0
    payload = float(ubytes[active].sum())
    return {"payload": payload,
            "ring_bytes": 2.0 * payload,
            "active_units": int(active.sum()),
            "frac_of_full": payload / float(ubytes.sum())}


def expected_uplink_fraction(n_units: int, n_train: int) -> float:
    """E[selected bytes]/total under uniform selection = n_train/U
    (unit sizes cancel in expectation)."""
    return n_train / n_units


def table4_row(assign: UnitAssignment, params, sel_history,
               bytes_per_param: int = 4,
               wire_ubytes=None) -> Dict[str, float]:
    """Reproduce one Table 4 cell from a run's selection history.

    sel_history: (rounds, C, U).  Returns average per-round uplink bytes
    and trained-parameter count across the history.  ``wire_ubytes``
    (codec-encoded per-unit bytes, core/codecs.py) rebills the uplink
    terms at wire width while ``reduction_vs_full`` keeps the fp32
    full-model denominator, so the reduction composes structural freeze
    × codec compression.
    """
    ub = unit_bytes(assign, params, bytes_per_param)
    counts = unit_param_counts(assign, params)
    hist = np.asarray(sel_history)
    per_round_bytes = np.einsum(
        "rcu,u->r", hist, ub if wire_ubytes is None else wire_ubytes)
    per_round_params = np.einsum("rcu,u->r", hist, counts)
    return {
        "avg_uplink_bytes": float(per_round_bytes.mean()),
        "avg_trained_params": float(per_round_params.mean()),
        "total_uplink_bytes": float(per_round_bytes.sum()),
        "reduction_vs_full": 1.0 - float(per_round_bytes.mean()) /
        (float(ub.sum()) * hist.shape[1]),
    }
