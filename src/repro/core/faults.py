"""Seeded fault injection — the chaos axis (DESIGN.md §14).

Everything upstream of this module assumes benign failures: the
``DelayScheduler`` only reorders updates and ``StragglerDropout`` only
drops them cleanly.  Real edge fleets crash mid-round, ship corrupted
deltas, deliver duplicates and torn payloads, and lose the *server*
between checkpoint and flush.  This module makes every one of those an
injectable, deterministic event:

* a fault registry (``@register_fault``), symmetric with the strategy /
  topology / staleness / client-sampler registries, keyed by name with
  the shared unknown-name error contract;
* a :class:`FaultInjector` whose every draw is a pure function of
  ``(seed, tag, coordinates)`` — the stateless ``SeedSequence`` idiom of
  ``DelayScheduler`` — so fault schedules replay bit-exactly across
  restarts and never touch the server's jax key stream;
* :func:`chaos_inject`, the compiled corruption transform applied to
  packed deltas inside the round step (mode 0 is a bitwise identity, so
  a zero-rate chaos config stays bitwise-equal to the plain round);
* :class:`ChaosHook` + :func:`run_with_restarts`, the crash-restart
  harness: a seeded kill between ``on_round_end`` hooks plus an
  auto-resume loop proving kill-at-any-boundary + restore reproduces
  the uninterrupted fit bit-exactly.

Fault seams
-----------
``crash``     client crash mid-cohort-chunk — the update never arrives
              (sync: weight zeroed before the step; cohort: the client
              is resampled with bounded backoff; async: the in-flight
              update is discarded and the client re-dispatched).
``delta``     delta corruption on the wire: ``nan``, ``inf``,
              ``bitflip`` (exponent-bit flip), ``scale`` (magnitude
              blow-up, param = factor).
``delivery``  ``duplicate`` (same update pushed twice into the
              ``BufferedAggregator``) and ``torn`` (NaN tail — a
              partially-received payload).
``server``    ``kill`` — raises :class:`ServerKilled` between
              ``on_round_end`` hooks (after the ``Checkpointer``).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, FrozenSet, Iterable, Optional, Sequence, Tuple, \
    Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..common.retry import Backoff, retry_call
from .registry import unknown_name_message

# draw-domain tags: every stochastic decision hashes (seed, tag, coords)
# into its own numpy generator, so adding a new fault kind never shifts
# the draws of an existing one (same contract as DelayScheduler)
_TAG_CRASH = 0xFA001      # sync/cohort crash, coords (round, client)
_TAG_DELTA = 0xFA002      # sync/cohort corruption, coords (round, client)
_TAG_ADELTA = 0xFA003     # async corruption, coords (client, seq)
_TAG_DUP = 0xFA004        # duplicate delivery, coords (client, seq)
_TAG_TORN = 0xFA005       # torn delivery, coords (client, seq)
_TAG_KILL = 0xFA006       # server kill, coords (incarnation, round)
_TAG_RESAMPLE = 0xFA007   # crash resampling, coords (round, pos, attempt)
_TAG_ACRASH = 0xFA008     # async crash, coords (client, seq)

# delta corruption modes (the int32 plan fed to chaos_inject)
MODE_NONE, MODE_NAN, MODE_INF, MODE_BITFLIP, MODE_SCALE = 0, 1, 2, 3, 4


class ServerKilled(RuntimeError):
    """Injected server death (the ``kill`` fault).  Raised between
    ``on_round_end`` hooks; :func:`run_with_restarts` catches it and
    resumes from the last checkpoint."""


class ClientCrashed(RuntimeError):
    """A (re)sampled client crashed; retried via ``common/retry.py``."""


class Fault:
    """One registered fault kind.  Instances carry the per-run
    probability (and optional parameter); the class carries identity:
    ``name``, ``seam`` (crash | delta | delivery | server) and, for
    delta faults, the corruption ``mode`` code."""

    name: str = ""
    seam: str = ""
    mode: int = MODE_NONE
    default_param: float = 1.0

    def __init__(self, prob: float = 0.0, param: Optional[float] = None):
        prob = float(prob)
        if not 0.0 <= prob <= 1.0:
            raise ValueError(
                f"fault {self.name!r} probability must be in [0, 1], "
                f"got {prob}")
        self.prob = prob
        self.param = float(self.default_param if param is None else param)

    def __repr__(self):
        return f"{type(self).__name__}(prob={self.prob}, param={self.param})"


_FAULTS: Dict[str, Type[Fault]] = {}


class UnknownFaultError(ValueError):
    pass


def register_fault(cls: Type[Fault]):
    """Class decorator: register a fault kind by its ``name``."""
    if not cls.name:
        raise ValueError(f"fault class {cls!r} has no name")
    _FAULTS[cls.name] = cls
    return cls


def unregister_fault(name: str):
    _FAULTS.pop(name, None)


def registered_faults() -> Tuple[str, ...]:
    return tuple(sorted(_FAULTS))


def get_fault(name: str) -> Type[Fault]:
    try:
        return _FAULTS[name]
    except KeyError:
        raise UnknownFaultError(
            unknown_name_message("fault", name, _FAULTS)) from None


@register_fault
class CrashFault(Fault):
    """Client crashes before uploading: its weight drops to zero and
    the cohort engine resamples a replacement (crash seam)."""
    name, seam = "crash", "crash"


@register_fault
class NanFault(Fault):
    """Uploaded delta poisoned with NaNs (delta seam) — the validation
    gate must quarantine it before aggregation."""
    name, seam, mode = "nan", "delta", MODE_NAN


@register_fault
class InfFault(Fault):
    """Uploaded delta poisoned with Infs (delta seam)."""
    name, seam, mode = "inf", "delta", MODE_INF


@register_fault
class BitflipFault(Fault):
    """Sign-bit corruption of the uploaded delta (delta seam) — a
    finite-but-wrong update the norm gate has to catch."""
    name, seam, mode = "bitflip", "delta", MODE_BITFLIP


@register_fault
class ScaleFault(Fault):
    """Delta scaled by ``param`` (default 1024x, delta seam) — the
    classic exploding-update client."""
    name, seam, mode = "scale", "delta", MODE_SCALE
    default_param = 1024.0


@register_fault
class DuplicateFault(Fault):
    """Upload delivered twice (delivery seam) — the buffer's per-client
    seq watermark must reject the redelivery."""
    name, seam = "duplicate", "delivery"


@register_fault
class TornFault(Fault):
    """Upload lost in transit after leaving the client (delivery seam):
    billed bytes, no aggregate contribution."""
    name, seam = "torn", "delivery"


@register_fault
class KillFault(Fault):
    """Server process killed between rounds (server seam) — the chaos
    harness restarts from the last checkpoint."""
    name, seam = "kill", "server"


def parse_faults(spec: Union[str, Sequence[Fault], None]
                 ) -> Tuple[Fault, ...]:
    """``"crash:0.1,nan:0.05,scale:0.02:1e3"`` -> fault instances.

    Each entry is ``name:prob`` or ``name:prob:param``; already-built
    instances pass through.  A typo'd name fails with the registry's
    uniform unknown-name message."""
    if not spec:
        return ()
    if not isinstance(spec, str):
        out = tuple(spec)
        for f in out:
            if not isinstance(f, Fault):
                raise TypeError(f"expected Fault instances, got {f!r}")
        return out
    out = []
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        parts = entry.split(":")
        if len(parts) not in (2, 3):
            raise ValueError(
                f"bad fault spec {entry!r}: expected name:prob or "
                f"name:prob:param")
        cls = get_fault(parts[0].strip())
        try:
            prob = float(parts[1])
            param = float(parts[2]) if len(parts) == 3 else None
        except ValueError:
            raise ValueError(
                f"bad fault spec {entry!r}: prob/param must be numbers"
            ) from None
        out.append(cls(prob, param))
    return tuple(out)


def delta_faults(faults: Iterable[Fault]) -> Tuple[Fault, ...]:
    return tuple(f for f in faults if f.seam == "delta")


def delta_faults_configured(fl) -> bool:
    """True when the config names any delta fault — even at rate 0.
    The injection transform is then compiled into the round step (a
    bitwise identity at mode 0), so zero-rate and live chaos configs
    share one traced graph."""
    return bool(delta_faults(parse_faults(getattr(fl, "faults", ""))))


def gate_enabled(fl) -> bool:
    """Whether the packed-delta validation gate is compiled in: any
    fault that can corrupt payload bytes configured (delta faults, or
    torn delivery — both even at zero rate, since the untripped gate is
    a bitwise no-op) or an explicit norm threshold."""
    faults = parse_faults(getattr(fl, "faults", ""))
    return bool(delta_faults(faults)) \
        or any(f.name == "torn" for f in faults) \
        or getattr(fl, "max_delta_norm", 0.0) > 0.0


def _rng(seed: int, tag: int, *coords: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence(
        (int(seed), int(tag)) + tuple(int(c) for c in coords)))


class FaultInjector:
    """All fault draws for one run.  Stateless: every decision is a
    pure function of ``(seed, tag, coordinates)`` (plus ``incarnation``
    for the kill fault, so a restarted server doesn't deterministically
    re-die at the round that killed it)."""

    def __init__(self, faults: Union[str, Sequence[Fault], None],
                 seed: int = 0, incarnation: int = 0):
        self.faults = parse_faults(faults)
        self.seed = int(seed)
        self.incarnation = int(incarnation)
        self._delta = delta_faults(self.faults)

    def _prob(self, name: str) -> float:
        return min(1.0, sum(f.prob for f in self.faults
                            if f.name == name))

    @property
    def crash_prob(self) -> float:
        return self._prob("crash")

    @property
    def kill_prob(self) -> float:
        return self._prob("kill")

    @property
    def duplicate_prob(self) -> float:
        return self._prob("duplicate")

    @property
    def torn_prob(self) -> float:
        return self._prob("torn")

    @property
    def has_delta(self) -> bool:
        """Any delta fault *configured* (zero-rate counts: the plan is
        still threaded so the traced round step is identical)."""
        return bool(self._delta)

    # -- client crash ---------------------------------------------------
    def crashed(self, round_idx: int, client: int) -> bool:
        p = self.crash_prob
        return p > 0.0 and float(
            _rng(self.seed, _TAG_CRASH, round_idx, client).random()) < p

    def crash_mask(self, round_idx: int, clients: Sequence[int]
                   ) -> np.ndarray:
        return np.array([self.crashed(round_idx, int(c)) for c in clients])

    def crashed_async(self, client: int, seq: int) -> bool:
        p = self.crash_prob
        return p > 0.0 and float(
            _rng(self.seed, _TAG_ACRASH, client, seq).random()) < p

    def resample(self, round_idx: int, pos: int, attempt: int,
                 n_registered: int,
                 exclude: FrozenSet[int]) -> Optional[int]:
        """Replacement candidate for a crashed cohort slot, or None when
        the whole registered fleet is already in the cohort."""
        cands = [c for c in range(int(n_registered)) if c not in exclude]
        if not cands:
            return None
        rng = _rng(self.seed, _TAG_RESAMPLE, round_idx, pos, attempt)
        return int(cands[int(rng.integers(len(cands)))])

    # -- delta corruption -----------------------------------------------
    def _draw_modes(self, tag: int, a: int, b: int
                    ) -> Tuple[int, float]:
        rng = _rng(self.seed, tag, a, b)
        # first configured fault that fires wins (spec order); each
        # draws independently so per-fault rates are marginal rates
        for f in self._delta:
            if f.prob > 0.0 and float(rng.random()) < f.prob:
                return f.mode, f.param
        return MODE_NONE, 1.0

    def corrupt_plan(self, round_idx: int, clients: Sequence[int]
                     ) -> Dict[str, np.ndarray]:
        """The round's per-client corruption plan: ``mode`` (C,) int32
        codes (0 = clean) and ``scale`` (C,) f32 factors, fed to
        :func:`chaos_inject` inside the compiled round step."""
        modes, scales = [], []
        for c in clients:
            m, s = self._draw_modes(_TAG_DELTA, round_idx, int(c))
            modes.append(m)
            scales.append(s)
        return {"mode": np.asarray(modes, np.int32),
                "scale": np.asarray(scales, np.float32)}

    def corrupt_async(self, client: int, seq: int) -> Tuple[int, float]:
        return self._draw_modes(_TAG_ADELTA, client, seq)

    # -- delivery -------------------------------------------------------
    def duplicated(self, client: int, seq: int) -> bool:
        p = self.duplicate_prob
        return p > 0.0 and float(
            _rng(self.seed, _TAG_DUP, client, seq).random()) < p

    def torn(self, client: int, seq: int) -> bool:
        p = self.torn_prob
        return p > 0.0 and float(
            _rng(self.seed, _TAG_TORN, client, seq).random()) < p

    def perturb_update(self, upd):
        """Apply async-path delta corruption + torn delivery to a
        ``BufferedUpdate`` (host-side: the update is already off the
        compiled path when it sits in the buffer).  Clean draws return
        the update object unchanged — bitwise no-op."""
        mode, scale = self.corrupt_async(upd.client, upd.seq)
        is_torn = self.torn(upd.client, upd.seq)
        if mode == MODE_NONE and not is_torn:
            return upd

        def leaf(x):
            a = np.array(x)                      # owned copy
            if not np.issubdtype(a.dtype, np.floating):
                return x
            if mode == MODE_NAN:
                a[...] = np.nan
            elif mode == MODE_INF:
                a[...] = np.inf
            elif mode == MODE_BITFLIP:
                if a.dtype == np.float32:
                    a = (a.view(np.int32) ^ np.int32(1 << 30)) \
                        .view(np.float32)
                else:
                    a = a * a.dtype.type(2.0 ** 40)
            elif mode == MODE_SCALE:
                a = a * a.dtype.type(scale)
            if is_torn and a.ndim >= 1 and a.shape[0] > 1:
                # payload cut off mid-transfer: the tail rows never
                # arrived — NaN marks "no data", the validation gate
                # quarantines the whole entry
                a[a.shape[0] // 2:] = np.nan
            return jnp.asarray(a)

        return dataclasses.replace(
            upd, pdelta=jax.tree_util.tree_map(leaf, upd.pdelta))

    # -- server kill ----------------------------------------------------
    def kill(self, round_idx: int) -> bool:
        p = self.kill_prob
        return p > 0.0 and float(_rng(self.seed, _TAG_KILL,
                                      self.incarnation,
                                      round_idx).random()) < p


def _bitflip_leaf(d: jnp.ndarray) -> jnp.ndarray:
    """Flip the high exponent bit of every element — a deterministic
    stand-in for radiation/transport bit errors that keeps values
    finite (so only the *norm* gate catches it, unlike nan/inf)."""
    if d.dtype == jnp.float32:
        bits = jax.lax.bitcast_convert_type(d, jnp.int32)
        return jax.lax.bitcast_convert_type(bits ^ jnp.int32(1 << 30),
                                            jnp.float32)
    if d.dtype in (jnp.dtype(jnp.float16), jnp.dtype(jnp.bfloat16)):
        bits = jax.lax.bitcast_convert_type(d, jnp.int16)
        return jax.lax.bitcast_convert_type(bits ^ jnp.int16(1 << 13),
                                            d.dtype)
    return d * jnp.asarray(2.0 ** 40, d.dtype)


def chaos_inject(pdeltas, mode, scale):
    """Apply the per-client corruption plan to packed deltas inside the
    compiled round step.  Every leaf has a leading client axis; mode 0
    selects the original value through ``jnp.where``, which is a
    bitwise identity — a zero-rate chaos run compiles this in and still
    matches the plain round bit-for-bit."""
    mode = jnp.asarray(mode, jnp.int32)
    scale = jnp.asarray(scale, jnp.float32)

    def leaf(d):
        if not jnp.issubdtype(d.dtype, jnp.floating):
            return d
        m = mode.reshape(mode.shape + (1,) * (d.ndim - 1))
        s = scale.reshape(scale.shape + (1,) * (d.ndim - 1)).astype(d.dtype)
        out = jnp.where(m == MODE_NAN, jnp.asarray(jnp.nan, d.dtype), d)
        out = jnp.where(m == MODE_INF, jnp.asarray(jnp.inf, d.dtype), out)
        out = jnp.where(m == MODE_BITFLIP, _bitflip_leaf(d), out)
        out = jnp.where(m == MODE_SCALE, d * s, out)
        return out

    return jax.tree_util.tree_map(leaf, pdeltas)


class ChaosHook:
    """The fault axis's server hook (duck-typed — hooks are any object
    with the three ``ServerHook`` methods).  Appended *after* user
    hooks by the Federation facade so an injected kill fires after the
    ``Checkpointer`` saved: the kill lands *between* ``on_round_end``
    hooks, the hardest restart boundary."""

    def __init__(self, injector: FaultInjector):
        self.injector = injector

    def on_round_start(self, server, round_idx, weights):
        # sync-path crash: the client's update never arrives -> weight
        # 0 before the compiled step.  The cohort and async engines own
        # their richer crash handling (resample / re-dispatch), so this
        # hook stands down there; at rate 0 it must not draw at all
        # (bit-exactness contract, same as StragglerDropout)
        inj = self.injector
        if inj.crash_prob <= 0.0 \
                or getattr(server, "cohort_engine", None) is not None \
                or getattr(server, "async_engine", None) is not None:
            return None
        keep = ~inj.crash_mask(round_idx, range(int(weights.shape[0])))
        return weights * jnp.asarray(keep, jnp.float32)

    def on_round_end(self, server, record, metrics):
        if self.injector.kill(record.round):
            raise ServerKilled(
                f"injected server kill after round {record.round} "
                f"(incarnation {self.injector.incarnation})")

    def on_fit_end(self, server, history):
        pass


def run_with_restarts(make_federation, rounds: int, ckpt_path: str, *,
                      max_restarts: int = 50):
    """Crash-restart harness: run ``rounds`` rounds to completion,
    rebuilding + resuming from ``ckpt_path`` every time the injected
    kill fires.  ``make_federation(incarnation)`` must return a fresh
    ``Federation``; the incarnation number feeds the kill draw so a
    restarted server doesn't re-die deterministically at the same
    boundary.  Returns the completed federation."""
    from ..ckpt.store import _manifest_path
    inc = 0
    while True:
        fed = make_federation(inc)
        if os.path.exists(_manifest_path(ckpt_path)):
            fed.restore(ckpt_path)
        done = fed.server.history[-1].round + 1 if fed.server.history \
            else 0
        if done >= rounds:
            return fed
        try:
            fed.fit(rounds - done)
            return fed
        except ServerKilled:
            inc += 1
            if inc > max_restarts:
                raise
