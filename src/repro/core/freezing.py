"""Layer-selection strategies (the paper's core mechanism, Alg. 2 line 3).

Every strategy returns a 0/1 selection over freeze units, traced-friendly
so the whole federated round compiles as one ``jit``.  The paper uses
per-client independent uniform random selection; we add:

  * ``synchronized``  — all clients of a round share the subset (seeded by
    the round id).  Beyond-paper: lets the cross-client collective shrink
    (frozen units never hit the ICI/DCN link) — see core/comm.py and
    EXPERIMENTS.md §Perf.
  * ``fixed_last``    — transfer-learning baseline (train the last k units).
  * ``weighted``      — selection probability proportional to provided
    per-unit scores (e.g. gradient norms; the paper's "future work").

``n_train`` is static (the paper keeps it fixed over training), so masks
have static sparsity and the comm accounting is exact.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def select_uniform(key, n_units: int, n_train: int) -> jnp.ndarray:
    """(U,) 0/1 — exactly n_train randomly chosen units."""
    perm = jax.random.permutation(key, n_units)
    return (perm < n_train).astype(jnp.float32)


def select_fixed_last(n_units: int, n_train: int) -> jnp.ndarray:
    return (jnp.arange(n_units) >= n_units - n_train).astype(jnp.float32)


def select_weighted(key, n_units: int, n_train: int,
                    scores: jnp.ndarray) -> jnp.ndarray:
    """Top-n_train by perturbed score (Gumbel top-k sampling ∝ softmax(scores))."""
    g = jax.random.gumbel(key, (n_units,))
    ranked = jnp.argsort(-(scores + g))
    sel = jnp.zeros(n_units).at[ranked[:n_train]].set(1.0)
    return sel


def select_clients(key, n_clients: int, n_units: int, n_train: int, *,
                   strategy: str = "uniform", synchronized: bool = False,
                   scores: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(C, U) 0/1 selection matrix for one round.

    ``synchronized=True`` gives every client the same subset (one key);
    otherwise each client folds its index into the round key (paper
    semantics: independent per-client selection).
    """
    if strategy == "full":
        return jnp.ones((n_clients, n_units), jnp.float32)
    if strategy == "fixed_last":
        row = select_fixed_last(n_units, n_train)
        return jnp.broadcast_to(row, (n_clients, n_units))

    def one(k):
        if strategy == "uniform":
            return select_uniform(k, n_units, n_train)
        if strategy == "weighted":
            return select_weighted(k, n_units, n_train, scores)
        raise ValueError(f"unknown strategy {strategy!r}")

    if synchronized:
        row = one(key)
        return jnp.broadcast_to(row, (n_clients, n_units))
    keys = jax.random.split(key, n_clients)
    return jax.vmap(one)(keys)


def n_train_from_fraction(n_units: int, fraction: float) -> int:
    """The paper's 25%/50%/75% settings -> unit counts (at least 1)."""
    return max(1, round(n_units * fraction))
