"""Layer-selection helpers (paper Alg. 2 line 3) — thin wrappers.

The actual strategies live in ``core/strategies.py`` as registered
plugins (``uniform``, ``fixed_last``, ``weighted`` (deprecated),
``full``, ``synchronized``, plus the scored family ``score_weighted`` /
``depth_dropout`` / ``successive`` — DESIGN.md §11); this module keeps
the original functional API for call sites and notebooks that think in
terms of one selection draw.

Every function returns a 0/1 selection over freeze units, traced-
friendly so the whole federated round compiles as one ``jit``.
``n_train`` is static (the paper keeps it fixed over training), so
masks have static sparsity and the comm accounting is exact.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .strategies import (SelectionContext, get_strategy, resolve_strategy)


def _ctx(n_clients: int, n_units: int, n_train: int,
         scores: Optional[jnp.ndarray] = None) -> SelectionContext:
    return SelectionContext(n_clients=n_clients, n_units=n_units,
                            n_train=n_train, scores=scores)


def select_uniform(key, n_units: int, n_train: int) -> jnp.ndarray:
    """(U,) 0/1 — exactly n_train randomly chosen units."""
    return get_strategy("uniform").select_row(
        key, _ctx(1, n_units, n_train))


def select_fixed_last(n_units: int, n_train: int) -> jnp.ndarray:
    return get_strategy("fixed_last").select_row(
        None, _ctx(1, n_units, n_train))


def select_weighted(key, n_units: int, n_train: int,
                    scores: jnp.ndarray) -> jnp.ndarray:
    """Top-n_train by perturbed score (Gumbel top-k ∝ softmax(scores))."""
    return get_strategy("weighted").select_row(
        key, _ctx(1, n_units, n_train, scores))


def select_clients(key, n_clients: int, n_units: int, n_train: int, *,
                   strategy: str = "uniform", synchronized: bool = False,
                   scores: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(C, U) 0/1 selection matrix for one round.

    ``synchronized=True`` gives every client the same subset (one key);
    otherwise each client folds its index into the round key (paper
    semantics: independent per-client selection).
    """
    strat = resolve_strategy(strategy, synchronized)
    return strat.select(key, _ctx(n_clients, n_units, n_train, scores))


def n_train_from_fraction(n_units: int, fraction: float) -> int:
    """The paper's 25%/50%/75% settings -> unit counts (at least 1)."""
    return max(1, round(n_units * fraction))
