"""Freeze-unit assignment over parameter pytrees.

A **freeze unit** is the granularity of the paper's layer selection: one
transformer block (or one conv/dense layer for the paper's own models).
Every param leaf maps to one unit — either wholly (``scalar`` leaves like
the embedding table) or per-index along its leading macro dim
(``stacked`` leaves inside the scanned block stack).

Given a 0/1 selection vector ``sel (U,)`` (from ``core.freezing``),
``mask_tree`` materializes a pytree of broadcastable masks; a leaf mask
for a stacked leaf has shape ``(n_macro,)`` and broadcasts over the rest
of the leaf, so masking cost is negligible.

Unit ordering is forward order: unit 0 = input embeddings (+ projector /
enc embeddings), units 1..L = layers (enc layers first for enc-dec),
unit U-1 = final norm + LM head.  This matches the paper's "14 trainable
layers including the output layer" accounting for VGG16.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pytree as pt


class LeafUnit(NamedTuple):
    kind: str        # "scalar" | "stacked"
    base: int        # unit id (scalar) or unit of macro index 0 (stacked)
    stride: int      # units advanced per macro index (stacked only)


class UnitAssignment(NamedTuple):
    n_units: int
    leaf_units: Any          # pytree congruent to params, leaves: LeafUnit
    unit_names: Tuple[str, ...]


def _is_leafunit(x):
    return isinstance(x, LeafUnit)


def build_units_zoo(cfg, params) -> UnitAssignment:
    """Unit map for the model-zoo architectures (stacked macro blocks)."""
    from ..models.transformer import block_layout
    n_subs = len(block_layout(cfg)) if cfg.family != "audio" else 1
    n_enc = cfg.n_enc_layers
    dec_base = 1 + n_enc
    n_dec = cfg.n_layers
    head_unit = dec_base + n_dec
    n_units = head_unit + 1

    def assign(path: str, leaf) -> LeafUnit:
        m = re.match(r"^blocks/sub(\d+)/", path)
        if m:
            return LeafUnit("stacked", dec_base + int(m.group(1)), n_subs)
        if path.startswith("enc_blocks/"):
            return LeafUnit("stacked", 1, 1)
        if path.startswith(("embed/", "enc_embed/", "projector/")):
            return LeafUnit("scalar", 0, 0)
        if path.startswith(("final_norm/", "head/", "enc_final_norm/")):
            return LeafUnit("scalar", head_unit, 0)
        raise ValueError(f"unassigned param path: {path}")

    leaf_units = pt.tree_map_with_path(assign, params)
    names = (["embed"] + [f"enc{i}" for i in range(n_enc)] +
             [f"layer{i}" for i in range(n_dec)] + ["head"])
    return UnitAssignment(n_units, leaf_units, tuple(names))


def build_units_flat(params, unit_order: Sequence[str]) -> UnitAssignment:
    """Unit map for the paper models: each top-level key is one unit."""
    order = {k: i for i, k in enumerate(unit_order)}

    def assign(path: str, leaf) -> LeafUnit:
        top = path.split("/")[0]
        if top not in order:
            raise ValueError(f"param {path} not in unit order {unit_order}")
        return LeafUnit("scalar", order[top], 0)

    leaf_units = pt.tree_map_with_path(assign, params)
    return UnitAssignment(len(unit_order), leaf_units, tuple(unit_order))


def mask_tree(assign: UnitAssignment, sel: jnp.ndarray, params) -> Any:
    """sel (U,) 0/1 -> pytree of masks broadcastable to params leaves."""

    def one(lu: LeafUnit, p):
        if lu.kind == "scalar":
            return sel[lu.base].astype(jnp.float32)
        nm = p.shape[0]
        idx = lu.base + lu.stride * jnp.arange(nm)
        return sel[idx].astype(jnp.float32)

    return jax.tree_util.tree_map(one, assign.leaf_units, params,
                                  is_leaf=_is_leafunit)


def apply_mask(mask, tree):
    """Elementwise tree * mask with trailing broadcast."""
    return jax.tree_util.tree_map(
        lambda x, k: x * jnp.reshape(
            k, jnp.shape(k) + (1,) * (x.ndim - jnp.ndim(k))).astype(x.dtype),
        tree, mask)


def unit_param_counts(assign: UnitAssignment, params) -> np.ndarray:
    """(U,) int64 — parameters per freeze unit (comm accounting)."""
    counts = np.zeros(assign.n_units, np.int64)
    for (path, leaf), lu in zip(
            pt.flatten_with_paths(params),
            jax.tree_util.tree_leaves(assign.leaf_units, is_leaf=_is_leafunit)):
        if lu.kind == "scalar":
            counts[lu.base] += int(np.prod(leaf.shape))
        else:
            per = int(np.prod(leaf.shape[1:]))
            for m in range(leaf.shape[0]):
                counts[lu.base + lu.stride * m] += per
    return counts


def build_units(cfg_or_order, params) -> UnitAssignment:
    if isinstance(cfg_or_order, (list, tuple)):
        return build_units_flat(params, cfg_or_order)
    return build_units_zoo(cfg_or_order, params)
