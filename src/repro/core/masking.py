"""Freeze-unit assignment over parameter pytrees.

A **freeze unit** is the granularity of the paper's layer selection: one
transformer block (or one conv/dense layer for the paper's own models).
Every param leaf maps to one unit — either wholly (``scalar`` leaves like
the embedding table) or per-index along its leading macro dim
(``stacked`` leaves inside the scanned block stack).

Given a 0/1 selection vector ``sel (U,)`` (from ``core.freezing``),
``mask_tree`` materializes a pytree of broadcastable masks; a leaf mask
for a stacked leaf has shape ``(n_macro,)`` and broadcasts over the rest
of the leaf, so masking cost is negligible.

Unit ordering is forward order: unit 0 = input embeddings (+ projector /
enc embeddings), units 1..L = layers (enc layers first for enc-dec),
unit U-1 = final norm + LM head.  This matches the paper's "14 trainable
layers including the output layer" accounting for VGG16.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pytree as pt


class LeafUnit(NamedTuple):
    kind: str        # "scalar" | "stacked"
    base: int        # unit id (scalar) or unit of macro index 0 (stacked)
    stride: int      # units advanced per macro index (stacked only)


class UnitAssignment(NamedTuple):
    n_units: int
    leaf_units: Any          # pytree congruent to params, leaves: LeafUnit
    unit_names: Tuple[str, ...]


def _is_leafunit(x):
    return isinstance(x, LeafUnit)


def build_units_zoo(cfg, params) -> UnitAssignment:
    """Unit map for the model-zoo architectures (stacked macro blocks)."""
    from ..models.transformer import block_layout
    n_subs = len(block_layout(cfg)) if cfg.family != "audio" else 1
    n_enc = cfg.n_enc_layers
    dec_base = 1 + n_enc
    n_dec = cfg.n_layers
    head_unit = dec_base + n_dec
    n_units = head_unit + 1

    def assign(path: str, leaf) -> LeafUnit:
        m = re.match(r"^blocks/sub(\d+)/", path)
        if m:
            return LeafUnit("stacked", dec_base + int(m.group(1)), n_subs)
        if path.startswith("enc_blocks/"):
            return LeafUnit("stacked", 1, 1)
        if path.startswith(("embed/", "enc_embed/", "projector/")):
            return LeafUnit("scalar", 0, 0)
        if path.startswith(("final_norm/", "head/", "enc_final_norm/")):
            return LeafUnit("scalar", head_unit, 0)
        raise ValueError(f"unassigned param path: {path}")

    leaf_units = pt.tree_map_with_path(assign, params)
    names = (["embed"] + [f"enc{i}" for i in range(n_enc)] +
             [f"layer{i}" for i in range(n_dec)] + ["head"])
    return UnitAssignment(n_units, leaf_units, tuple(names))


def build_units_flat(params, unit_order: Sequence[str]) -> UnitAssignment:
    """Unit map for the paper models: each top-level key is one unit."""
    order = {k: i for i, k in enumerate(unit_order)}

    def assign(path: str, leaf) -> LeafUnit:
        top = path.split("/")[0]
        if top not in order:
            raise ValueError(f"param {path} not in unit order {unit_order}")
        return LeafUnit("scalar", order[top], 0)

    leaf_units = pt.tree_map_with_path(assign, params)
    return UnitAssignment(len(unit_order), leaf_units, tuple(unit_order))


def mask_tree(assign: UnitAssignment, sel: jnp.ndarray, params) -> Any:
    """sel (U,) 0/1 -> pytree of masks broadcastable to params leaves."""

    def one(lu: LeafUnit, p):
        if lu.kind == "scalar":
            return sel[lu.base].astype(jnp.float32)
        nm = p.shape[0]
        idx = lu.base + lu.stride * jnp.arange(nm)
        return sel[idx].astype(jnp.float32)

    return jax.tree_util.tree_map(one, assign.leaf_units, params,
                                  is_leaf=_is_leafunit)


def apply_mask(mask, tree):
    """Elementwise tree * mask with trailing broadcast."""
    return jax.tree_util.tree_map(
        lambda x, k: x * jnp.reshape(
            k, jnp.shape(k) + (1,) * (x.ndim - jnp.ndim(k))).astype(x.dtype),
        tree, mask)


# ---------------------------------------------------------------------------
# slot packing (DESIGN.md §7 — the sparse round step)
#
# With a static per-round trained-unit budget ``n_slots`` the selected
# macro rows of every *stacked* leaf can be gathered into fixed-shape
# ``(L, ...)`` slot buffers (L = min(n_macro, n_slots)), so optimizer
# moments, weight deltas and the cross-client reduce only ever touch the
# trained slice of the model while shapes stay static under vmap/scan.
# Scalar leaves (embed/head) participate as whole units and are carried
# dense — their selection is per-client dynamic, so there is nothing to
# pack.


def slot_plan(assign: UnitAssignment, sel_row: jnp.ndarray, n_slots: int,
              params) -> Tuple[Any, Any]:
    """Per-leaf slot layout for one client's packed round.

    Returns ``(rows, valid)`` — two pytrees congruent to ``params``:

    * stacked leaf: ``rows (L,)`` int32 macro indices with the selected
      rows first (stable order) and *distinct* unselected pad rows after
      (argsort yields a permutation, so pad slots never alias a selected
      row); ``valid (L,)`` float32 is 1 on selected slots, 0 on pads.
    * scalar leaf: ``rows`` is an empty int32 sentinel and ``valid`` is
      the leaf's participation scalar ``sel_row[unit]`` — the same value
      ``mask_tree`` would produce, so ``valid`` doubles as the grad /
      optimizer mask tree for the packed representation.

    ``n_slots`` must be static (the strategy's ``n_train`` plus the
    optional always-trained head) for the shapes to stay static.
    """

    def one(lu: LeafUnit, p):
        if lu.kind == "scalar":
            return (jnp.zeros((0,), jnp.int32),
                    sel_row[lu.base].astype(jnp.float32))
        nm = p.shape[0]
        ids = lu.base + lu.stride * jnp.arange(nm)
        leaf_sel = sel_row[ids].astype(jnp.float32)
        n_keep = min(nm, n_slots)
        order = jnp.argsort(-leaf_sel)          # stable: selected first
        rows = order[:n_keep].astype(jnp.int32)
        return rows, leaf_sel[rows]

    out = jax.tree_util.tree_map(one, assign.leaf_units, params,
                                 is_leaf=_is_leafunit)
    unzip = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
    return unzip(0), unzip(1)


def slot_gather(assign: UnitAssignment, tree, rows):
    """Stacked leaves -> their ``(L, ...)`` slot rows; scalar leaves whole."""
    return jax.tree_util.tree_map(
        lambda lu, x, r: x if lu.kind == "scalar" else x[r],
        assign.leaf_units, tree, rows, is_leaf=_is_leafunit)


def slot_merge(assign: UnitAssignment, base, packed, rows):
    """Inverse of :func:`slot_gather`: write slot rows into ``base``.

    Stacked leaves scatter their packed rows into the full-shape base
    leaf (rows are distinct by construction, so a plain ``.set`` is
    exact — pad slots rewrite their own unchanged value); scalar leaves
    pass through from ``packed``.  Used with ``base =
    stop_gradient(global_params)`` this makes frozen stacked rows
    constants of the traced loss: no cotangent flows into them and
    their optimizer state simply does not exist.
    """
    return jax.tree_util.tree_map(
        lambda lu, b, p, r: p if lu.kind == "scalar" else b.at[r].set(p),
        assign.leaf_units, base, packed, rows, is_leaf=_is_leafunit)


# ---------------------------------------------------------------------------
# gradient-norm telemetry (DESIGN.md §11 — scored selection)
#
# The scored selection engine needs per-unit gradient norms out of the
# round step at (near-)zero cost: norms are reduced from gradients that
# local training has already materialized, accumulated into one tiny
# (U,) vector per client, and ride the metrics alongside the existing
# collective.  Both paths accumulate leaves in tree order and reduce
# each macro row independently, so the packed path's telemetry equals
# the dense path's BITWISE (regression-tested) — pad slots and frozen
# rows contribute exact zeros either way.


class NormHook(NamedTuple):
    """Per-step gradient-norm accumulator for the local-update scan:
    ``fn(grads) -> (n_units,)`` per-unit squared-norm contributions."""
    n_units: int
    fn: Any


def unit_sqnorm(assign: UnitAssignment, grads) -> jnp.ndarray:
    """(U,) float32 — per-unit squared norms of a (masked) dense
    gradient tree.  Frozen units' gradients are exact zeros after
    masking, so their bins stay exactly 0.0."""
    acc = jnp.zeros((assign.n_units,), jnp.float32)
    for lu, g in zip(
            jax.tree_util.tree_leaves(assign.leaf_units, is_leaf=_is_leafunit),
            jax.tree_util.tree_leaves(grads)):
        gf = g.astype(jnp.float32)
        if lu.kind == "scalar":
            acc = acc.at[lu.base].add(jnp.sum(jnp.square(gf)))
        else:
            nm = g.shape[0]
            rows_sq = jnp.sum(jnp.square(gf).reshape((nm, -1)), axis=1)
            idx = lu.base + lu.stride * jnp.arange(nm)
            acc = acc.at[idx].add(rows_sq)
    return acc


def unit_sqnorm_packed(assign: UnitAssignment, grads, rows) -> jnp.ndarray:
    """Packed-path twin of :func:`unit_sqnorm`: per-unit squared norms
    from the already-materialized ``(L, ...)`` packed slot gradients.
    Each slot reduces independently and scatters to its macro row's
    unit (``rows`` from ``slot_plan``; pad slots carry masked-zero
    gradients, so their unselected units receive exact zeros — the same
    value the dense path's masked rows contribute), keeping packed ==
    dense telemetry bitwise."""
    acc = jnp.zeros((assign.n_units,), jnp.float32)
    for lu, g, r in zip(
            jax.tree_util.tree_leaves(assign.leaf_units, is_leaf=_is_leafunit),
            jax.tree_util.tree_leaves(grads),
            jax.tree_util.tree_leaves(rows)):
        gf = g.astype(jnp.float32)
        if lu.kind == "scalar":
            acc = acc.at[lu.base].add(jnp.sum(jnp.square(gf)))
        else:
            n_slots = g.shape[0]
            rows_sq = jnp.sum(jnp.square(gf).reshape((n_slots, -1)), axis=1)
            acc = acc.at[lu.base + lu.stride * r].add(rows_sq)
    return acc


def dense_norm_hook(assign: UnitAssignment) -> NormHook:
    return NormHook(assign.n_units, lambda g: unit_sqnorm(assign, g))


def packed_norm_hook(assign: UnitAssignment, rows) -> NormHook:
    """``rows`` is one client's slot plan (built inside the per-client
    closure, so the hook is vmap-friendly)."""
    return NormHook(assign.n_units,
                    lambda g: unit_sqnorm_packed(assign, g, rows))


def unit_param_counts(assign: UnitAssignment, params) -> np.ndarray:
    """(U,) int64 — parameters per freeze unit (comm accounting)."""
    counts = np.zeros(assign.n_units, np.int64)
    for (path, leaf), lu in zip(
            pt.flatten_with_paths(params),
            jax.tree_util.tree_leaves(assign.leaf_units, is_leaf=_is_leafunit)):
        if lu.kind == "scalar":
            counts[lu.base] += int(np.prod(leaf.shape))
        else:
            per = int(np.prod(leaf.shape[1:]))
            for m in range(leaf.shape[0]):
                counts[lu.base + lu.stride * m] += per
    return counts


def build_units(cfg_or_order, params) -> UnitAssignment:
    if isinstance(cfg_or_order, (list, tuple)):
        return build_units_flat(params, cfg_or_order)
    return build_units_zoo(cfg_or_order, params)
