"""ClientUpdate (paper Alg. 2) as a compiled local-training loop.

``local_update`` runs ``local_steps`` masked optimizer steps over the
client's batch stream under ``lax.scan`` and returns the weight *delta*
(zero, bit-exactly, for frozen units — property-tested).  The optimizer
is freshly initialized each round, matching the paper's per-round client
setup (FEDn clients re-create the optimizer on every round).

FedProx (Sahu et al. 2018) is available through ``prox_mu > 0`` — the
proximal term pulls only the round's *trained* (unmasked) layers toward
the global model: the freeze mask is applied inside the prox sum, so
frozen layers contribute neither loss nor gradient.

``norm_hook`` (DESIGN.md §11) accumulates per-unit squared gradient
norms across the local steps — the scored selection engine's live
telemetry.  The hook reads the gradients the step has already
materialized (no extra HBM round-trips, one extra (U,) carry slot);
with ``norm_hook=None`` (scoring off) the scan carries and traces are
byte-for-byte what they were before the hook existed.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..common import pytree as pt
from ..optim.masked import adam_init, adam_step, sgd_init, sgd_step
from .masking import NormHook, apply_mask, slot_gather, slot_merge

PyTree = Any


def local_update(loss_fn: Callable, global_params: PyTree, mask: PyTree,
                 batches: PyTree, *, lr: float = 1e-2,
                 optimizer: str = "adam", prox_mu: float = 0.0,
                 loss_kwargs: Optional[Dict] = None,
                 norm_hook: Optional[NormHook] = None
                 ) -> Tuple[PyTree, Dict[str, jnp.ndarray]]:
    """One client's round.  ``batches`` leaves have leading (steps,) dim.

    Returns (delta, metrics) where delta = trained - global (exact zeros
    on frozen units).  With ``norm_hook``, metrics additionally carries
    ``unit_sqnorm`` — (U,) per-unit squared gradient norms summed over
    the local steps (frozen units: exact zeros).
    """
    loss_kwargs = loss_kwargs or {}
    opt_init, opt_step = ((adam_init, adam_step) if optimizer == "adam"
                          else (sgd_init, sgd_step))

    def total_loss(params, batch):
        loss, metrics = loss_fn(params, batch, **loss_kwargs)
        if prox_mu > 0.0:
            # prox pulls TRAINED layers only: mask the diffs so frozen
            # layers contribute neither loss nor gradient
            diffs = apply_mask(mask, jax.tree_util.tree_map(
                lambda a, b: (a - b).astype(jnp.float32),
                params, global_params))
            sq = sum(jnp.sum(jnp.square(d))
                     for d in jax.tree_util.tree_leaves(diffs))
            loss = loss + 0.5 * prox_mu * sq
        return loss, metrics

    def step(carry, batch):
        if norm_hook is None:
            params, opt_state = carry
        else:
            params, opt_state, nacc = carry
        (loss, metrics), grads = jax.value_and_grad(
            total_loss, has_aux=True)(params, batch)
        grads = apply_mask(mask, grads)
        if norm_hook is not None:
            nacc = nacc + norm_hook.fn(grads)
        params, opt_state = opt_step(grads, opt_state, params, lr=lr,
                                     mask=mask)
        carry = (params, opt_state) if norm_hook is None \
            else (params, opt_state, nacc)
        return carry, loss

    init = (global_params, opt_init(global_params))
    if norm_hook is not None:
        init = init + (jnp.zeros((norm_hook.n_units,), jnp.float32),)
    carry, losses = jax.lax.scan(step, init, batches)
    delta = pt.tree_sub(carry[0], global_params)
    metrics = {"loss_mean": losses.mean(), "loss_last": losses[-1]}
    if norm_hook is not None:
        metrics["unit_sqnorm"] = carry[2]
    return delta, metrics


def packed_cohort_fn(loss_fn: Callable, assign, fl,
                     loss_kwargs: Optional[Dict] = None, *,
                     scoring: bool = False) -> Callable:
    """The vmapped packed local-training stage, shared verbatim by the
    sync round step, the async dispatch, and the chunked cohort engine
    (DESIGN.md §13).

    Returns ``cohort(global_params, rows, valid, batches) -> (pdeltas,
    metrics)`` with a leading client axis on everything but
    ``global_params`` — exactly the shape contract
    ``launch.mesh.shard_over_clients`` splits over the ``(client,)``
    mesh, which is how all three call sites shard the same trace.
    """
    from .masking import packed_norm_hook

    def cohort(global_params, rows, valid, batches):
        def one(rows_c, valid_c, b):
            return local_update_packed(
                loss_fn, global_params, assign, rows_c, valid_c, b,
                lr=fl.lr, optimizer=fl.optimizer, prox_mu=fl.prox_mu,
                loss_kwargs=loss_kwargs,
                norm_hook=packed_norm_hook(assign, rows_c)
                if scoring else None)

        return jax.vmap(one)(rows, valid, batches)

    return cohort


def local_update_packed(loss_fn: Callable, global_params: PyTree,
                        assign, rows: PyTree, valid: PyTree,
                        batches: PyTree, *, lr: float = 1e-2,
                        optimizer: str = "adam", prox_mu: float = 0.0,
                        loss_kwargs: Optional[Dict] = None,
                        norm_hook: Optional[NormHook] = None
                        ) -> Tuple[PyTree, Dict[str, jnp.ndarray]]:
    """Packed variant of :func:`local_update` (DESIGN.md §7).

    ``rows``/``valid`` come from ``masking.slot_plan``: the client's
    trained macro rows of every stacked leaf, gathered into fixed-shape
    ``(L, ...)`` slot buffers.  The scan carry — packed params plus
    freshly initialized optimizer moments — holds only those slots, so
    frozen stacked rows cost **zero optimizer memory**; the loss sees
    the full model reconstructed by scattering the slots into
    ``stop_gradient(global_params)``, so no cotangent flows into frozen
    rows and XLA can dead-code-eliminate their weight-gradient work.
    Scalar leaves (embed/head) are carried whole with masked grads —
    exactly the dense path, which keeps the two paths bit-comparable.

    Returns ``(packed_delta, metrics)``: stacked leaves carry ``(L,
    ...)`` slot deltas (exact zeros on pad slots — pads never receive an
    optimizer update), scalar leaves full-shape masked deltas.
    """
    loss_kwargs = loss_kwargs or {}
    opt_init, opt_step = ((adam_init, adam_step) if optimizer == "adam"
                          else (sgd_init, sgd_step))
    frozen = jax.lax.stop_gradient(global_params)
    packed0 = slot_gather(assign, global_params, rows)

    def total_loss(packed, batch):
        params = slot_merge(assign, frozen, packed, rows)
        loss, metrics = loss_fn(params, batch, **loss_kwargs)
        if prox_mu > 0.0:
            # prox over the packed representation: trained slots only
            diffs = apply_mask(valid, jax.tree_util.tree_map(
                lambda a, b: (a - b).astype(jnp.float32), packed, packed0))
            sq = sum(jnp.sum(jnp.square(d))
                     for d in jax.tree_util.tree_leaves(diffs))
            loss = loss + 0.5 * prox_mu * sq
        return loss, metrics

    def step(carry, batch):
        if norm_hook is None:
            packed, opt_state = carry
        else:
            packed, opt_state, nacc = carry
        (loss, metrics), grads = jax.value_and_grad(
            total_loss, has_aux=True)(packed, batch)
        grads = apply_mask(valid, grads)
        if norm_hook is not None:
            # norms reduce from the packed grads the step already
            # materialized — the telemetry never touches frozen rows
            nacc = nacc + norm_hook.fn(grads)
        packed, opt_state = opt_step(grads, opt_state, packed, lr=lr,
                                     mask=valid)
        carry = (packed, opt_state) if norm_hook is None \
            else (packed, opt_state, nacc)
        return carry, loss

    init = (packed0, opt_init(packed0))
    if norm_hook is not None:
        init = init + (jnp.zeros((norm_hook.n_units,), jnp.float32),)
    carry, losses = jax.lax.scan(step, init, batches)
    delta = pt.tree_sub(carry[0], packed0)
    metrics = {"loss_mean": losses.mean(), "loss_last": losses[-1]}
    if norm_hook is not None:
        metrics["unit_sqnorm"] = carry[2]
    return delta, metrics
