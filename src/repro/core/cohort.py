"""Fleet-scale cohort engine: chunk-streamed rounds over a registered
client fleet (DESIGN.md §13).

The synchronous loop materializes the whole cohort at once: one
``(C, steps, ...)`` batch pytree, one width-C vmapped local-training
trace, one dense aggregate.  That couples *how many clients exist* to
*how much memory one round takes* — a fleet of 10^5 registered edge
nodes cannot even be enumerated, let alone vmapped.  This module breaks
the coupling along three axes:

* **Registered fleet vs. in-flight cohort** (``FLConfig.n_registered``):
  the server knows R clients but trains an ``n_clients``-sized cohort
  per round.  Host state per registered client is O(1) scalars — the
  :class:`FleetState` loss/grad-norm EMAs — never a batch or a delta.
* **Chunk streaming** (``FLConfig.cohort_chunk``): the cohort flows
  through the round in fixed-size chunks, each chunk one compiled step
  (static shapes — one compile for every chunk of every round), with a
  scatter-accumulate partial aggregate carried across chunks
  (``aggregation.packed_accumulate``).  Because the packed aggregation
  is a strictly sequential per-client scan, *any* chunking in cohort
  order is **bitwise-equal** to the single-shot vmapped round
  (property-tested across topologies × strategies × chunk sizes,
  including straggler dropout and mid-round checkpoint restore).
* **Client-sampling plugin axis** (``@register_client_sampler``,
  mirroring the selection-strategy registry): which R-fleet members
  form the round's cohort.  ``uniform`` draws without replacement;
  ``loss_proportional`` and ``telemetry_driven`` Gumbel-top-k against
  the fleet's loss / gradient-norm EMAs — the same per-unit norm-hook
  telemetry the scored selection engine reads (DESIGN.md §11), reduced
  per client and EMA'd per fleet member.

Sampler keys come off their own stateless stream
(``fold_in(sampler_base, round)``), NOT the server key stream — so with
R == C and any sampler the cohort is the identity and the engine's
rounds are bitwise the plain loop's (the regression anchor), and a
checkpoint needs no sampler RNG state.

The engine mirrors ``Server.run_round``'s observable contract exactly —
same key-stream order (round key drawn before hooks), same hook
call points, same ``RoundRecord``/``sel_history``/telemetry layout — so
every ``ServerHook`` (straggler dropout, accounting, checkpointing,
logging) composes unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, ClassVar, Dict, List, Optional, Tuple, \
    Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.compileguard import CompileGuard
from .registry import unknown_name_message

PyTree = Any


# ---------------------------------------------------------------------------
# fleet state: O(1) host scalars per registered client

@dataclasses.dataclass
class FleetState:
    """Per-registered-client signals the samplers read.

    All ``(R,)`` numpy arrays — the ONLY per-registered-client host
    state the engine keeps (batches and deltas exist per cohort chunk
    only), which is what bounds host memory at fleet scale.
    """
    loss_ema: np.ndarray      # (R,) EMA of the client's round mean loss
    norm_ema: np.ndarray      # (R,) EMA of the client's total grad norm
    counts: np.ndarray        # (R,) participation counts (0 = unseen)
    round: int = 0            # rounds the fleet has advanced through


def fleet_init(n_registered: int) -> FleetState:
    return FleetState(loss_ema=np.zeros((n_registered,), np.float32),
                      norm_ema=np.zeros((n_registered,), np.float32),
                      counts=np.zeros((n_registered,), np.int32))


@dataclasses.dataclass(frozen=True)
class CohortContext:
    """What a sampler sees when drawing a round's cohort."""
    n_registered: int
    cohort: int
    fleet: FleetState


# ---------------------------------------------------------------------------
# client-sampler registry (mirrors strategies/topologies)

class ClientSampler:
    """Base class for cohort-sampling plugins.

    ``sample(key, ctx)`` returns the round's cohort as a **sorted**
    ``(cohort,)`` array of unique registered-client ids.  Sorted order
    is load-bearing: with R == C every sampler then returns
    ``arange(C)`` and the engine's rounds are bitwise the plain loop's.
    ``needs_norms`` turns the per-unit gradient-norm hook on inside
    local training so :class:`FleetState.norm_ema` gets fed.
    """

    name: ClassVar[str] = ""
    needs_norms: ClassVar[bool] = False

    def sample(self, key, ctx: CohortContext) -> np.ndarray:
        raise NotImplementedError

    def __repr__(self):
        return f"{type(self).__name__}(name={self.name!r})"


_SAMPLERS: Dict[str, ClientSampler] = {}


class UnknownClientSamplerError(ValueError):
    pass


def register_client_sampler(obj: Union[Type[ClientSampler], ClientSampler],
                            *, name: Optional[str] = None):
    """Register a sampler class (instantiated with no args) or instance.

    Usable as a decorator::

        @register_client_sampler
        class Mine(ClientSampler):
            name = "mine"
            ...
    """
    sampler = obj() if isinstance(obj, type) else obj
    key = name or sampler.name
    if not key:
        raise ValueError(f"client sampler {obj!r} has no name")
    _SAMPLERS[key] = sampler
    return obj


def unregister_client_sampler(name: str):
    _SAMPLERS.pop(name, None)


def registered_client_samplers() -> Tuple[str, ...]:
    return tuple(sorted(_SAMPLERS))


def get_client_sampler(name: str) -> ClientSampler:
    try:
        return _SAMPLERS[name]
    except KeyError:
        raise UnknownClientSamplerError(unknown_name_message(
            "client sampler", name, _SAMPLERS)) from None


def resolve_client_sampler(spec: Union[str, ClientSampler, None]
                           ) -> ClientSampler:
    """Name or instance -> instance (None -> the uniform default)."""
    if spec is None:
        return get_client_sampler("uniform")
    return get_client_sampler(spec) if isinstance(spec, str) else spec


def _uniform_draw(key, n_registered: int, cohort: int) -> np.ndarray:
    """O(cohort) uniform draw without replacement (Floyd's algorithm).

    The previous implementation materialized ``permutation(key, R)`` —
    O(R) memory and O(R log R) device work per round, untenable at
    R = 10^6 registered clients when only C of them train.  Floyd's F2
    touches exactly ``cohort`` draws: for j in R-C..R-1 pick t uniform
    on [0, j], take t unless already taken, else take j.  Exactly
    uniform over C-subsets, O(C) time and memory, independent of R.

    Seed contract (bitwise-stable; pinned by the regression suite): the
    JAX key's raw ``key_data`` words plus the tag ``0xF107D`` seed a
    numpy ``SeedSequence`` driving a ``Philox`` generator, whose
    integer stream is specified and platform-independent — the draw is
    a pure function of the key bits, so the engine still needs no
    sampler RNG state in checkpoints.  With cohort == n_registered the
    draw is the identity ``arange`` (the R == C bitwise anchor).
    """
    if cohort >= n_registered:
        return np.arange(n_registered, dtype=np.int32)
    words = [int(w) for w in np.asarray(jax.random.key_data(key),
                                        np.uint32).ravel()]
    rng = np.random.Generator(np.random.Philox(
        np.random.SeedSequence(words + [0xF107D])))
    # vectorized pre-draw: t_j ~ U[0, j] for j = R-C .. R-1
    ts = rng.integers(0, np.arange(n_registered - cohort,
                                   n_registered) + 1)
    chosen: set = set()
    for j, t in zip(range(n_registered - cohort, n_registered), ts):
        t = int(t)
        chosen.add(j if t in chosen else t)
    return np.asarray(sorted(chosen), np.int32)


def _scored_draw(key, signal: np.ndarray, seen: np.ndarray,
                 cohort: int) -> np.ndarray:
    """Gumbel-top-k draw ∝ softmax of the z-scored signal.

    Unseen clients take the *maximum* seen signal (optimistic
    initialization: every fleet member gets sampled eventually), and
    with no signal at all the draw degrades to uniform on the same key.
    """
    if not seen.any():
        return _uniform_draw(key, signal.shape[0], cohort)
    s = np.where(seen, signal, signal[seen].max()).astype(np.float64)
    z = (s - s.mean()) / (s.std() + 1e-6)
    g = np.asarray(jax.random.gumbel(key, s.shape), np.float64)
    top = np.argsort(-(z + g), kind="stable")[:cohort]
    return np.sort(top).astype(np.int32)


@register_client_sampler
class UniformSampler(ClientSampler):
    """Uniform without replacement — the FedAvg default."""
    name = "uniform"

    def sample(self, key, ctx):
        return _uniform_draw(key, ctx.n_registered, ctx.cohort)


@register_client_sampler
class LossProportionalSampler(ClientSampler):
    """Prefer clients whose recent loss EMA is high (they have the most
    to learn from another round)."""
    name = "loss_proportional"

    def sample(self, key, ctx):
        return _scored_draw(key, ctx.fleet.loss_ema,
                            ctx.fleet.counts > 0, ctx.cohort)


@register_client_sampler
class TelemetryDrivenSampler(ClientSampler):
    """Prefer clients whose gradient-norm EMA is high — the fleet-level
    analogue of score-weighted unit selection (DESIGN.md §11), fed by
    the same norm-hook telemetry."""
    name = "telemetry_driven"
    needs_norms = True

    def sample(self, key, ctx):
        return _scored_draw(key, ctx.fleet.norm_ema,
                            ctx.fleet.counts > 0, ctx.cohort)


# ---------------------------------------------------------------------------
# compiled programs

@dataclasses.dataclass
class CohortPrograms:
    """The engine's four compiled pieces plus resolved plugins.

    * ``select(key[, sel_state]) -> sel (C, U)`` — the round's
      per-client trained-unit selection (bitwise the sync round's);
    * ``acc_init(global) -> acc`` — the zero partial aggregate;
    * ``chunk(global, acc, sel_chunk, w_chunk, positions, batches) ->
      (acc, {"loss"[, "unit_sqnorm"]})`` — one chunk's packed local
      training folded into the carry (static shapes: one compile
      serves every chunk of every round);
    * ``finalize(global, acc, sel, weights, losses) -> (new_global,
      loss_mean)`` — full-cohort denominators + the round loss.
    """
    select: Callable
    acc_init: Callable
    chunk: Callable
    finalize: Callable
    sampler: ClientSampler
    strategy: Any
    scoring: bool
    n_slots: int


def build_cohort_programs(loss_fn: Callable, assign, fl,
                          loss_kwargs: Optional[Dict] = None, *,
                          strategy=None, scores=None,
                          topology=None) -> CohortPrograms:
    """Build the chunk-streamed round's compiled programs.

    The chunk program is the sync packed round step's selection +
    vmapped packed local training (``client.packed_cohort_fn`` — the
    same trace, optionally shard_map'd over the ``(client,)`` mesh via
    ``fl.client_shards``) followed by ``Topology.build_chunk_agg``'s
    scatter-accumulate.  Streaming every chunk and finalizing is
    bitwise the single-shot ``masked_fedavg_packed`` by construction:
    the accumulate is a sequential per-client scan, and splitting a
    scan across calls changes nothing about its float-add order.
    """
    from .client import packed_cohort_fn
    from .masking import slot_plan
    from .topology import (_cohort_runner, _live_ctx, _selection_setup,
                           resolve_topology)
    from . import codecs as _codecs
    from . import faults as _faults
    from .aggregation import gate_packed_updates
    topo = resolve_topology(topology if topology is not None
                            else fl.topology)
    strat, ctx = _selection_setup(assign, fl, strategy, scores)
    if strat.dense:
        raise ValueError(
            "the chunked cohort engine carries packed trained-slot "
            "deltas; the dense 'full' strategy has nothing to pack — "
            "use a partial strategy (train_fraction < 1)")
    sampler = resolve_client_sampler(fl.client_sampler)
    n_slots = fl.resolve_n_slots(ctx.n_units)
    scoring = strat.stateful or sampler.needs_norms
    acc_init, accumulate, finalize_agg = topo.build_chunk_agg(assign, fl)
    chunk_width = fl.cohort_chunk or fl.n_clients
    run_cohort = _cohort_runner(fl, chunk_width)
    cohort = packed_cohort_fn(loss_fn, assign, fl, loss_kwargs,
                              scoring=scoring)

    def select(key, sel_state=None):
        sel = strat.select(key, _live_ctx(ctx, sel_state))
        if fl.always_train_head:
            sel = sel.at[:, -1].set(1.0)
        return sel

    inject_on = _faults.delta_faults_configured(fl)
    gate_on = _faults.gate_enabled(fl)
    codec_fn = _codecs.build_codec_transform(
        _codecs.resolve_codec(fl.codec), assign, fl)

    def chunk_step(global_params, acc, sel_chunk, w_chunk, positions,
                   batches, mode=None, scale=None, codec_key=None):
        rows, valid = jax.vmap(
            lambda s: slot_plan(assign, s, n_slots, global_params)
        )(sel_chunk)
        pdeltas, metrics = run_cohort(cohort, global_params, rows, valid,
                                      batches)
        out = {"loss": metrics["loss_mean"]}
        if scoring:
            out["unit_sqnorm"] = metrics["unit_sqnorm"]
        # uplink codec (DESIGN.md §16): encode/decode compiles into the
        # chunk program between local training and the fault axis, so
        # wire corruption hits what actually crossed the WAN.  Only
        # stateless codecs reach this path — FLConfig rejects the
        # error-feedback codec × cohort engine combination up front.
        if codec_fn is not None:
            pdeltas, _ = codec_fn(pdeltas, rows, valid, w_chunk,
                                  codec_key)
        # fault axis (DESIGN.md §14): corruption + validation gate ride
        # the chunk program when configured — both bitwise identities
        # when untripped, so zero-rate chaos keeps chunked == single-
        # shot == plain bitwise
        if inject_on:
            if mode is None:
                mode = jnp.zeros((chunk_width,), jnp.int32)
                scale = jnp.ones((chunk_width,), jnp.float32)
            pdeltas = _faults.chaos_inject(pdeltas, mode, scale)
        if gate_on:
            pdeltas, w_chunk, quar = gate_packed_updates(
                assign, pdeltas, valid, w_chunk, fl.max_delta_norm)
            out["quarantined"] = quar
        acc = accumulate(acc, pdeltas, rows, valid, w_chunk, positions)
        return acc, out

    def finalize(global_params, acc, sel, weights, losses):
        new_params = finalize_agg(global_params, acc, sel, weights)
        # same jnp.mean over the same (C,) values the sync round step
        # reduces, so the recorded loss is bitwise the sync round's
        return new_params, losses.mean()

    # chunk and finalize donate the ``acc`` carry (argnum 1): the
    # engine reassigns p["acc"] from every chunk's output and discards
    # it after finalize, so each chunk scatter-accumulates into the
    # donated buffer instead of allocating a fresh partial aggregate
    # per chunk.  global_params is NOT donated — it is re-read by every
    # chunk of the round.  CompileGuard pins each program to exactly
    # one compile across the round's chunks (and across rounds).
    return CohortPrograms(
        select=CompileGuard(select, name="cohort_select", max_programs=1),
        acc_init=CompileGuard(acc_init, name="cohort_acc_init",
                              max_programs=1),
        chunk=CompileGuard(chunk_step, name="cohort_chunk",
                           max_programs=1, donate_argnums=(1,)),
        finalize=CompileGuard(finalize, name="cohort_finalize",
                              max_programs=1, donate_argnums=(1,)),
        sampler=sampler, strategy=strat, scoring=scoring, n_slots=n_slots)


# ---------------------------------------------------------------------------
# the engine

class CohortEngine:
    """Drives chunk-streamed rounds over a registered fleet.

    A round is three phases — ``begin_round`` (sample the cohort, draw
    the selection, zero the partial aggregate), ``step_chunk`` × the
    chunk count (stream one chunk's batches through packed local
    training into the carry), ``finish_round`` (full-cohort finalize,
    record, telemetry, fleet EMAs) — composed by ``run_round``/``run``.
    Host memory in flight is O(chunk) batches + O(cohort) selection
    rows + one packed accumulator, regardless of R.

    ``batch_fn(round_idx, client_ids) -> (len(ids), steps, ...)``
    pytree is the loader contract (``FederatedLoader.client_batches``):
    the host never materializes more than one chunk of batches.

    Checkpointing: ``checkpoint_state``/``restore_state`` carry the
    fleet EMAs and — mid-round — the partial aggregate, streamed-chunk
    counter, cohort ids/selection/weights and per-chunk losses, so a
    restore at any chunk boundary resumes bitwise (the server key
    stream was already advanced by ``begin_round`` and is saved by the
    ordinary server checkpoint).
    """

    def __init__(self, server, assign, fl, *, programs: CohortPrograms,
                 seed: int = 0):
        self.server = server
        self.assign = assign
        self.fl = fl
        self.programs = programs
        self.n_registered = fl.n_registered or fl.n_clients
        self.chunk = fl.cohort_chunk or fl.n_clients
        self.n_chunks = fl.n_clients // self.chunk
        self.fleet = fleet_init(self.n_registered)
        # stateless sampler key stream: round r's draw is a pure
        # function of (seed, r), independent of the server stream —
        # nothing to checkpoint, and the server stream stays bitwise
        # identical to the plain loop's
        self._sampler_base = jax.random.fold_in(
            jax.random.PRNGKey(seed), 0x0C0F0E)
        # stateless codec key stream mirroring the sampler stream: the
        # chunk at (round r, chunk j) encodes under a pure function of
        # (seed, r, j) — nothing to checkpoint, and codec "none" never
        # draws so plain-loop key streams stay bitwise identical
        from . import codecs as _codecs
        self._codec_base = jax.random.fold_in(
            jax.random.PRNGKey(seed), _codecs.CODEC_KEY_TAG)
        self._partial: Optional[Dict[str, Any]] = None

    @property
    def started(self) -> bool:
        return self.fleet.round > 0 or self._partial is not None

    # -- the three phases -------------------------------------------------

    def begin_round(self, weights=None) -> Dict[str, Any]:
        server = self.server
        if self._partial is not None:
            raise RuntimeError(
                "a cohort round is already in flight; stream its "
                "remaining chunks and finish_round() first")
        r = len(server.history)
        t0 = time.perf_counter()
        # SAME key-stream slot as Server.run_round: round key first,
        # then hooks (StragglerDropout) draw — bitwise-equal streams
        rk = server.next_key()
        sk = jax.random.fold_in(self._sampler_base, r)
        ids = np.asarray(self.programs.sampler.sample(
            sk, CohortContext(self.n_registered, self.fl.n_clients,
                              self.fleet)), np.int32)
        # crash-resilient cohort assembly (DESIGN.md §14): crashed
        # members are resampled from the rest of the registered fleet
        # with bounded jittered backoff; slots that exhaust their
        # retries degrade to zero-weight holes (partial round)
        dead: List[int] = []
        inj = server.fault_injector
        if inj is not None and inj.crash_prob > 0.0:
            ids, dead = self._resample_crashed(r, ids)
        c = self.fl.n_clients
        if weights is None:
            w = jnp.ones((c,), jnp.float32)
        else:
            wr = np.asarray(weights, np.float32)
            if wr.shape[0] == c:
                w = jnp.asarray(wr)
            elif wr.shape[0] == self.n_registered:
                w = jnp.asarray(wr[ids])    # fleet weights -> cohort view
            else:
                raise ValueError(
                    f"weights must have length n_clients={c} (cohort) or "
                    f"n_registered={self.n_registered} (fleet), got "
                    f"{wr.shape[0]}")
        if dead:
            mask = np.ones((c,), np.float32)
            mask[dead] = 0.0
            w = w * jnp.asarray(mask)
        for hook in server.hooks:
            new_w = hook.on_round_start(server, r, w)
            if new_w is not None:
                w = new_w
        w_np = np.asarray(w, np.float32)
        n_part = int(np.count_nonzero(w_np))
        p: Dict[str, Any] = {
            "round": r, "t0": t0, "ids": ids, "w": jnp.asarray(w_np),
            "eff_w": [float(x) for x in w_np], "n_part": n_part,
            "chunk": 0, "losses": [], "sqnorms": [], "quars": [],
            "skipped": n_part == 0, "sel": None, "acc": None,
        }
        if n_part:
            st = server.sel_state
            sel = self.programs.select(rk) if st is None \
                else self.programs.select(rk, st)
            p["sel"] = sel
            p["acc"] = self.programs.acc_init(server.global_params())
            if getattr(self.fl, "client_shards", 0):
                # the sharded chunk program commits its acc output to
                # the (client,) mesh; the fresh accumulator must start
                # there too or chunk 2 retraces on the sharding flip
                from jax.sharding import NamedSharding, PartitionSpec
                from ..launch.mesh import make_client_mesh
                p["acc"] = jax.device_put(
                    p["acc"],
                    NamedSharding(make_client_mesh(self.fl.client_shards),
                                  PartitionSpec()))
        self._partial = p
        return p

    def _resample_crashed(self, r: int,
                          ids: np.ndarray) -> Tuple[np.ndarray, List[int]]:
        """Replace crashed cohort members with freshly sampled fleet
        clients (bounded attempts via ``common/retry.py``); returns the
        repaired ids and the positions that stayed dead."""
        from ..common.retry import Backoff, retry_call
        from .faults import ClientCrashed
        inj = self.server.fault_injector
        ids = np.array(ids, np.int32)
        taken = {int(i) for i in ids}
        dead: List[int] = []
        backoff = Backoff(attempts=max(1, self.fl.fault_retries),
                          seed=inj.seed)
        for pos in range(ids.shape[0]):
            if not inj.crashed(r, int(ids[pos])):
                continue
            taken.discard(int(ids[pos]))

            def attempt(k, _pos=pos):
                cand = inj.resample(r, _pos, k, self.n_registered,
                                    frozenset(taken))
                if cand is None or inj.crashed(r, cand):
                    raise ClientCrashed(
                        f"round {r} slot {_pos}: no live replacement "
                        f"on attempt {k}")
                return cand

            try:
                # simulated time: the jittered backoff schedule bounds
                # attempts but nobody really sleeps (sleep=None)
                new = retry_call(attempt, backoff=backoff,
                                 retry_on=(ClientCrashed,),
                                 token=(r, pos), sleep=None)
                ids[pos] = new
                taken.add(int(new))
            except ClientCrashed:
                dead.append(pos)
        return ids, dead

    def step_chunk(self, batch_fn: Callable[[int, np.ndarray], Any]):
        p = self._partial
        if p is None:
            raise RuntimeError("no cohort round in flight; begin_round "
                               "first")
        if p["skipped"]:
            return
        j = p["chunk"]
        if j >= self.n_chunks:
            raise RuntimeError(
                f"all {self.n_chunks} chunks of round {p['round']} are "
                "already streamed; finish_round()")
        lo, hi = j * self.chunk, (j + 1) * self.chunk
        pos = np.arange(lo, hi)
        batches = batch_fn(p["round"], p["ids"][pos])
        inj = self.server.fault_injector
        chunk_kw = {}
        if inj is not None and inj.has_delta:
            # the corruption plan is a pure function of (seed, round,
            # client id) — recomputed here, never checkpointed
            plan = inj.corrupt_plan(p["round"], p["ids"][pos])
            chunk_kw = {"mode": jnp.asarray(plan["mode"]),
                        "scale": jnp.asarray(plan["scale"])}
        if getattr(self.fl, "codec", "none") != "none":
            chunk_kw["codec_key"] = jax.random.fold_in(
                jax.random.fold_in(self._codec_base, p["round"]), j)
        acc, mets = self.programs.chunk(
            self.server.global_params(), p["acc"], p["sel"][lo:hi],
            p["w"][lo:hi], jnp.asarray(pos, jnp.int32), batches,
            **chunk_kw)
        p["acc"] = acc
        p["losses"].append(np.asarray(mets["loss"], np.float32))
        if "unit_sqnorm" in mets:
            p["sqnorms"].append(np.asarray(mets["unit_sqnorm"],
                                           np.float32))
        if "quarantined" in mets:
            p["quars"].append(np.asarray(mets["quarantined"], np.float32))
        p["chunk"] = j + 1

    def finish_round(self):
        from .server import RoundRecord
        p = self._partial
        if p is None:
            raise RuntimeError("no cohort round in flight; begin_round "
                               "first")
        server = self.server
        r = p["round"]
        c = self.fl.n_clients
        t0 = p["t0"]
        if p["skipped"]:
            # loss 0.0 (NOT NaN): a skipped round must never leak NaN
            # into loss summaries / EMA consumers downstream
            rec = RoundRecord(r, 0.0, None,
                              time.perf_counter() - t0, 0.0, 0.0,
                              n_participants=0, skipped=True,
                              dropped=True, effective_weights=p["eff_w"])
            server.sel_history.append(
                np.zeros((c, self.assign.n_units), np.float32))
            metrics = None
        else:
            if p["chunk"] != self.n_chunks:
                raise RuntimeError(
                    f"round {r} has streamed {p['chunk']}/"
                    f"{self.n_chunks} chunks; step_chunk the rest first")
            losses = jnp.concatenate(
                [jnp.asarray(x) for x in p["losses"]]) \
                if len(p["losses"]) > 1 else jnp.asarray(p["losses"][0])
            w_fin = p["w"]
            quar_full = None
            if p["quars"]:
                quar_full = np.concatenate(p["quars"])
                # quarantined clients already accumulated with weight 0
                # per chunk; zero them in the full-cohort denominator too
                w_fin = w_fin * jnp.asarray(1.0 - quar_full)
            new_params, loss_mean = self.programs.finalize(
                server.global_params(), p["acc"], p["sel"], w_fin,
                losses)
            server.params = new_params   # star topologies: state==params
            server.sel_history.append(np.asarray(p["sel"]))
            metrics = {"loss_mean": loss_mean, "loss_per_client": losses,
                       "sel": p["sel"]}
            if p["sqnorms"]:
                metrics["unit_sqnorm"] = np.concatenate(p["sqnorms"],
                                                        axis=0)
            if quar_full is not None:
                metrics["quarantined"] = quar_full
            ev = None
            if server.eval_fn is not None:
                ev = float(server.eval_fn(server.global_params()))
            rec = RoundRecord(r, float(loss_mean), ev,
                              time.perf_counter() - t0, 0.0, 0.0,
                              n_participants=p["n_part"],
                              effective_weights=p["eff_w"])
        # selection-state telemetry BEFORE end-of-round hooks, exactly
        # like the sync loop (a Checkpointer hook must save post-round
        # state for bit-exact mid-fit resume)
        server.update_sel_state(server._round_telemetry(r, metrics,
                                                        p["eff_w"]))
        self._update_fleet(p, metrics)
        # clear the in-flight round BEFORE end hooks: a ChaosHook kill
        # must not leave a completed round marked partial, or the resumed
        # run would double-apply it
        self._partial = None
        for hook in server.hooks:
            hook.on_round_end(server, rec, metrics)
        rec.seconds = time.perf_counter() - t0
        server.history.append(rec)
        server._trim_history()
        return rec

    def _update_fleet(self, p: Dict[str, Any],
                      metrics: Optional[Dict]) -> None:
        """Fold the round into the fleet EMAs at the *sampled* ids.
        Dropped clients (effective weight 0) contributed nothing and
        update nothing, matching the aggregation and sel-state rules."""
        f = self.fleet
        if metrics is not None:
            active = np.asarray(p["eff_w"], np.float32) > 0
            if "quarantined" in metrics:
                # a quarantined upload contributed nothing to the model;
                # its (possibly poisoned) telemetry must not steer the
                # sampler either
                active &= np.asarray(metrics["quarantined"],
                                     np.float32) <= 0
            act = p["ids"][active]
            if act.size:
                e = self.fl.sampler_ema
                seen = f.counts[act] > 0
                loss = np.asarray(metrics["loss_per_client"],
                                  np.float32)[active]
                f.loss_ema[act] = np.where(
                    seen, e * f.loss_ema[act] + (1.0 - e) * loss, loss)
                if "unit_sqnorm" in metrics:
                    norm = np.asarray(metrics["unit_sqnorm"],
                                      np.float32)[active].sum(axis=1)
                    f.norm_ema[act] = np.where(
                        seen, e * f.norm_ema[act] + (1.0 - e) * norm,
                        norm)
                f.counts[act] += 1
        f.round += 1

    # -- composed loops ---------------------------------------------------

    def run_round(self, batch_fn: Callable[[int, np.ndarray], Any],
                  weights=None):
        """One full round; resumes a restored mid-round partial (whose
        hooks and key draws already happened) instead of re-beginning."""
        if self._partial is None:
            self.begin_round(weights)
        p = self._partial
        while not p["skipped"] and p["chunk"] < self.n_chunks:
            self.step_chunk(batch_fn)
        return self.finish_round()

    def run(self, rounds: int, batch_fn: Callable[[int, np.ndarray], Any],
            weights=None, log_every: int = 0):
        from .server import RoundLogger
        server = self.server
        extra = [RoundLogger(log_every,
                             total=len(server.history) + rounds,
                             base=len(server.history))] if log_every else []
        server.hooks.extend(extra)
        try:
            for _ in range(rounds):
                self.run_round(batch_fn, weights)
        finally:
            for h in extra:
                server.hooks.remove(h)
        for hook in server.hooks:
            hook.on_fit_end(server, server.history)
        return server.history

    # -- checkpoint state (ckpt/store.py) ---------------------------------

    def checkpoint_state(self) -> Tuple[Dict[str, Any], PyTree]:
        """(json metadata, array pytree): fleet EMAs always, plus the
        in-flight round's carry when saving at a chunk boundary."""
        meta: Dict[str, Any] = {
            "fleet_round": int(self.fleet.round),
            "n_registered": int(self.n_registered),
        }
        arrays: Dict[str, Any] = {"fleet": {
            "loss_ema": self.fleet.loss_ema,
            "norm_ema": self.fleet.norm_ema,
            "counts": self.fleet.counts,
        }}
        p = self._partial
        if p is not None:
            meta["partial"] = {
                "round": int(p["round"]), "chunk": int(p["chunk"]),
                "n_part": int(p["n_part"]),
                "eff_w": [float(x) for x in p["eff_w"]],
                "skipped": bool(p["skipped"]),
                "scored": bool(self.programs.scoring),
                "gated": bool(p["quars"]),
            }
            pa: Dict[str, Any] = {
                "ids": np.asarray(p["ids"], np.int32),
                "w": np.asarray(p["w"], np.float32),
            }
            if not p["skipped"]:
                pa["sel"] = np.asarray(p["sel"], np.float32)
                pa["acc"] = jax.tree_util.tree_map(np.asarray, p["acc"])
                if p["losses"]:
                    pa["losses"] = np.concatenate(p["losses"])
                if p["sqnorms"]:
                    pa["sqnorm"] = np.concatenate(p["sqnorms"], axis=0)
                if p["quars"]:
                    pa["quar"] = np.concatenate(p["quars"])
            arrays["partial"] = pa
        return meta, arrays

    def arrays_template(self, meta: Dict[str, Any]) -> PyTree:
        sds = jax.ShapeDtypeStruct
        n_r = int(meta["n_registered"])
        tpl: Dict[str, Any] = {"fleet": {
            "loss_ema": sds((n_r,), jnp.float32),
            "norm_ema": sds((n_r,), jnp.float32),
            "counts": sds((n_r,), jnp.int32),
        }}
        pm = meta.get("partial")
        if pm is not None:
            c = self.fl.n_clients
            pa: Dict[str, Any] = {"ids": sds((c,), jnp.int32),
                                  "w": sds((c,), jnp.float32)}
            if not pm["skipped"]:
                pa["sel"] = sds((c, self.assign.n_units), jnp.float32)
                pa["acc"] = jax.eval_shape(self.programs.acc_init,
                                           self.server.global_params())
                done = int(pm["chunk"]) * self.chunk
                if done:
                    pa["losses"] = sds((done,), jnp.float32)
                    if pm.get("scored"):
                        pa["sqnorm"] = sds((done, self.assign.n_units),
                                           jnp.float32)
                    if pm.get("gated"):
                        pa["quar"] = sds((done,), jnp.float32)
            tpl["partial"] = pa
        return tpl

    def restore_state(self, meta: Dict[str, Any], arrays: PyTree):
        if int(meta["n_registered"]) != self.n_registered:
            raise ValueError(
                f"checkpoint fleet has {meta['n_registered']} registered "
                f"clients, this engine {self.n_registered}; restore with "
                "the original FLConfig.n_registered")
        fa = arrays["fleet"]
        # np.array (copy): views of jnp arrays are read-only, and the
        # fleet EMAs are updated in place every round
        self.fleet = FleetState(
            loss_ema=np.array(fa["loss_ema"], np.float32),
            norm_ema=np.array(fa["norm_ema"], np.float32),
            counts=np.array(fa["counts"], np.int32),
            round=int(meta["fleet_round"]))
        pm = meta.get("partial")
        if pm is None:
            self._partial = None
            return
        pa = arrays["partial"]
        p: Dict[str, Any] = {
            "round": int(pm["round"]), "t0": time.perf_counter(),
            "ids": np.asarray(pa["ids"], np.int32),
            "w": jnp.asarray(np.asarray(pa["w"], np.float32)),
            "eff_w": [float(x) for x in pm["eff_w"]],
            "n_part": int(pm["n_part"]), "chunk": int(pm["chunk"]),
            "skipped": bool(pm["skipped"]),
            "losses": [], "sqnorms": [], "quars": [],
            "sel": None, "acc": None,
        }
        if not p["skipped"]:
            p["sel"] = jnp.asarray(np.asarray(pa["sel"], np.float32))
            p["acc"] = jax.tree_util.tree_map(jnp.asarray, pa["acc"])
            if "losses" in pa:
                p["losses"] = [np.asarray(pa["losses"], np.float32)]
            if "sqnorm" in pa:
                p["sqnorms"] = [np.asarray(pa["sqnorm"], np.float32)]
            if "quar" in pa:
                p["quars"] = [np.asarray(pa["quar"], np.float32)]
        self._partial = p
