"""The ``Federation`` facade: one object that owns a federated run.

Every entry point (launcher, example, benchmark) used to re-implement
the same wiring by hand: model init -> unit assignment -> loader ->
``build_round_step`` -> ``Server``.  ``Federation.from_config`` does
that wiring once, for both model worlds:

* a zoo :class:`ArchConfig` (``repro.configs``) — model comes from
  ``repro.models.get_model``, units from ``build_units_zoo``;
* a :class:`ModelSpec` — any hand-rolled model (the paper's VGG16 /
  IMDB / CASA live in ``repro.models.paper_models``), units from
  ``build_units_flat``.

Usage::

    fed = Federation.from_config(cfg, fl, data=loader, eval_fn=acc)
    fed.fit(rounds=20, log_every=1)
    fed.comm_summary()

Strategy selection is the registered-plugin name in ``fl.strategy``
(see core/strategies.py); the federation topology is the registered
plugin name in ``fl.topology`` (core/topology.py: ``hub`` |
``hierarchical`` | ``gossip``).  Pass ``strategy=`` / ``topology=`` to
override either with an unregistered instance.  Cross-cutting behaviour
(straggler dropout, checkpointing, logging, custom metrics) attaches as
``ServerHook``s.

The sparse round step (DESIGN.md §7) is two more ``FLConfig`` knobs
that flow straight through the facade: ``packed=True`` runs
hub/hierarchical rounds on packed trained-unit slot buffers (zero
optimizer state for frozen stacked rows, shrunken cross-client
reduce — bit-exact with the default dense-masked path), and
``fused_agg`` selects the fused Pallas aggregation kernel ("auto":
compiled on TPU/GPU, jnp reference elsewhere).

Semi-async buffered rounds (DESIGN.md §8) are three further knobs:
``async_buffer=K`` switches ``fit`` to FedBuff-style flush rounds (K
buffered packed updates per global step), ``staleness``/
``staleness_alpha`` pick the registered stale-delta reweighting rule,
and ``client_delay_dist`` the simulated client-latency distribution
(``"pareto[:a]"`` for the heavy-tailed straggler regime).

Scored selection (DESIGN.md §11) needs no knob at all beyond the
strategy name: a stateful strategy (``score_weighted`` /
``depth_dropout`` / ``successive``) makes the ``Server`` own a
``SelectionState`` pytree, turns on the gradient-norm telemetry inside
the round step, and checkpoints carry the state (bit-exact mid-fit
restore).  ``score_ema`` / ``score_every`` tune the EMA decay and the
update cadence.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from ..data import FederatedLoader
from .federation import FLConfig, build_round_step
from .masking import UnitAssignment, build_units_flat, build_units_zoo
from .server import RoundRecord, Server, ServerHook
from .strategies import SelectionStrategy
from .topology import Topology, resolve_topology

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A model described by plain functions (non-zoo architectures).

    ``unit_order`` is either the explicit freeze-unit order (top-level
    param keys) or a callable ``params -> order`` (e.g.
    ``paper_models.vgg16_units``).
    """
    name: str
    init_params: Callable[[Any], PyTree]            # PRNGKey -> params
    loss_fn: Callable                               # (params, batch) -> (loss, aux)
    unit_order: Union[Sequence[str], Callable[[PyTree], Sequence[str]]]


class Federation:
    """Owns params, unit assignment, compiled round step, server, data."""

    def __init__(self, *, loss_fn: Callable, params: PyTree,
                 assign: UnitAssignment, fl: FLConfig,
                 loader: Optional[FederatedLoader] = None,
                 eval_fn: Optional[Callable] = None,
                 loss_kwargs: Optional[Dict] = None, seed: int = 0,
                 dropout_rate: float = 0.0,
                 hooks: Sequence[ServerHook] = (),
                 strategy: Union[str, SelectionStrategy, None] = None,
                 scores: Optional[jnp.ndarray] = None,
                 topology: Union[str, Topology, None] = None,
                 incarnation: int = 0):
        self.fl = fl
        self.assign = assign
        self.loader = loader
        self.topology = resolve_topology(topology if topology is not None
                                         else fl.topology)
        round_step = build_round_step(loss_fn, assign, fl, loss_kwargs,
                                      strategy=strategy, scores=scores,
                                      topology=self.topology)
        self.server = Server(round_step, assign, fl, params,
                             eval_fn=eval_fn, seed=seed,
                             dropout_rate=dropout_rate, hooks=hooks,
                             topology=self.topology, strategy=strategy)
        # fault-injection chaos axis (DESIGN.md §14): the injector is a
        # pure function of (seed, incarnation, coordinates), so a
        # restarted process with incarnation+1 replays a *different*
        # kill schedule while the training key streams stay identical
        injector = None
        if fl.faults:
            from .faults import FaultInjector
            injector = FaultInjector(fl.faults, seed=seed,
                                     incarnation=incarnation)
        self.server.fault_injector = injector
        if fl.async_buffer:
            # semi-async buffered rounds (DESIGN.md §8): the engine owns
            # the simulated-delay scheduler, per-version selection keys
            # and the FedBuff-style buffer; one fit "round" = one flush
            from .async_agg import AsyncRoundEngine, build_cohort_step
            from .faults import gate_enabled
            select_fn, cohort_fn, _ = build_cohort_step(
                loss_fn, assign, fl, loss_kwargs, strategy=strategy,
                scores=scores)
            base_flush = self.topology.build_buffered_flush(assign, fl)
            flush_fn, gated = base_flush, False
            if gate_enabled(fl):
                from .aggregation import gate_packed_updates

                def flush_fn(g, pdeltas, rows, valid, sel, weights,
                             clients, _base=base_flush):
                    pdeltas, gw, quar = gate_packed_updates(
                        assign, pdeltas, valid, weights,
                        fl.max_delta_norm)
                    return _base(g, pdeltas, rows, valid, sel, gw,
                                 clients), quar
                gated = True
            self.server.attach_async_engine(AsyncRoundEngine(
                self.server, assign, fl, select_fn=select_fn,
                cohort_fn=cohort_fn, flush_fn=flush_fn,
                seed=seed, gated=gated))
        if fl.uses_cohort_engine():
            # fleet-scale cohort engine (DESIGN.md §13): samples the
            # round's cohort out of n_registered clients and streams it
            # through the round in cohort_chunk-sized compiled chunks
            # (mutually exclusive with async_buffer — FLConfig validates)
            from .cohort import CohortEngine, build_cohort_programs
            programs = build_cohort_programs(
                loss_fn, assign, fl, loss_kwargs, strategy=strategy,
                scores=scores, topology=self.topology)
            self.server.attach_cohort_engine(CohortEngine(
                self.server, assign, fl, programs=programs, seed=seed))
        if injector is not None:
            # appended LAST so a user Checkpointer hook has already
            # saved the round before an injected kill can raise
            from .faults import ChaosHook
            self.server.hooks.append(ChaosHook(injector))

    # -- construction -----------------------------------------------------

    @classmethod
    def from_config(cls, cfg, fl: FLConfig, *, data=None, seed: int = 0,
                    eval_fn: Optional[Callable] = None,
                    loss_kwargs: Optional[Dict] = None,
                    batch_size: int = 8, steps_per_round: int = 2,
                    **kwargs) -> "Federation":
        """Wire a full federated run from a config.

        ``cfg`` is a zoo ``ArchConfig`` or a :class:`ModelSpec`.
        ``data`` is a :class:`FederatedLoader`, or a list of per-client
        array dicts (then ``batch_size``/``steps_per_round`` apply), or
        None (supply batches to ``run_round`` yourself).
        Remaining ``kwargs`` go to the constructor (hooks,
        dropout_rate, strategy, scores, topology).
        """
        key = jax.random.PRNGKey(seed)
        if isinstance(cfg, ModelSpec):
            params = cfg.init_params(key)
            order = cfg.unit_order(params) if callable(cfg.unit_order) \
                else list(cfg.unit_order)
            assign = build_units_flat(params, order)
            loss_fn = cfg.loss_fn
        elif hasattr(cfg, "family"):
            from ..models import get_model
            model = get_model(cfg)
            params = model.init_params(key)
            assign = build_units_zoo(cfg, params)
            loss_fn = model.loss_fn
            if loss_kwargs is None:
                # CPU-host default; pod launchers pass their own
                loss_kwargs = {} if cfg.family == "ssm" else \
                    {"attn_impl": "reference"}
        else:
            raise TypeError(
                f"cfg must be an ArchConfig or ModelSpec, got {type(cfg)}")
        loader = cls._as_loader(data, batch_size=batch_size,
                                steps_per_round=steps_per_round, seed=seed)
        return cls(loss_fn=loss_fn, params=params, assign=assign, fl=fl,
                   loader=loader, eval_fn=eval_fn, loss_kwargs=loss_kwargs,
                   seed=seed, **kwargs)

    @staticmethod
    def _as_loader(data, *, batch_size: int, steps_per_round: int,
                   seed: int) -> Optional[FederatedLoader]:
        if data is None or isinstance(data, FederatedLoader):
            return data
        return FederatedLoader(list(data), batch_size=batch_size,
                               steps_per_round=steps_per_round, key=seed)

    # -- the run ----------------------------------------------------------

    def fit(self, rounds: int, *, log_every: int = 0,
            weights=None) -> List[RoundRecord]:
        """Run ``rounds`` federated rounds off the attached loader.

        In buffered-async mode (``fl.async_buffer > 0``) a "round" is
        one buffer flush, and the loader is indexed by each client's own
        dispatch window (the engine carries per-client counters across
        ``fit`` calls and restores), not a shared round counter.
        """
        if self.loader is None:
            raise ValueError("Federation has no data attached; pass "
                             "data= to from_config or use run_round")
        if weights is None:
            weights = jnp.asarray(self.loader.weights())
        if self.server.cohort_engine is not None:
            # cohort-engine mode: the loader holds the registered fleet
            # and serves one chunk of sampled clients at a time; the
            # engine indexes it by absolute round (resume-safe), so no
            # history base is added here
            return self.server.run(
                rounds, lambda r, ids: jax.tree_util.tree_map(
                    jnp.asarray, self.loader.client_batches(r, ids)),
                weights=weights, log_every=log_every)
        base = 0 if self.server.async_engine is not None \
            else len(self.server.history)
        return self.server.run(
            rounds, lambda r: jax.tree_util.tree_map(
                jnp.asarray, self.loader.round_batches(base + r)),
            weights=weights, log_every=log_every)

    def run_round(self, client_batches, weights=None) -> RoundRecord:
        return self.server.run_round(client_batches, weights)

    def evaluate(self) -> Optional[float]:
        if self.server.eval_fn is None:
            return None
        return float(self.server.eval_fn(self.server.global_params()))

    def comm_summary(self) -> Dict[str, float]:
        return self.server.comm_summary()

    # -- state ------------------------------------------------------------

    @property
    def params(self) -> PyTree:
        """Single-model view (the mean replica under gossip)."""
        return self.server.global_params()

    @property
    def state(self) -> PyTree:
        """The raw topology state the server carries across rounds."""
        return self.server.params

    @property
    def history(self) -> List[RoundRecord]:
        return self.server.history

    def save(self, path: str, extra: Optional[Dict] = None) -> None:
        from ..ckpt import save_server_state
        save_server_state(path, self.server, extra=extra)

    def restore(self, path: str) -> Dict:
        from ..ckpt import restore_server_state
        return restore_server_state(path, self.server)
