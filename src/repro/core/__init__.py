"""The paper's contribution: federated partial-layer freezing (FedPLF).

freezing   — per-round layer-selection strategies (Alg. 2 line 3)
masking    — freeze units over param pytrees, mask trees
aggregation— FedAvg / participation-weighted masked FedAvg
client     — ClientUpdate (Alg. 2): masked local training
federation — the compiled federated round step
server     — round orchestration (Alg. 1)
comm       — exact transfer-byte accounting (Table 4)
"""
from . import freezing, masking, aggregation, client, federation, server, comm  # noqa: F401
from .federation import FLConfig, build_round_step, build_fullmodel_round_step  # noqa: F401
from .masking import build_units, build_units_zoo, build_units_flat, mask_tree, apply_mask, UnitAssignment  # noqa: F401
