"""The paper's contribution: federated partial-layer freezing (FedPLF).

strategies — pluggable layer-selection strategies + registry (Alg. 2 line 3)
topology   — pluggable federation topologies + registry (hub/hierarchical/gossip)
freezing   — functional wrappers over the strategy registry
masking    — freeze units over param pytrees, mask trees
aggregation— FedAvg / participation-weighted masked FedAvg (flat + two-stage)
client     — ClientUpdate (Alg. 2): masked local training
federation — the compiled federated round step
server     — round orchestration (Alg. 1) + composable ServerHooks
async_agg  — FedBuff-style semi-async buffered rounds + staleness registry
cohort     — fleet-scale chunk-streamed cohort engine + sampler registry
session    — the Federation facade (from_config -> fit/evaluate/comm)
comm       — exact transfer-byte accounting (Table 4), per topology
faults     — seeded fault-injection chaos axis + fault-tolerant defenses
codecs     — uplink compression codec axis over packed trained-slot deltas
"""
from . import (freezing, masking, aggregation, client, federation, server,  # noqa: F401
               comm, strategies, session, topology, async_agg, cohort,
               faults, codecs)
from .federation import FLConfig, build_round_step, build_fullmodel_round_step  # noqa: F401
from .masking import (build_units, build_units_zoo, build_units_flat,  # noqa: F401
                      mask_tree, apply_mask, UnitAssignment,
                      slot_plan, slot_gather, slot_merge)
from .session import Federation, ModelSpec  # noqa: F401
from .server import (Server, ServerHook, RoundRecord, StragglerDropout,  # noqa: F401
                     CommAccounting, RoundLogger, Checkpointer)
from .strategies import (SelectionStrategy, SelectionContext, Synchronized,  # noqa: F401
                         SelectionState, NormTelemetry, ScoredStrategy,
                         register_strategy, unregister_strategy,
                         registered_strategies, get_strategy,
                         resolve_strategy, UnknownStrategyError)
from .topology import (Topology, register_topology, unregister_topology,  # noqa: F401
                       registered_topologies, get_topology,
                       resolve_topology, UnknownTopologyError,
                       ring_mixing_matrix)
from .async_agg import (AsyncRoundEngine, BufferedAggregator,  # noqa: F401
                        BufferedUpdate, DelayScheduler,
                        UnknownStalenessError, build_cohort_step,
                        get_staleness, register_staleness,
                        registered_staleness, staleness_weights,
                        unregister_staleness)
from .cohort import (ClientSampler, CohortContext, CohortEngine,  # noqa: F401
                     FleetState, UnknownClientSamplerError,
                     build_cohort_programs, fleet_init,
                     get_client_sampler, register_client_sampler,
                     registered_client_samplers, resolve_client_sampler,
                     unregister_client_sampler)
from .codecs import (Codec, UnknownCodecError, available_codecs,  # noqa: F401
                     build_codec_transform, codec_unit_bytes,
                     encoded_wire_bytes, get_codec, init_codec_state,
                     register_codec, resolve_codec, unregister_codec)
from .faults import (ChaosHook, ClientCrashed, Fault, FaultInjector,  # noqa: F401
                     ServerKilled, UnknownFaultError, chaos_inject,
                     get_fault, parse_faults, register_fault,
                     registered_faults, run_with_restarts,
                     unregister_fault)
