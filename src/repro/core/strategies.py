"""Pluggable layer-selection strategies (the paper's Alg. 2 line 3 as a
plugin point).

A **strategy** decides, per round, which freeze units each client
trains.  The paper's four variants (random subsets, fixed-last transfer
learning, weighted selection, full-model baseline) are registered
plugins here; adding a new one (depth dropout, successive layer
training, ...) is a subclass + ``@register_strategy`` — no change to
``federation.py`` or any launcher.

Contract: ``select_row(key, ctx) -> (U,)`` 0/1 float32 over freeze
units, traced-friendly (the whole federated round compiles as one
``jit``).  ``n_train`` is static, so masks have static sparsity and the
comm accounting stays exact.

``Synchronized`` wraps any stochastic strategy so all clients of a
round share one subset (seeded by the round key) — the beyond-paper
variant that lets the cross-client collective shrink (core/comm.py).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SelectionContext:
    """Static per-run facts a strategy may consult."""
    n_clients: int
    n_units: int
    n_train: int                       # N_l in the paper
    scores: Optional[jnp.ndarray] = None   # (U,) per-unit scores (weighted)


class SelectionStrategy:
    """Base class for layer-selection plugins.

    Subclasses set ``name`` and implement ``select_row``.  Flags:

    * ``stochastic`` — row depends on the PRNG key; False means the row
      is a pure function of the context (fixed_last, full) and is
      broadcast to all clients.
    * ``dense`` — every unit is trained every round by construction
      (the ``full`` baseline).  The round builder uses this to fall back
      to plain FedAvg + unmasked local training, which is bit-exact
      with the conventional FedAvg baseline.
    """

    name: ClassVar[str] = ""
    stochastic: ClassVar[bool] = True
    dense: ClassVar[bool] = False

    def select_row(self, key, ctx: SelectionContext) -> jnp.ndarray:
        raise NotImplementedError

    def select(self, key, ctx: SelectionContext) -> jnp.ndarray:
        """(C, U) selection matrix for one round.

        Stochastic strategies fold each client's index into the round
        key (paper semantics: independent per-client selection);
        deterministic ones broadcast a single row.
        """
        if not self.stochastic:
            row = self.select_row(key, ctx)
            return jnp.broadcast_to(row, (ctx.n_clients, ctx.n_units))
        keys = jax.random.split(key, ctx.n_clients)
        return jax.vmap(lambda k: self.select_row(k, ctx))(keys)

    def __repr__(self):
        return f"{type(self).__name__}(name={self.name!r})"


class Synchronized(SelectionStrategy):
    """All clients of a round share the inner strategy's subset."""

    def __init__(self, inner: "SelectionStrategy"):
        self.inner = inner
        self.name = f"synchronized({inner.name})"

    @property
    def dense(self):                       # type: ignore[override]
        return self.inner.dense

    def select_row(self, key, ctx):
        return self.inner.select_row(key, ctx)

    def select(self, key, ctx):
        row = self.inner.select_row(key, ctx)
        return jnp.broadcast_to(row, (ctx.n_clients, ctx.n_units))


# ---------------------------------------------------------------------------
# registry

_REGISTRY: Dict[str, SelectionStrategy] = {}


class UnknownStrategyError(ValueError):
    pass


def register_strategy(obj: Union[Type[SelectionStrategy], SelectionStrategy],
                      *, name: Optional[str] = None):
    """Register a strategy class (instantiated with no args) or instance.

    Usable as a decorator::

        @register_strategy
        class Mine(SelectionStrategy):
            name = "mine"
            ...
    """
    strat = obj() if isinstance(obj, type) else obj
    key = name or strat.name
    if not key:
        raise ValueError(f"strategy {obj!r} has no name")
    _REGISTRY[key] = strat
    return obj


def unregister_strategy(name: str):
    _REGISTRY.pop(name, None)


def registered_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_strategy(name: str) -> SelectionStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownStrategyError(
            f"unknown selection strategy {name!r}; registered: "
            f"{', '.join(registered_strategies())}") from None


def resolve_strategy(spec: Union[str, SelectionStrategy],
                     synchronized: bool = False) -> SelectionStrategy:
    """Name or instance -> instance, optionally wrapped in Synchronized."""
    strat = get_strategy(spec) if isinstance(spec, str) else spec
    if synchronized and not isinstance(strat, Synchronized) \
            and strat.stochastic:
        strat = Synchronized(strat)
    return strat


# ---------------------------------------------------------------------------
# built-in strategies (the paper's family)

@register_strategy
class Uniform(SelectionStrategy):
    """Exactly n_train units, uniformly at random per client (paper)."""
    name = "uniform"

    def select_row(self, key, ctx):
        perm = jax.random.permutation(key, ctx.n_units)
        return (perm < ctx.n_train).astype(jnp.float32)


@register_strategy
class FixedLast(SelectionStrategy):
    """Transfer-learning baseline: always the last n_train units."""
    name = "fixed_last"
    stochastic = False

    def select_row(self, key, ctx):
        return (jnp.arange(ctx.n_units) >=
                ctx.n_units - ctx.n_train).astype(jnp.float32)


@register_strategy
class Weighted(SelectionStrategy):
    """Top-n_train by perturbed score (Gumbel top-k ∝ softmax(scores)).

    ``ctx.scores`` defaults to all-zeros, which degenerates to uniform
    sampling — so the strategy is usable before any score signal (e.g.
    gradient norms) is wired in.
    """
    name = "weighted"

    def select_row(self, key, ctx):
        scores = ctx.scores if ctx.scores is not None \
            else jnp.zeros((ctx.n_units,))
        g = jax.random.gumbel(key, (ctx.n_units,))
        ranked = jnp.argsort(-(scores + g))
        return jnp.zeros(ctx.n_units).at[ranked[:ctx.n_train]].set(1.0)


@register_strategy
class Full(SelectionStrategy):
    """Conventional FedAvg baseline: every unit trained by every client."""
    name = "full"
    stochastic = False
    dense = True

    def select_row(self, key, ctx):
        return jnp.ones((ctx.n_units,), jnp.float32)


# the beyond-paper synchronized variant as a named plugin of its own
register_strategy(Synchronized(Uniform()), name="synchronized")
