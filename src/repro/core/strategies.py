"""Pluggable layer-selection strategies (the paper's Alg. 2 line 3 as a
plugin point).

A **strategy** decides, per round, which freeze units each client
trains.  The paper's four variants (random subsets, fixed-last transfer
learning, weighted selection, full-model baseline) are registered
plugins here; adding a new one is a subclass + ``@register_strategy`` —
no change to ``federation.py`` or any launcher.

Contract: ``select_row(key, ctx) -> (U,)`` 0/1 float32 over freeze
units, traced-friendly (the whole federated round compiles as one
``jit``).  ``n_train`` is static, so masks have static sparsity and the
comm accounting stays exact.

**Stateful scored selection** (DESIGN.md §11): strategies that adapt to
live training signal set ``stateful = True`` and implement
``init_state`` / ``update_state`` over a :class:`SelectionState` pytree
(per-unit gradient-norm EMA, per-unit train counts, round index).  The
``Server`` owns the state, threads it into the compiled round step
(where ``ctx.scores`` / ``ctx.state`` become the live values) and feeds
``update_state`` the round's :class:`NormTelemetry` — per-unit squared
gradient norms accumulated inside local training at zero cost when
scoring is off.  Stateless strategies ignore all of it and compile the
identical trace as before (bit-exact, regression-tested).

``Synchronized`` wraps any stochastic strategy so all clients of a
round share one subset (seeded by the round key) — the beyond-paper
variant that lets the cross-client collective shrink (core/comm.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import (ClassVar, Dict, NamedTuple, Optional, Tuple, Type,
                    Union)

import jax
import jax.numpy as jnp

from .registry import unknown_name_message


class SelectionState(NamedTuple):
    """Per-run adaptive selection state (a pytree; checkpointed).

    ``scores`` — (U,) float32 EMA of per-unit gradient norms (the live
    signal the paper's future-work weighted selection calls for);
    ``counts`` — (U,) float32 cumulative (staleness-weighted) count of
    client updates that trained each unit;
    ``round``  — () int32 rounds (sync) / flushes (async) completed.
    """
    scores: jnp.ndarray
    counts: jnp.ndarray
    round: jnp.ndarray


class NormTelemetry(NamedTuple):
    """One round's (or flush's) aggregated gradient-norm signal.

    ``unit_sqnorm`` — (U,) weighted sum over contributing client
    updates of their per-unit squared gradient norms (summed over local
    steps); ``unit_count`` — (U,) the matching weighted count of
    updates that trained each unit; ``unit_raw_count`` — (U,) the
    UNWEIGHTED update count.  Sync rounds weight participants by 1
    (dropped clients 0), so count == raw count; async flushes weight
    each entry by its staleness factor, and the weighted/raw ratio is
    what lets ``update_state`` decay stale evidence by exactly the
    factor the aggregation applied to the stale delta.
    """
    unit_sqnorm: jnp.ndarray
    unit_count: jnp.ndarray
    unit_raw_count: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SelectionContext:
    """Static per-run facts a strategy may consult.

    Inside a scored round step, ``scores``/``state`` are swapped for
    the live :class:`SelectionState` values (traced arrays); outside
    one they keep their build-time values (``None`` by default).
    """
    n_clients: int
    n_units: int
    n_train: int                       # N_l in the paper
    scores: Optional[jnp.ndarray] = None   # (U,) per-unit scores
    state: Optional[SelectionState] = None  # live state (scored rounds)
    score_ema: float = 0.9             # EMA decay for update_state


def _uniform_row(key, ctx: SelectionContext) -> jnp.ndarray:
    """Exactly n_train units, uniformly at random — the shared draw of
    ``uniform`` and every score strategy's no-signal degeneration (so
    "no scores" is *bit-exact* with uniform, regression-tested)."""
    perm = jax.random.permutation(key, ctx.n_units)
    return (perm < ctx.n_train).astype(jnp.float32)


def _topk_row(key, ranking_scores: jnp.ndarray,
              ctx: SelectionContext) -> jnp.ndarray:
    """Gumbel top-k: exactly n_train units, w/o replacement, biased by
    ``ranking_scores`` — keeps the static sparsity the packed round
    path (DESIGN.md §7) and comm accounting rely on."""
    g = jax.random.gumbel(key, (ctx.n_units,))
    ranked = jnp.argsort(-(ranking_scores + g))
    return jnp.zeros(ctx.n_units).at[ranked[:ctx.n_train]].set(1.0)


class SelectionStrategy:
    """Base class for layer-selection plugins.

    Subclasses set ``name`` and implement ``select_row``.  Flags:

    * ``stochastic`` — row depends on the PRNG key; False means the row
      is a pure function of the context (fixed_last, full) and is
      broadcast to all clients.
    * ``dense`` — every unit is trained every round by construction
      (the ``full`` baseline).  The round builder uses this to fall back
      to plain FedAvg + unmasked local training, which is bit-exact
      with the conventional FedAvg baseline.
    * ``stateful`` — the strategy consumes per-round state: the server
      threads a :class:`SelectionState` through the compiled round step
      (live ``ctx.scores``/``ctx.state``) and calls ``update_state``
      once per round/flush with that round's :class:`NormTelemetry`
      (``None`` on skipped or off-cadence rounds — the round counter
      still advances).
    """

    name: ClassVar[str] = ""
    stochastic: ClassVar[bool] = True
    dense: ClassVar[bool] = False
    stateful: ClassVar[bool] = False
    deprecated: ClassVar[Optional[str]] = None

    def select_row(self, key, ctx: SelectionContext) -> jnp.ndarray:
        raise NotImplementedError

    def select(self, key, ctx: SelectionContext) -> jnp.ndarray:
        """(C, U) selection matrix for one round.

        Stochastic strategies fold each client's index into the round
        key (paper semantics: independent per-client selection);
        deterministic ones broadcast a single row.
        """
        if not self.stochastic:
            row = self.select_row(key, ctx)
            return jnp.broadcast_to(row, (ctx.n_clients, ctx.n_units))
        keys = jax.random.split(key, ctx.n_clients)
        return jax.vmap(lambda k: self.select_row(k, ctx))(keys)

    # -- stateful contract (no-ops for stateless strategies) --------------

    def init_state(self, ctx: SelectionContext) -> Optional[SelectionState]:
        """Fresh state for a run, or None for stateless strategies."""
        return None

    def update_state(self, state: SelectionState, ctx: SelectionContext,
                     telemetry: Optional[NormTelemetry]) -> SelectionState:
        """Fold one round's telemetry into the state (see ScoredStrategy)."""
        return state

    def __repr__(self):
        return f"{type(self).__name__}(name={self.name!r})"


class ScoredStrategy(SelectionStrategy):
    """Shared state engine of the score-driven strategies.

    ``scores`` is an EMA of observed per-unit gradient norms: a unit
    trained this round moves toward ``sqrt(sqnorm / count)`` (its mean
    accumulated squared norm per contributing update; within a flush,
    fresher entries dominate the mean through their larger staleness
    weight) with step ``(1 - ctx.score_ema) * confidence``, where
    ``confidence = count / raw_count`` is the mean staleness factor of
    the round's observations — 1 for a synchronous round, so stale
    evidence moves the EMA by exactly the factor the aggregation
    applied to the stale delta (a fully-decayed update moves it not at
    all).  A never-before-seen unit adopts its first observation
    outright (no zero-bias warmup); untrained units keep their score.
    ``counts`` accumulates the (staleness-weighted) per-unit update
    counts, ``round`` the rounds/flushes completed.
    """

    stateful = True

    def init_state(self, ctx):
        u = ctx.n_units
        return SelectionState(scores=jnp.zeros((u,), jnp.float32),
                              counts=jnp.zeros((u,), jnp.float32),
                              round=jnp.zeros((), jnp.int32))

    def update_state(self, state, ctx, telemetry):
        new_round = state.round + 1
        if telemetry is None:
            return state._replace(round=new_round)
        sqn = jnp.asarray(telemetry.unit_sqnorm, jnp.float32)
        cnt = jnp.asarray(telemetry.unit_count, jnp.float32)
        raw = jnp.asarray(telemetry.unit_raw_count, jnp.float32)
        observed = cnt > 0
        norm = jnp.sqrt(sqn / jnp.maximum(cnt, 1e-9))
        conf = cnt / jnp.maximum(raw, 1e-9)      # mean staleness factor
        step = (1 - ctx.score_ema) * conf
        seen_before = state.counts > 0
        ema = jnp.where(seen_before,
                        (1 - step) * state.scores + step * norm, norm)
        return SelectionState(
            scores=jnp.where(observed, ema, state.scores),
            counts=state.counts + cnt,
            round=new_round)

    @staticmethod
    def _round_index(ctx: SelectionContext) -> jnp.ndarray:
        return (ctx.state.round if ctx.state is not None
                else jnp.zeros((), jnp.int32))


class Synchronized(SelectionStrategy):
    """All clients of a round share the inner strategy's subset."""

    def __init__(self, inner: "SelectionStrategy"):
        self.inner = inner
        self.name = f"synchronized({inner.name})"

    @property
    def dense(self):                       # type: ignore[override]
        return self.inner.dense

    @property
    def stateful(self):                    # type: ignore[override]
        return self.inner.stateful

    def select_row(self, key, ctx):
        return self.inner.select_row(key, ctx)

    def select(self, key, ctx):
        row = self.inner.select_row(key, ctx)
        return jnp.broadcast_to(row, (ctx.n_clients, ctx.n_units))

    def init_state(self, ctx):
        return self.inner.init_state(ctx)

    def update_state(self, state, ctx, telemetry):
        return self.inner.update_state(state, ctx, telemetry)


# ---------------------------------------------------------------------------
# registry

_REGISTRY: Dict[str, SelectionStrategy] = {}


class UnknownStrategyError(ValueError):
    pass


def register_strategy(obj: Union[Type[SelectionStrategy], SelectionStrategy],
                      *, name: Optional[str] = None):
    """Register a strategy class (instantiated with no args) or instance.

    Usable as a decorator::

        @register_strategy
        class Mine(SelectionStrategy):
            name = "mine"
            ...
    """
    strat = obj() if isinstance(obj, type) else obj
    key = name or strat.name
    if not key:
        raise ValueError(f"strategy {obj!r} has no name")
    _REGISTRY[key] = strat
    return obj


def unregister_strategy(name: str):
    _REGISTRY.pop(name, None)


def registered_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_strategy(name: str) -> SelectionStrategy:
    try:
        strat = _REGISTRY[name]
    except KeyError:
        raise UnknownStrategyError(unknown_name_message(
            "selection strategy", name, _REGISTRY)) from None
    if strat.deprecated:
        warnings.warn(f"selection strategy {name!r} is deprecated: "
                      f"{strat.deprecated}", DeprecationWarning,
                      stacklevel=2)
    return strat


def resolve_strategy(spec: Union[str, SelectionStrategy],
                     synchronized: bool = False) -> SelectionStrategy:
    """Name or instance -> instance, optionally wrapped in Synchronized."""
    strat = get_strategy(spec) if isinstance(spec, str) else spec
    if synchronized and not isinstance(strat, Synchronized) \
            and strat.stochastic:
        strat = Synchronized(strat)
    return strat


# ---------------------------------------------------------------------------
# built-in strategies (the paper's family)

@register_strategy
class Uniform(SelectionStrategy):
    """Exactly n_train units, uniformly at random per client (paper)."""
    name = "uniform"

    def select_row(self, key, ctx):
        return _uniform_row(key, ctx)


@register_strategy
class FixedLast(SelectionStrategy):
    """Transfer-learning baseline: always the last n_train units."""
    name = "fixed_last"
    stochastic = False

    def select_row(self, key, ctx):
        return (jnp.arange(ctx.n_units) >=
                ctx.n_units - ctx.n_train).astype(jnp.float32)


@register_strategy
class Weighted(SelectionStrategy):
    """Deprecated static-score selection (use ``score_weighted``).

    With explicit ``ctx.scores``: top-n_train by perturbed score
    (Gumbel top-k ∝ softmax(scores)) — unchanged, bit-exact with the
    historical behaviour.  With no scores it used to *silently*
    degenerate to uniform sampling; that degeneration is now explicit
    and bit-exact with the ``uniform`` strategy (shared draw,
    regression-tested).  ``score_weighted`` is the live-signal
    replacement the paper's future work calls for.
    """
    name = "weighted"
    deprecated = ("static scores degenerate to uniform without a signal; "
                  "use 'score_weighted' (live gradient-norm EMAs)")

    def select_row(self, key, ctx):
        if ctx.scores is None:
            return _uniform_row(key, ctx)
        return _topk_row(key, ctx.scores, ctx)


@register_strategy
class Full(SelectionStrategy):
    """Conventional FedAvg baseline: every unit trained by every client."""
    name = "full"
    stochastic = False
    dense = True

    def select_row(self, key, ctx):
        return jnp.ones((ctx.n_units,), jnp.float32)


@register_strategy
class ScoreWeighted(ScoredStrategy):
    """The paper's future-work variant: Gumbel top-k over live
    gradient-norm EMAs.

    Scores are standardized before ranking (selection pressure is
    scale-free: a model whose norms are uniformly 10x larger samples
    identically), then perturbed with Gumbel noise — exactly n_train
    units, without replacement, units with larger recent gradient norms
    exponentially more likely.  With no live state attached (bare
    ``build_round_step`` with no server) it degenerates, bit-exactly,
    to ``uniform``.
    """
    name = "score_weighted"

    def select_row(self, key, ctx):
        if ctx.scores is None:
            return _uniform_row(key, ctx)
        s = jnp.asarray(ctx.scores, jnp.float32)
        z = (s - s.mean()) / (s.std() + 1e-6)
        return _topk_row(key, z, ctx)


@register_strategy
class DepthDropout(ScoredStrategy):
    """Depth-biased keep probabilities à la Guo et al. 2023.

    Layer-wise-growth schedule: early rounds concentrate training on
    shallow units (large negative bias on depth), and the bias anneals
    linearly to uniform over ``horizon`` rounds — by then every depth
    competes equally.  Realized as Gumbel top-k (weighted sampling
    *without* replacement) rather than independent Bernoulli keeps, so
    every round trains exactly n_train units and the packed round path
    keeps its static slot budget.
    """
    name = "depth_dropout"
    horizon: ClassVar[int] = 64        # rounds to anneal to uniform
    strength: ClassVar[float] = 4.0    # initial shallow-vs-deep log-odds

    def select_row(self, key, ctx):
        r = self._round_index(ctx).astype(jnp.float32)
        progress = jnp.clip(r / float(self.horizon), 0.0, 1.0)
        depth = jnp.arange(ctx.n_units, dtype=jnp.float32) \
            / float(max(ctx.n_units - 1, 1))
        bias = -(1.0 - progress) * self.strength * depth
        return _topk_row(key, bias, ctx)


@register_strategy
class Successive(ScoredStrategy):
    """Deterministic layer-wise growth à la Pfeiffer et al. 2023.

    Training advances through the depth in phases: phase p trains the
    contiguous window of n_train units starting at ``p * n_train``
    (clipped to the deep end, where it stays), advancing one phase
    every ``phase_rounds`` rounds.  Deterministic — every client of a
    round trains the same window — so the cross-client collective
    shrinks exactly as under synchronized selection.
    """
    name = "successive"
    stochastic = False
    phase_rounds: ClassVar[int] = 4    # rounds per growth phase

    def select_row(self, key, ctx):
        phase = self._round_index(ctx) // self.phase_rounds
        start = jnp.minimum(phase * ctx.n_train,
                            max(ctx.n_units - ctx.n_train, 0))
        idx = jnp.arange(ctx.n_units)
        return ((idx >= start) &
                (idx < start + ctx.n_train)).astype(jnp.float32)


# the beyond-paper synchronized variant as a named plugin of its own
register_strategy(Synchronized(Uniform()), name="synchronized")
