"""Uplink compression codecs — the fourth plugin axis.

A **codec** is a lossy (or identity) transform applied to the packed
trained-slot deltas *before* they cross the WAN, composing a
compression factor on top of the paper's structural freeze factor
(Caldas et al. 2018 show the two multiply).  Symmetric with the other
axes: ``@register_codec`` + a literal ``name``, resolved from
``FLConfig.codec``, encode/decode compiled *into* the round step.

Contract (``build_codec_transform``):

* ``none`` resolves to **no transform at all** — call sites skip the
  codec branch entirely, so the traced program is bitwise-identical to
  the pre-codec paths (property-gated like every prior axis).
* Otherwise the transform maps the round's packed deltas to their
  **decoded round-trip** ``decode(encode(x))`` — the wire never exists
  as bytes in-sim; byte accounting is analytic via
  :func:`codec_unit_bytes` (claimed == encoded wire bytes, asserted in
  tests and ``benchmarks/codec_bench.py``).
* Wire format is per **slot row**: each stacked-leaf slot row (``P =
  prod(leaf.shape[1:])`` params) and each participating scalar leaf
  (``P = prod(leaf.shape)`` params) is one row, encoded independently
  with its own scale / top-k budget.  Pad slots (``valid == 0``) and
  non-participants ship nothing and decode to **exact zeros**, so the
  frozen-slot invariant survives the codec (tracecheck-gated).
* Stochastic codecs (``stochastic = True``) consume a PRNG key —
  uniforms for stochastic rounding are drawn *outside* the Pallas
  kernel so the kernel is pure arithmetic and the jnp reference matches
  bitwise.
* Stateful codecs (``stateful = True``, i.e. ``topk_ef``) thread a
  per-client error-feedback residual pytree (leaves ``(C, *param)``,
  float32) through the round step like PR 5's ``SelectionState``:
  residual rows are gathered into slot space, added (staleness-decayed
  on the async path via the per-client ``decay`` vector), the
  transmitted part subtracted, and the rows scattered back.  Dropped
  clients (``weights == 0``) keep their residual untouched — they never
  uploaded.  The state checkpoints bit-exactly via ``ckpt/store.py``.
"""
from __future__ import annotations

import math
from typing import ClassVar, Dict, Optional, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pytree as pt
from ..kernels.codec import dequantize_unpack, quantize_pack
from .masking import UnitAssignment, _is_leafunit
from .registry import unknown_name_message


class Codec:
    """Base codec: per-row round-trip + per-row wire-byte formula."""

    name: ClassVar[str] = ""
    stateful: ClassVar[bool] = False    # carries per-client EF residual
    stochastic: ClassVar[bool] = False  # consumes a PRNG key

    def row_bytes(self, p: int, fl=None) -> int:
        """Wire bytes for one encoded row of ``p`` float32 params."""
        raise NotImplementedError

    def row_roundtrip(self, x2: jnp.ndarray, key, fl=None) -> jnp.ndarray:
        """decode(encode(x2)) for ``(R, P)`` float32 rows (traced)."""
        raise NotImplementedError


class UnknownCodecError(KeyError):
    pass


_REGISTRY: Dict[str, Codec] = {}


def register_codec(obj: Union[Type[Codec], Codec], *,
                   name: Optional[str] = None):
    """Register a codec class (instantiated with no args) or instance.

    Usable as a decorator::

        @register_codec
        class Mine(Codec):
            name = "mine"
            ...
    """
    codec = obj() if isinstance(obj, type) else obj
    key = name or codec.name
    if not key:
        raise ValueError(f"codec {obj!r} has no name")
    _REGISTRY[key] = codec
    return obj


def unregister_codec(name: str):
    _REGISTRY.pop(name, None)


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownCodecError(unknown_name_message(
            "codec", name, _REGISTRY)) from None


def resolve_codec(spec: Union[str, Codec, None]) -> Codec:
    """Name / instance / None -> codec instance (None means ``none``)."""
    if spec is None:
        return _REGISTRY["none"]
    return get_codec(spec) if isinstance(spec, str) else spec


def available_codecs():
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in codecs


@register_codec
class NoneCodec(Codec):
    """Identity codec: fp32 rows on the wire, no transform compiled in."""

    name = "none"

    def row_bytes(self, p, fl=None):
        return 4 * p

    def row_roundtrip(self, x2, key, fl=None):
        return x2


class _QuantCodec(Codec):
    """Shared per-slot-row absmax stochastic-rounding quantization."""

    stochastic = True
    bits: ClassVar[int] = 8

    def row_roundtrip(self, x2, key, fl=None):
        u = jax.random.uniform(key, x2.shape, jnp.float32)
        packed, scale = quantize_pack(x2, u, self.bits)
        return dequantize_unpack(packed, scale, self.bits, x2.shape[1])


@register_codec
class QInt8(_QuantCodec):
    """int8 stochastic-rounding quantization: 1 byte/param + 4-byte
    per-row scale (absmax/127); round-trip error ≤ scale per element."""

    name = "qint8"
    bits = 8

    def row_bytes(self, p, fl=None):
        return p + 4


@register_codec
class QInt4(_QuantCodec):
    """int4 stochastic-rounding quantization: two nibbles per byte +
    4-byte per-row scale (absmax/7); round-trip error ≤ scale."""

    name = "qint4"
    bits = 4

    def row_bytes(self, p, fl=None):
        return (p + 1) // 2 + 4


@register_codec
class TopKEF(Codec):
    """Per-row top-k sparsification with per-client error feedback.

    Keeps the ``k = max(1, ceil(codec_topk * P))`` largest-magnitude
    entries of each slot row (4-byte value + 4-byte index each); the
    untransmitted remainder accumulates in the client's residual and is
    re-injected next round (staleness-decayed under async).
    Deterministic — ties resolve to the lower index via ``lax.top_k``.
    """

    name = "topk_ef"
    stateful = True

    @staticmethod
    def k_for(p: int, fl=None) -> int:
        frac = getattr(fl, "codec_topk", 0.1) if fl is not None else 0.1
        return max(1, min(p, int(math.ceil(frac * p))))

    def row_bytes(self, p, fl=None):
        return 8 * self.k_for(p, fl)

    def row_roundtrip(self, x2, key, fl=None):
        k = self.k_for(x2.shape[1], fl)
        _, idx = jax.lax.top_k(jnp.abs(x2), k)
        vals = jnp.take_along_axis(x2, idx, axis=1)
        rows = jnp.arange(x2.shape[0])[:, None]
        return jnp.zeros_like(x2).at[rows, idx].set(vals)


# ---------------------------------------------------------------------------
# byte math — claimed bytes == encoded wire bytes, structurally


def codec_unit_bytes(codec: Codec, assign: UnitAssignment, params,
                     fl=None) -> np.ndarray:
    """(U,) int64 — encoded uplink bytes per selected freeze unit.

    Mirrors ``masking.unit_param_counts``: a unit's bytes are the sum of
    its rows' :meth:`Codec.row_bytes` (one row per stacked macro index,
    one per member scalar leaf).  Because ``slot_plan`` marks exactly
    the selected units' rows valid, ``sel @ codec_unit_bytes`` equals
    the actual encoded wire bytes (see :func:`encoded_wire_bytes`) —
    the equality the comm tests assert.  For ``none`` this reduces to
    ``comm.unit_bytes`` exactly (4 bytes/param).
    """
    out = np.zeros(assign.n_units, np.int64)
    for (_, leaf), lu in zip(
            pt.flatten_with_paths(params),
            jax.tree_util.tree_leaves(assign.leaf_units,
                                      is_leaf=_is_leafunit)):
        if lu.kind == "scalar":
            out[lu.base] += codec.row_bytes(int(np.prod(leaf.shape)), fl)
        else:
            per = codec.row_bytes(int(np.prod(leaf.shape[1:])), fl)
            for m in range(leaf.shape[0]):
                out[lu.base + lu.stride * m] += per
    return out


def encoded_wire_bytes(codec: Codec, assign: UnitAssignment, params,
                       valid, fl=None) -> float:
    """Actual encoded uplink bytes for one round, from the slot plan.

    Sums :meth:`Codec.row_bytes` over every *valid* row each client
    ships (stacked ``valid (C, L)``; scalar participation ``(C,)``) —
    the ground truth the analytic ``sel @ codec_unit_bytes`` claim is
    checked against.
    """
    total = 0.0
    for (_, leaf), lu, v in zip(
            pt.flatten_with_paths(params),
            jax.tree_util.tree_leaves(assign.leaf_units,
                                      is_leaf=_is_leafunit),
            jax.tree_util.tree_leaves(valid)):
        if lu.kind == "scalar":
            p = int(np.prod(leaf.shape))
        else:
            p = int(np.prod(leaf.shape[1:]))
        total += codec.row_bytes(p, fl) * float(np.asarray(v).sum())
    return total


# ---------------------------------------------------------------------------
# error-feedback state


def init_codec_state(codec: Codec, params, n_clients: int):
    """Zero per-client residual pytree (``(C, *leaf)`` float32 leaves),
    or None for stateless codecs."""
    if not codec.stateful:
        return None
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_clients,) + p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# the compiled transform


def _expand(v, ndim):
    """Reshape ``v`` to broadcast over ``ndim`` total dims."""
    return jnp.reshape(v, v.shape + (1,) * (ndim - v.ndim))


def build_codec_transform(codec: Codec, assign: UnitAssignment, fl):
    """Codec -> traced round-trip transform, or None for ``none``.

    The transform signature is uniform across codecs::

        transform(pdeltas, rows, valid, weights, key, state, decay)
            -> (decoded_pdeltas, new_state)

    ``pdeltas``/``rows``/``valid`` are the packed round's client-stacked
    trees (stacked leaves ``(C, L, ...)``, scalar leaves ``(C, ...)``);
    ``weights (C,)`` gates residual updates (dropped clients shipped
    nothing); ``key`` feeds stochastic rounding (ignored by
    deterministic codecs); ``state`` is the EF residual pytree (None
    for stateless codecs, and ``new_state`` is None back); ``decay
    (C,)`` scales the re-injected residual (ones on the sync path,
    staleness factors on async).
    """
    if codec.name == "none":
        return None

    def transform(pdeltas, rows, valid, weights, key, state=None,
                  decay=None):
        leaves_d, treedef = jax.tree_util.tree_flatten(pdeltas)
        leaves_lu = jax.tree_util.tree_leaves(assign.leaf_units,
                                              is_leaf=_is_leafunit)
        leaves_r = jax.tree_util.tree_leaves(rows)
        leaves_v = jax.tree_util.tree_leaves(valid)
        if state is not None:
            leaves_s = jax.tree_util.tree_leaves(state)
        else:
            leaves_s = [None] * len(leaves_d)
        out, new_res = [], []
        for i, (lu, d, r, v, res) in enumerate(
                zip(leaves_lu, leaves_d, leaves_r, leaves_v, leaves_s)):
            lk = jax.random.fold_in(key, i) if codec.stochastic else None
            dec, nres = _leaf_roundtrip(codec, fl, lu, d, r, v, res,
                                        weights, lk, decay)
            out.append(dec)
            new_res.append(nres)
        decoded = jax.tree_util.tree_unflatten(treedef, out)
        if state is None:
            return decoded, None
        return decoded, jax.tree_util.tree_unflatten(treedef, new_res)

    return transform


def _leaf_roundtrip(codec, fl, lu, d, r, v, res, weights, key, decay):
    """Round-trip one client-stacked leaf; returns (decoded, new_res)."""
    c = d.shape[0]
    if lu.kind == "scalar":
        p = int(np.prod(d.shape[1:]))
        vm = _expand(v.astype(d.dtype), d.ndim)           # (C, 1, ...)
        if res is not None:
            x = (d + _expand(decay, d.ndim) * res) * vm
        else:
            x = d * vm
        xh = codec.row_roundtrip(x.reshape(c, p), key, fl)
        xh = xh.reshape(d.shape) * vm                     # pads: exact 0
        if res is None:
            return xh, None
        ok = (vm > 0) & (_expand(weights, d.ndim) > 0)
        return xh, jnp.where(ok, x - xh, res)
    # stacked leaf: d (C, L, ...), r (C, L), v (C, L)
    l = d.shape[1]
    p = int(np.prod(d.shape[2:]))
    vm = _expand(v.astype(d.dtype), d.ndim)               # (C, L, 1...)
    if res is not None:
        rr = jax.vmap(lambda s, ri: s[ri])(res, r)        # (C, L, ...)
        x = (d + _expand(decay, d.ndim) * rr) * vm
    else:
        x = d * vm
    xh = codec.row_roundtrip(x.reshape(c * l, p), key, fl)
    xh = xh.reshape(d.shape) * vm                         # pads: exact 0
    if res is None:
        return xh, None
    ok = (vm > 0) & (_expand(weights, d.ndim) > 0)
    upd = jnp.where(ok, x - xh, rr)
    new_res = jax.vmap(lambda s, ri, nu: s.at[ri].set(nu))(res, r, upd)
    return xh, new_res


CODEC_KEY_TAG = 0xC0DEC
