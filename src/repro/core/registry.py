"""Shared plumbing for the three plugin registries.

``core/strategies.py`` (selection), ``core/topology.py`` (federation
topology) and ``core/async_agg.py`` (staleness reweighting) each keep a
name -> plugin dict with the same lookup contract: an unknown name must
fail with an error that *lists the registered names*, so a typo'd CLI
flag or config string is a one-glance fix instead of a bare KeyError.
This module holds the one message formatter all three share — the
uniform wording is load-bearing: tests and users match on it.
"""
from __future__ import annotations

from typing import Iterable


def unknown_name_message(kind: str, name: str,
                         registered: Iterable[str]) -> str:
    """The uniform unknown-plugin error message: ``unknown <kind>
    '<name>'; registered: a, b, c``."""
    return (f"unknown {kind} {name!r}; registered: "
            f"{', '.join(sorted(registered))}")
