"""Synthetic stand-ins for the paper's datasets (offline container).

CIFAR-10 / IMDB / CASA are not downloadable here, so we generate
statistically-matched tasks with the same shapes and cardinalities and a
controllable amount of learnable structure — enough for the paper's
*trends* (partial-layer training ≈ full training) to be reproducible.
Absolute paper accuracies are not claimable (EXPERIMENTS.md §Paper-claims).

* cifar_like : class prototypes + noise, (32,32,3) float images, 10 cls
* imdb_like  : binary sentiment — class-indicative token distributions,
               length-100 int sequences, vocab 20k
* casa_like  : 30 "homes", Non-IID sizes and label mixes (Dirichlet),
               (100, 36) sensor sequences, 10 activities
* lm_tokens  : bigram-structured token streams for the zoo LMs
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def cifar_like(n: int, *, key: int = 0, num_classes: int = 10,
               noise: float = 0.35) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(key)
    protos = rng.normal(0, 1, (num_classes, 32, 32, 3)).astype(np.float32)
    # low-frequency prototypes: smooth across space so convs can pick it up
    for _ in range(2):
        protos = (protos + np.roll(protos, 1, 1) + np.roll(protos, 1, 2)) / 3
    labels = rng.integers(0, num_classes, n)
    x = protos[labels] + rng.normal(0, noise, (n, 32, 32, 3)).astype(np.float32)
    return x.astype(np.float32), labels.astype(np.int32)


def imdb_like(n: int, *, key: int = 0, vocab: int = 20000, maxlen: int = 100,
              signal_tokens: int = 200) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(key)
    labels = rng.integers(0, 2, n)
    # Zipf background + class-indicative tokens sprinkled in
    base = rng.zipf(1.3, (n, maxlen)).clip(1, vocab - 1)
    pos_tokens = rng.integers(100, 100 + signal_tokens, (n, maxlen))
    neg_tokens = rng.integers(100 + signal_tokens, 100 + 2 * signal_tokens,
                              (n, maxlen))
    signal = np.where(labels[:, None] == 1, pos_tokens, neg_tokens)
    use_signal = rng.random((n, maxlen)) < 0.15
    x = np.where(use_signal, signal, base)
    return x.astype(np.int32), labels.astype(np.int32)


def casa_like(n_homes: int = 30, *, key: int = 0, num_classes: int = 10,
              features: int = 36, seq: int = 100,
              min_samples: int = 200, max_samples: int = 1200
              ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-home Non-IID datasets (sizes and label mixes vary)."""
    rng = np.random.default_rng(key)
    protos = rng.normal(0, 1, (num_classes, seq, features)).astype(np.float32)
    for _ in range(2):
        protos = (protos + np.roll(protos, 1, 1)) / 2
    homes = []
    for h in range(n_homes):
        n = int(rng.integers(min_samples, max_samples))
        mix = rng.dirichlet(np.full(num_classes, 0.5))
        labels = rng.choice(num_classes, n, p=mix)
        x = protos[labels] + rng.normal(0, 0.5, (n, seq, features))
        homes.append((x.astype(np.float32), labels.astype(np.int32)))
    return homes


def lm_tokens(n_seqs: int, seq_len: int, vocab: int, *, key: int = 0
              ) -> np.ndarray:
    """Markov token streams: next token ~ structured function of current.

    Cheap to sample at any vocab size and gives an LM a learnable signal
    (per-token bigram successor sets)."""
    rng = np.random.default_rng(key)
    # successor rule: t -> (a*t + b + small noise) mod vocab, 4 branches
    a = np.asarray([1, 3, 7, 11], np.int64)
    b = rng.integers(0, vocab, 4)
    x = np.empty((n_seqs, seq_len), np.int64)
    cur = rng.integers(0, vocab, n_seqs)
    for t in range(seq_len):
        x[:, t] = cur
        branch = rng.integers(0, 4, n_seqs)
        cur = (a[branch] * cur + b[branch]) % vocab
    return x.astype(np.int32)


def lm_batch(n_seqs: int, seq_len: int, vocab: int, *, key: int = 0
             ) -> Dict[str, np.ndarray]:
    toks = lm_tokens(n_seqs, seq_len + 1, vocab, key=key)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
