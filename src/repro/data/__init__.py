from .synthetic import cifar_like, imdb_like, casa_like, lm_tokens, lm_batch  # noqa: F401
from .partition import iid_partition, dirichlet_partition, FederatedLoader  # noqa: F401
