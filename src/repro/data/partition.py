"""Client data partitioning (IID and Dirichlet Non-IID) + round loaders."""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import numpy as np


def iid_partition(n: int, n_clients: int, *, key: int = 0) -> List[np.ndarray]:
    """Equal-size disjoint shards (the paper's CIFAR/IMDB setting)."""
    rng = np.random.default_rng(key)
    idx = rng.permutation(n)
    per = n // n_clients
    return [idx[c * per:(c + 1) * per] for c in range(n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, *,
                        alpha: float = 0.5, key: int = 0,
                        min_per_client: int = 8) -> List[np.ndarray]:
    """Label-skewed Non-IID shards (CASA-style heterogeneity)."""
    rng = np.random.default_rng(key)
    classes = np.unique(labels)
    shards: List[List[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for shard, part in zip(shards, np.split(idx, cuts)):
            shard.extend(part.tolist())
    out = []
    for shard in shards:
        if len(shard) < min_per_client:  # top up from the global pool
            extra = rng.integers(0, len(labels), min_per_client - len(shard))
            shard = shard + extra.tolist()
        out.append(np.asarray(shard))
    return out


class FederatedLoader:
    """Builds per-round (C, steps, B, ...) batch pytrees from client shards.

    Deterministic per (round, client): each client cycles its shard with a
    per-round shuffle, mirroring FEDn's one-epoch-per-round default.
    """

    def __init__(self, client_data: Sequence[Dict[str, np.ndarray]],
                 *, batch_size: int, steps_per_round: int, key: int = 0):
        self.client_data = list(client_data)
        self.batch_size = batch_size
        self.steps = steps_per_round
        self.key = key

    @property
    def n_clients(self) -> int:
        return len(self.client_data)

    def weights(self) -> np.ndarray:
        sizes = [len(next(iter(d.values()))) for d in self.client_data]
        return np.asarray(sizes, np.float32)

    def round_batches(self, rnd: int) -> Dict[str, np.ndarray]:
        return self.client_batches(rnd, range(self.n_clients))

    def client_batches(self, rnd: int,
                       client_ids: Sequence[int]) -> Dict[str, np.ndarray]:
        """Batches for a subset of clients: (len(ids), steps, B, ...).

        Each client's draw is a pure function of (key, round, client
        id), so a chunk of a sampled cohort gets bitwise the rows the
        full-fleet ``round_batches`` would have built — the cohort
        engine's loader contract (DESIGN.md §13), with host memory
        bounded by the chunk, not the fleet.
        """
        need = self.batch_size * self.steps
        per_client = []
        for ci in client_ids:
            ci = int(ci)
            data = self.client_data[ci]
            n = len(next(iter(data.values())))
            rng = np.random.default_rng((self.key, rnd, ci))
            idx = rng.permutation(n)
            if n < need:
                idx = np.concatenate(
                    [idx, rng.integers(0, n, need - n)])
            idx = idx[:need]
            per_client.append({k: v[idx].reshape(
                (self.steps, self.batch_size) + v.shape[1:])
                for k, v in data.items()})
        return {k: np.stack([pc[k] for pc in per_client])
                for k in per_client[0]}
