"""End-to-end driver: the paper's Experiment 1 (CIFAR-10 / VGG16) at
CPU scale — federated training for a few dozen rounds with comm
accounting, straggler dropout and checkpointing, all through the
``Federation`` facade.

    PYTHONPATH=src python examples/federated_vision.py \
        [--rounds 12] [--layers 7] [--clients 4] [--dropout 0.1] \
        [--topology hub|hierarchical|gossip] [--edges 2]

``--topology hierarchical`` demos edge aggregation: clients are grouped
under ``--edges`` edge aggregators and only per-edge partial aggregates
(the edge's selection union) cross the edge->hub WAN link, compounding
the paper's partial-update saving.
"""
import argparse
import functools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import Checkpointer, FLConfig, Federation, ModelSpec
from repro.data import FederatedLoader, cifar_like, iid_partition
from repro.models import paper_models as pm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--layers", type=int, default=7,
                    help="trained layers of 14 per client per round")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--width", type=float, default=0.125)
    ap.add_argument("--n-data", type=int, default=600)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--topology", default="hub",
                    choices=["hub", "hierarchical", "gossip"])
    ap.add_argument("--edges", type=int, default=None,
                    help="edge aggregators (hierarchical; default ~sqrt)")
    args = ap.parse_args()

    def loss_fn(p, batch):
        return pm.xent_loss(pm.vgg16_apply(p, batch["x"]), batch["y"]), {}

    spec = ModelSpec(
        name="vgg16",
        init_params=functools.partial(pm.init_vgg16,
                                      width_mult=args.width),
        loss_fn=loss_fn, unit_order=pm.vgg16_units)

    x_all, y_all = cifar_like(args.n_data + 256, key=0)
    x, y = x_all[:args.n_data], y_all[:args.n_data]
    xt = jnp.asarray(x_all[args.n_data:])
    yt = jnp.asarray(y_all[args.n_data:])
    shards = iid_partition(args.n_data, args.clients, key=1)
    loader = FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                             batch_size=16, steps_per_round=3)

    fl = FLConfig(n_clients=args.clients, n_train_units=args.layers,
                  lr=3e-3, topology=args.topology, n_edges=args.edges)
    fed = Federation.from_config(
        spec, fl,
        data=loader, dropout_rate=args.dropout,
        eval_fn=lambda p: pm.accuracy(pm.vgg16_apply(p, xt), yt),
        hooks=[Checkpointer(args.ckpt)] if args.ckpt else [])
    fed.fit(args.rounds, log_every=1)

    summ = fed.comm_summary()
    print(f"\ntrained {args.layers}/14 units per client per round "
          f"({args.topology} topology)")
    print(f"avg uplink/round: {summ['avg_uplink_bytes']/1e6:.1f} MB "
          f"(reduction vs full-model {args.topology}: "
          f"{summ['reduction_vs_full']:.1%})")
    if args.topology == "hierarchical":
        print(f"  {fl.resolve_n_edges()} edge aggregators: only per-edge "
              "selection unions cross the edge->hub WAN link")
    if args.ckpt:
        print(f"server state saved to {args.ckpt}")


if __name__ == "__main__":
    main()
