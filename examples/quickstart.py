"""Quickstart: the paper's technique through the Federation facade.

Federated training of a reduced qwen3-family LM across 4 clients where
each client trains a random HALF of the layers per round (the paper's
``uniform`` strategy), with participation-weighted FedAvg aggregation.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import get_config
from repro.core import FLConfig, Federation
from repro.data import iid_partition, lm_batch


def main():
    # a zoo architecture at smoke scale for this CPU host
    cfg = get_config("qwen3-1.7b").reduced()

    # synthetic LM data, IID across 4 clients
    data = lm_batch(128, 64, cfg.vocab, key=0)
    clients = [{k: v[s] for k, v in data.items()}
               for s in iid_partition(128, 4, key=1)]

    # the paper's round: each client trains HALF the units, randomly
    # re-drawn every round; aggregation averages only trained units
    fl = FLConfig(n_clients=4, train_fraction=0.5, lr=2e-3)
    fed = Federation.from_config(cfg, fl, data=clients,
                                 batch_size=4, steps_per_round=2)
    fed.fit(rounds=8, log_every=1)
    print(f"{cfg.name}: {fed.assign.n_units} freeze units; comm reduction "
          f"vs full-model FL: {fed.comm_summary()['reduction_vs_full']:.1%}")


if __name__ == "__main__":
    main()
