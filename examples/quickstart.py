"""Quickstart: the paper's technique in ~60 lines.

Federated training of a reduced qwen3-family LM across 4 clients where
each client trains a random HALF of the layers per round (the paper's
strategy), with participation-weighted FedAvg aggregation.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import FLConfig, build_round_step, build_units_zoo
from repro.data import FederatedLoader, iid_partition, lm_batch
from repro.models import get_model


def main():
    # 1. pick an architecture (any of the 10 assigned configs) and shrink
    #    it to smoke scale for this CPU host
    cfg = get_config("qwen3-1.7b").reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    # 2. freeze units = embedding + each layer + head (the paper's "layers")
    assign = build_units_zoo(cfg, params)
    print(f"{cfg.name}: {assign.n_units} freeze units "
          f"({', '.join(assign.unit_names)})")

    # 3. synthetic LM data, IID across 4 clients
    n, seq = 128, 64
    data = lm_batch(n, seq, cfg.vocab, key=0)
    shards = iid_partition(n, 4, key=1)
    loader = FederatedLoader([{k: v[s] for k, v in data.items()}
                              for s in shards],
                             batch_size=4, steps_per_round=2)

    # 4. the paper's round: each client trains HALF the units, randomly
    #    re-drawn every round; aggregation averages only trained units
    fl = FLConfig(n_clients=4, n_train_units=assign.n_units // 2, lr=2e-3)
    round_step = jax.jit(build_round_step(
        model.loss_fn, assign, fl, loss_kwargs={"attn_impl": "reference"}))

    weights = jnp.asarray(loader.weights())
    for r in range(8):
        batches = jax.tree_util.tree_map(jnp.asarray,
                                         loader.round_batches(r))
        params, metrics = round_step(params, batches, weights,
                                     jax.random.PRNGKey(100 + r))
        sel = metrics["sel"]
        print(f"round {r}: loss={float(metrics['loss_mean']):.4f} "
              f"(client0 trained units: "
              f"{[i for i, s in enumerate(sel[0]) if s]} )")


if __name__ == "__main__":
    main()
