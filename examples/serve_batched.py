"""Batched serving example: prefill + autoregressive decode with KV
caches (ring buffers on sliding-window layers, O(1) SSM states).

Runs three families to show the unified serving API:
  gemma3 (5:1 local:global ring caches), rwkv6 (state decode),
  hymba (hybrid attention+SSM).

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import get_model


def serve(arch: str, batch=2, prompt=24, gen=8):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    prompts = jax.random.randint(key, (batch, prompt), 0, cfg.vocab)
    kw = {"attn_impl": "reference"} if cfg.family != "ssm" else {}
    max_len = prompt + gen + 8

    t0 = time.time()
    logits, cache = jax.jit(lambda p, t: model.prefill(
        p, t, max_len=max_len, last_only=True, **kw))(params, prompts)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    toks = [tok]
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    out = jnp.concatenate(toks, axis=1)
    print(f"{arch:12s} [{cfg.family:6s}] prefill {batch}x{prompt} + "
          f"{gen} decode steps in {time.time()-t0:.1f}s -> "
          f"{out[0].tolist()}")


def main():
    for arch in ("gemma3-12b", "rwkv6-3b", "hymba-1.5b"):
        serve(arch)


if __name__ == "__main__":
    main()
