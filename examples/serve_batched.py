"""Batched serving example: the continuous-batching engine vs the static
loop on three families (DESIGN.md §12).

Each family submits 4 requests to a 2-slot paged engine — the engine
admits the second wave as the first finishes — then replays the same
prompts through the fixed-batch reference loop and checks the token
streams agree (greedy decode through the page pool is bitwise-equal to
the dense caches).

  gemma3 (5:1 local:global ring caches), rwkv6 (state decode),
  hymba (hybrid attention+SSM).

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import get_model
from repro.serve.engine import DecodeEngine, ServeConfig, static_generate


def serve(arch: str, n_req=4, slots=2, prompt=24, gen=8):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    prompts = np.asarray(jax.random.randint(key, (n_req, prompt), 0,
                                            cfg.vocab))

    eng = DecodeEngine(cfg, params, ServeConfig(
        n_slots=slots, max_len=prompt + gen + 8, page_size=16))
    for i in range(n_req):
        eng.submit(prompts[i], gen)
    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    st = eng.stats()

    # oracle: each admission wave as one static batch (same request ids)
    match = True
    for w in range(0, n_req, slots):
        ids = list(range(w, min(w + slots, n_req)))
        out = static_generate(cfg, params, jnp.asarray(prompts[ids]), gen,
                              max_len=eng.layout.max_len,
                              rids=np.asarray(ids))
        match &= all(np.array_equal(results[r], out[j])
                     for j, r in enumerate(ids))

    print(f"{arch:12s} [{cfg.family:6s}] {n_req} reqs x {slots} slots: "
          f"{st['total_tokens']} tokens in {dt:.1f}s, "
          f"{st['n_decode_steps']} decode steps, 1 decode compile "
          f"(cache={eng.decode_cache_size}), "
          f"matches static loop: {match} -> {results[0].tolist()}")
    if not match:
        raise SystemExit(f"{arch}: continuous != static")


def main():
    for arch in ("gemma3-12b", "rwkv6-3b", "hymba-1.5b"):
        serve(arch)


if __name__ == "__main__":
    main()
