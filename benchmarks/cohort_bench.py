"""Fleet-scale cohort engine: rounds/sec + host peak RSS vs registered
fleet size (DESIGN.md §13).

The vmapped baseline is the plain synchronous loop with the WHOLE fleet
as the cohort (``n_clients = R``): one width-R vmapped local-training
trace and an (R, steps, ...) batch pytree per round — the coupling of
fleet size to round cost that the cohort engine removes.  The cohort
rows run the chunk-streamed engine (``n_registered = R``, a fixed
16-client cohort streamed in 4-client chunks): per-registered-client
host state is three fleet-EMA scalars, and everything else is O(chunk).

Each (mode, R) row runs in its OWN subprocess (``--worker``):
``ru_maxrss`` is a process-lifetime high-water mark, so rows sharing a
process would all report the largest row's footprint.  Vmapped rows
beyond ``vmapped_max`` are recorded as skipped with the reason (a
width-10^5 vmap trace is neither compilable nor holdable on a host);
that boundary is itself the result.

Gates (what CI relies on): chunked == vmapped BITWISE at R == C
(in-process, both modes fed the identical batch tensor); cohort-mode
host RSS sub-linear in R (rss at the largest fleet <= 2x rss at the
smallest, vs the 100x fleet growth); cohort rounds/sec >= the vmapped
baseline's at the largest vmapped-runnable fleet.  Smoke mode records
the perf gates but only fails on the bitwise one (CI wall clocks and
RSS baselines are noisy); the full run (the committed artifact)
enforces all three.

Writes BENCH_cohort.json next to the other bench artifacts
(EXPERIMENTS.md §Scale).

    PYTHONPATH=src python -m benchmarks.cohort_bench [--smoke]
        [--out BENCH_cohort.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import resource
import subprocess
import sys
import time

import numpy as np

FULL = dict(n_blocks=4, d=16, hidden=16, out=4, steps=1, batch=2,
            cohort=16, chunk=4, rounds=4, train_fraction=0.5, lr=2e-2,
            registered=[1_000, 10_000, 100_000], vmapped_max=1_000,
            sampler_registered=[10_000, 100_000, 1_000_000])
SMOKE = dict(n_blocks=4, d=16, hidden=16, out=4, steps=1, batch=2,
             cohort=8, chunk=2, rounds=2, train_fraction=0.5, lr=2e-2,
             registered=[64, 256], vmapped_max=64,
             sampler_registered=[10_000, 100_000, 1_000_000])


def _np_batches(seed, rnd, ids, cfg):
    """Pure (seed, round, ids) -> batch rows; the host only ever holds
    len(ids) rows — the loader contract the engine's memory bound
    rests on."""
    ids = np.asarray(ids)
    rng = np.random.default_rng((seed, rnd, int(ids[0]), len(ids)))
    shape = (len(ids), cfg["steps"], cfg["batch"])
    return {"x": rng.normal(0, 1, shape + (cfg["d"],)).astype(np.float32),
            "y": rng.normal(0, 1, shape + (cfg["out"],)).astype(np.float32)}


def _federation(cfg, mode, registered, seed):
    import jax
    from repro.core import FLConfig, Federation
    from repro.models.toy import init_toy_mlp, toy_loss, toy_units
    params = init_toy_mlp(jax.random.PRNGKey(seed),
                          n_blocks=cfg["n_blocks"], d=cfg["d"],
                          hidden=cfg["hidden"], out=cfg["out"])
    assign = toy_units(params)
    kw = dict(train_fraction=cfg["train_fraction"], lr=cfg["lr"],
              packed=True, fused_agg="off")
    if mode == "vmapped":
        fl = FLConfig(n_clients=registered, **kw)
    else:
        fl = FLConfig(n_clients=cfg["cohort"], n_registered=registered,
                      cohort_chunk=cfg["chunk"], **kw)
    return Federation(loss_fn=toy_loss, params=params, assign=assign,
                      fl=fl, seed=seed)


def run_row(cfg, mode, registered, seed=0) -> dict:
    """One (mode, R) measurement — the --worker payload."""
    fed = _federation(cfg, mode, registered, seed)
    if mode == "vmapped":
        ids = np.arange(registered)
        bf = lambda r: _np_batches(seed, r, ids, cfg)  # noqa: E731
    else:
        bf = lambda r, ids: _np_batches(seed, r, ids, cfg)  # noqa: E731
    fed.server.run(1, bf)                   # compile + first-touch
    t0 = time.perf_counter()
    fed.server.run(cfg["rounds"], bf)
    dt = time.perf_counter() - t0
    return {"mode": mode, "registered": registered,
            "rounds_per_s": cfg["rounds"] / dt,
            "round_s": dt / cfg["rounds"],
            "peak_rss_mb": resource.getrusage(
                resource.RUSAGE_SELF).ru_maxrss / 1024.0,
            "loss": float(fed.history[-1].loss)}


def _spawn_row(cfg, mode, registered, seed=0) -> dict:
    spec = json.dumps({"cfg": cfg, "mode": mode,
                       "registered": registered, "seed": seed})
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.cohort_bench", "--worker",
         spec], capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"worker {mode}/R={registered} failed:\n"
                           f"{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def bitwise_gate(cfg, seed=0) -> bool:
    """R == C: the engine must reproduce the plain vmapped loop
    bit-for-bit on identical batches (the tentpole property, asserted
    here on the bench model/config as well as in tests/test_cohort.py)."""
    import jax
    c = cfg["cohort"]
    batches = _np_batches(seed, 0, np.arange(c), cfg)
    ref = _federation(cfg, "vmapped", c, seed)
    ref.server.run(2, lambda r: batches)
    eng = _federation(cfg, "cohort", c, seed)
    eng.server.run(2, lambda r, ids: jax.tree_util.tree_map(
        lambda x: x[np.asarray(ids)], batches))
    pa = jax.tree_util.tree_leaves(ref.server.params)
    pb = jax.tree_util.tree_leaves(eng.server.params)
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(pa, pb)) and \
        all(ra.loss == rb.loss for ra, rb in zip(ref.history, eng.history))


def sampler_latency_rows(cfg, seed=0) -> list:
    """Cohort-draw latency vs fleet size — the O(C) Floyd's-algorithm
    sampler satellite: a R = 10^6 fleet draw must cost host
    microseconds, flat in R, where the old ``permutation(key, R)`` path
    materialized (and sorted) a million-entry device array per round."""
    import jax
    from repro.core.cohort import _uniform_draw
    rows = []
    reps = 20
    for r in cfg["sampler_registered"]:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), r)
        _uniform_draw(key, r, cfg["cohort"])          # warm the jit bits
        t0 = time.perf_counter()
        for i in range(reps):
            _uniform_draw(jax.random.fold_in(key, i), r, cfg["cohort"])
        dt = (time.perf_counter() - t0) / reps
        rows.append({"registered": r, "cohort": cfg["cohort"],
                     "draw_ms": dt * 1e3})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale run (small fleets, fewer rounds)")
    ap.add_argument("--out", default="BENCH_cohort.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--worker", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.worker is not None:
        spec = json.loads(args.worker)
        print(json.dumps(run_row(spec["cfg"], spec["mode"],
                                 spec["registered"], spec["seed"])))
        return None

    cfg = SMOKE if args.smoke else FULL
    failures = []
    rows = []
    for r in cfg["registered"]:
        if r <= cfg["vmapped_max"]:
            rows.append(_spawn_row(cfg, "vmapped", r, args.seed))
        else:
            rows.append({"mode": "vmapped", "registered": r,
                         "skipped": f"width-{r} vmap is past the "
                                    "single-host envelope (the trace and "
                                    "the (R, steps, ...) batch pytree "
                                    "both scale with R)"})
        rows.append(_spawn_row(cfg, "cohort", r, args.seed))
        last = [x for x in rows if x["registered"] == r]
        print(" | ".join(
            f"{x['mode']} R={x['registered']}: " +
            (x["skipped"] if "skipped" in x else
             f"{x['rounds_per_s']:.2f} rounds/s "
             f"rss={x['peak_rss_mb']:.0f}MB") for x in last))

    bit_ok = bitwise_gate(cfg, args.seed)
    if not bit_ok:
        failures.append("chunked engine diverged bitwise from the "
                        "vmapped loop at R == C")

    sampler_rows = sampler_latency_rows(cfg, args.seed)
    sampler_1e6 = next((x["draw_ms"] for x in sampler_rows
                        if x["registered"] == 1_000_000), None)
    sampler_ok = sampler_1e6 is not None and sampler_1e6 <= 50.0
    for x in sampler_rows:
        print(f"sampler R={x['registered']}: {x['draw_ms']:.3f} ms/draw")
    if not args.smoke and not sampler_ok:
        failures.append(
            f"R=10^6 cohort draw took {sampler_1e6:.1f} ms "
            "(gate: <= 50 ms — the draw must stay O(cohort))")

    def _row(mode, r):
        return next(x for x in rows
                    if x["mode"] == mode and x["registered"] == r)

    co_small = _row("cohort", cfg["registered"][0])
    co_big = _row("cohort", cfg["registered"][-1])
    vm_max = _row("vmapped", cfg["vmapped_max"])
    co_at_vm = _row("cohort", cfg["vmapped_max"])
    fleet_growth = cfg["registered"][-1] / cfg["registered"][0]
    rss_ratio = co_big["peak_rss_mb"] / co_small["peak_rss_mb"]
    rss_sublinear = rss_ratio <= 2.0
    throughput_ok = co_at_vm["rounds_per_s"] >= vm_max["rounds_per_s"]
    if not args.smoke:
        if not rss_sublinear:
            failures.append(
                f"cohort host RSS grew {rss_ratio:.2f}x over a "
                f"{fleet_growth:.0f}x fleet (gate: <= 2x)")
        if not throughput_ok:
            failures.append(
                f"cohort rounds/s ({co_at_vm['rounds_per_s']:.2f}) fell "
                f"below the vmapped baseline "
                f"({vm_max['rounds_per_s']:.2f}) at "
                f"R={cfg['vmapped_max']}")

    import jax
    report = {
        "bench": "cohort",
        "mode": "smoke" if args.smoke else "full",
        "model": cfg,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "results": rows,
        "bitwise_chunked_eq_vmapped": bit_ok,
        "rss_ratio_largest_vs_smallest_fleet": rss_ratio,
        "fleet_growth": fleet_growth,
        "rss_sublinear": rss_sublinear,
        "cohort_rounds_per_s_at_vmapped_max": co_at_vm["rounds_per_s"],
        "vmapped_rounds_per_s_at_max": vm_max["rounds_per_s"],
        "throughput_ok": throughput_ok,
        "sampler_latency": sampler_rows,
        "sampler_draw_ms_at_1e6": sampler_1e6,
        "sampler_ok": sampler_ok,
    }
    report["sanity_ok"] = not failures
    import os
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if failures:
        raise SystemExit("cohort bench gates FAILED: " +
                         "; ".join(failures))
    return report


if __name__ == "__main__":
    main()
