"""Render the roofline table from results/dryrun/*.json (EXPERIMENTS.md
§Roofline source)."""
from __future__ import annotations

import glob
import json
import os

from .common import csv_row

COLS = ("arch", "shape", "mesh", "step", "layout")


def load_records(path: str = "results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(fast: bool = True, path: str = "results/dryrun"):
    recs = load_records(path)
    done = [r for r in recs if not r.get("skipped") and "roofline" in r]
    skipped = [r for r in recs if r.get("skipped")]
    print("# Roofline table (per-device terms, TPU v5e constants)")
    print("# arch, shape, mesh, step, layout, compute_ms, memory_ms, "
          "collective_ms, dominant, useful_flop_ratio, peak_GB, fits16GB")
    for r in sorted(done, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['step']},"
              f"{r['layout']},{t['compute_s']*1e3:.2f},"
              f"{t['memory_s']*1e3:.2f},{t['collective_s']*1e3:.2f},"
              f"{t['dominant']},{(r.get('useful_flops_ratio') or 0):.3f},"
              f"{r['bytes_per_device']/1e9:.2f},{r['fits_hbm_16gb']}")
    for r in skipped:
        print(f"{r['arch']},{r['shape']},-,-,-,-,-,-,SKIPPED({r['reason']})"
              .replace("\n", " "))
    doms = {}
    for r in done:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    csv_row("roofline_table", 0.0,
            f"records={len(done)} skipped={len(skipped)} dominants={doms}")


if __name__ == "__main__":
    run()
