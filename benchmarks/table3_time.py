"""Paper Table 3 / Figs 8-9: training time vs number of trained layers.

In JAX the paper's compute saving is realized by STATIC freeze masks:
the frozen units' backward is dead-code-eliminated at compile time.  We
measure (a) wall-clock per local step and (b) compiled backward FLOPs
(cost_analysis), for 4/7/10/14 trained VGG16 units — the static
counterpart of the dynamic in-round masking (DESIGN.md §2).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import cifar_like
from repro.models import paper_models as pm
from repro.optim.masked import adam_init, adam_step
from .common import csv_row, timed


def make_static_step(params, trainable, batch_shape):
    frozen = {k: v for k, v in params.items() if k not in trainable}

    def step(train_p, opt, batch):
        def loss_fn(tp):
            merged = dict(frozen)
            merged.update(tp)
            return pm.xent_loss(pm.vgg16_apply(merged, batch["x"]),
                                batch["y"])

        loss, grads = jax.value_and_grad(loss_fn)(train_p)
        train_p, opt = adam_step(grads, opt, train_p, lr=1e-3)
        return train_p, opt, loss

    return jax.jit(step)


def run(fast: bool = True):
    t0 = time.perf_counter()
    width = 0.125 if fast else 0.5
    bs = 8 if fast else 32
    params = pm.init_vgg16(jax.random.PRNGKey(0), width_mult=width)
    units = pm.vgg16_units(params)
    x, y = cifar_like(bs, key=0)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    print(f"# Table 3 / Fig 9 reproduction (static-freeze VGG16 w={width}, "
          f"batch {bs})")
    print("# layers, step_ms, bwd+fwd GFLOPs(compiled), flops_vs_full")
    rows = {}
    for n in (4, 7, 10, 14):
        trainable = units[-n:]          # paper trains a subset; use last-n
        train_p = {k: params[k] for k in trainable}
        step = make_static_step(params, trainable, batch)
        opt = adam_init(train_p)
        lowered = step.lower(train_p, opt, batch)
        comp = lowered.compile()
        ca = comp.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        fl = float(ca.get("flops", 0.0))
        dt, _ = timed(lambda tp=train_p, o=opt: step(tp, o, batch),
                      reps=2 if fast else 5)
        rows[n] = (dt, fl)
    flops_full = rows[14][1]
    for n in (4, 7, 10, 14):
        dt, fl = rows[n]
        print(f"{n},{dt*1e3:.1f},{fl/1e9:.2f},{fl/flops_full:.3f}")
    # paper: 4 layers saves ~19% of the 100-round time vs 14 layers
    saving = 1 - rows[4][0] / rows[14][0]
    csv_row("table3_time", rows[14][0] * 1e6,
            f"time_saving_4_vs_14_layers={saving:.2%} "
            f"flops_saving={1 - rows[4][1]/rows[14][1]:.2%}")


if __name__ == "__main__":
    run()
