"""Paper Figs 5-7: scaling clients vs trained layers (fixed data).

Claim reproduced: more clients with fewer trained layers reaches the
accuracy of fewer clients training the full model (negative correlation
between client count and required layers)."""
from __future__ import annotations

import time

from .common import csv_row, make_vgg_federation, run_rounds


def run(fast: bool = True):
    t0 = time.perf_counter()
    rounds = 5 if fast else 30
    n_data = 400 if fast else 4000
    settings = [
        # (clients, layers) — paper: (10, 14) vs (20, 7) same data
        (4, 14), (8, 7)] if fast else [(10, 14), (20, 7), (20, 10), (5, 7)]
    print(f"# Fig 5-7 reproduction (fixed {n_data} samples, {rounds} "
          "rounds)")
    print("# clients, layers, final_acc, acc_history")
    finals = {}
    for c, n in settings:
        srv, loader, _ = make_vgg_federation(c, n, n_data=n_data,
                                             width=0.125, lr=3e-3,
                                             steps_per_round=3,
                                             batch_size=16)
        hist = run_rounds(srv, rounds)
        accs = [h.eval_metric for h in hist]
        finals[(c, n)] = accs[-1]
        print(f"{c},{n},{accs[-1]:.3f}," + "|".join(
            f"{a:.3f}" for a in accs))
    (c1, n1), (c2, n2) = settings[0], settings[1]
    gap = finals[(c1, n1)] - finals[(c2, n2)]
    csv_row("fig5_scaling", (time.perf_counter() - t0) * 1e6,
            f"full@{c1}cl_minus_half@{c2}cl={gap:+.3f} (paper: ~-0.002)")


if __name__ == "__main__":
    run()
