"""Benchmark harness: one runner per paper table/figure + kernel benches
+ the roofline table.  ``python -m benchmarks.run [--full] [--only name]``.

Prints ``name,us_per_call,derived`` CSV summary lines (prefixed rows are
the per-table data)."""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (hours on this CPU)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    fast = not args.full

    from . import (fig2_accuracy, fig3_casa_imdb, fig4_distribution,
                   fig5_scaling, kernels_bench, roofline_table,
                   table3_time, table4_comm, table5_resources)
    benches = [
        ("table4_comm", table4_comm.run),
        ("fig4_distribution", fig4_distribution.run),
        ("table3_time", table3_time.run),
        ("table5_resources", table5_resources.run),
        ("fig2_accuracy", fig2_accuracy.run),
        ("fig3_casa_imdb", fig3_casa_imdb.run),
        ("fig5_scaling", fig5_scaling.run),
        ("kernels_bench", kernels_bench.run),
        ("roofline_table", roofline_table.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only != name:
            continue
        print(f"\n### {name} " + "#" * (60 - len(name)))
        t0 = time.time()
        try:
            fn(fast=fast)
        except Exception:
            failures += 1
            print(f"{name},nan,FAILED")
            traceback.print_exc()
        print(f"### {name} done in {time.time()-t0:.1f}s")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
