"""Sync vs. FedBuff-buffered rounds: wall-clock-to-accuracy (DESIGN.md §8).

Runs the paper's VGG16 (reduced width) on CIFAR-shaped data at the
paper's 25%/50% freeze settings, twice per setting:

* **sync** — the synchronous packed round loop.  A synchronous server
  waits for its slowest client, so a round's simulated wall-clock is
  ``max_c delay(c, round)`` under the same seeded delay model the async
  scheduler uses.
* **buffered** — ``FLConfig.async_buffer`` FedBuff rounds under the same
  heavy-tailed (Pareto) per-client delays: the server flushes every B
  buffered packed updates and never waits for the tail.

Per variant the bench records the (simulated time, eval accuracy) curve
and the time to reach a shared target accuracy; "wall-clock" is the
*simulated* scheduler clock — host compute time is meaningless for a
latency simulation (the simulator deliberately over-computes cohorts to
keep flushes bit-comparable with sync rounds, see core/async_agg.py).

Writes BENCH_async.json next to BENCH_round_step.json (EXPERIMENTS.md
§Perf).  ``--smoke`` is the CI-gate variant (tiny data, fewer rounds,
same JSON shape).

    PYTHONPATH=src python -m benchmarks.async_bench [--smoke]
        [--out BENCH_async.json] [--delay-dist pareto:1.2]
"""
from __future__ import annotations

import argparse
import functools
import json
import platform

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FLConfig, Federation, ModelSpec
from repro.core.async_agg import DelayScheduler
from repro.data import FederatedLoader, cifar_like, iid_partition
from repro.models import paper_models as pm

# full-mode scale is bounded by the simulator's deliberate cohort
# over-compute (one width-C cohort step per dispatch, see
# core/async_agg.py): a buffered run costs ~buffer x the sync run's
# host time, so the committed trajectory point stays CPU-host-sized
FULL = dict(n_clients=8, rounds=8, buffer=4, width=0.125, n_data=256,
            n_eval=128, batch=4, steps=2, lr=2e-3)
SMOKE = dict(n_clients=4, rounds=5, buffer=2, width=0.125, n_data=128,
             n_eval=64, batch=4, steps=2, lr=2e-3)


def vgg_loss(p, batch):
    return pm.xent_loss(pm.vgg16_apply(p, batch["x"]), batch["y"]), {}


def _setup(cfg, seed=0):
    spec = ModelSpec(
        name="vgg16",
        init_params=functools.partial(pm.init_vgg16,
                                      width_mult=cfg["width"]),
        loss_fn=vgg_loss, unit_order=pm.vgg16_units)
    x, y = cifar_like(cfg["n_data"], key=0)
    shards = iid_partition(cfg["n_data"], cfg["n_clients"], key=1)
    loader = FederatedLoader([{"x": x[s], "y": y[s]} for s in shards],
                             batch_size=cfg["batch"],
                             steps_per_round=cfg["steps"])
    ex, ey = cifar_like(cfg["n_eval"], key=7)
    ex, ey = jnp.asarray(ex), jnp.asarray(ey)

    @jax.jit
    def accuracy(params):
        return (pm.vgg16_apply(params, ex).argmax(-1) == ey).mean()

    return spec, loader, accuracy


def run_variant(cfg, *, fraction, delay_dist, buffer, seed=0) -> dict:
    """One (freeze fraction, sync-or-buffered) training curve."""
    spec, loader, accuracy = _setup(cfg, seed)
    is_async = buffer > 0
    fl = FLConfig(n_clients=cfg["n_clients"], train_fraction=fraction,
                  lr=cfg["lr"], fused_agg="off",
                  packed=not is_async,           # async is packed by design
                  async_buffer=buffer, client_delay_dist=delay_dist)
    fed = Federation.from_config(spec, fl, data=loader, seed=seed,
                                 eval_fn=accuracy)
    if is_async:
        # B buffered updates per flush: match the sync run's total
        # client work (rounds x C updates)
        flushes = cfg["rounds"] * cfg["n_clients"] // buffer
        fed.fit(flushes)
        times = [r.sim_time for r in fed.history]
        stale = [r.staleness_mean for r in fed.history]
    else:
        fed.fit(cfg["rounds"])
        # a synchronous server waits for its slowest client each round
        sched = DelayScheduler(delay_dist, seed=seed)
        per_round = [max(sched.delay(c, r)
                         for c in range(cfg["n_clients"]))
                     for r in range(cfg["rounds"])]
        times = list(np.cumsum(per_round))
        stale = [0.0] * cfg["rounds"]
    accs = [r.eval_metric for r in fed.history]
    return {"times": [float(t) for t in times],
            "accs": [float(a) for a in accs],
            "final_acc": float(accs[-1]),
            "staleness_mean": float(np.mean(stale)),
            "comm": fed.comm_summary()}


def time_to_target(times, accs, target):
    for t, a in zip(times, accs):
        if a >= target:
            return float(t)
    return None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale run (tiny model/data, fewer rounds)")
    ap.add_argument("--out", default="BENCH_async.json")
    ap.add_argument("--fractions", type=float, nargs="+",
                    default=[0.25, 0.50])
    ap.add_argument("--delay-dist", default="pareto:1.2",
                    help="heavy-tailed straggler regime by default")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    cfg = SMOKE if args.smoke else FULL

    results, failures = {}, []
    for frac in args.fractions:
        sync = run_variant(cfg, fraction=frac,
                           delay_dist=args.delay_dist, buffer=0,
                           seed=args.seed)
        buf = run_variant(cfg, fraction=frac,
                          delay_dist=args.delay_dist,
                          buffer=cfg["buffer"], seed=args.seed)
        # shared target: just under the weaker variant's best accuracy,
        # so both curves can reach it and the race is on wall-clock
        target = 0.98 * min(max(sync["accs"]), max(buf["accs"]))
        t_sync = time_to_target(sync["times"], sync["accs"], target)
        t_buf = time_to_target(buf["times"], buf["accs"], target)
        row = {"sync": sync, "buffered": buf, "target_acc": float(target),
               "t_sync": t_sync, "t_buffered": t_buf,
               "speedup": (t_sync / t_buf)
               if t_sync and t_buf else None}
        results[f"{frac:.2f}"] = row
        print(f"frac={frac:.2f} target={target:.3f} "
              f"t_sync={t_sync} t_buffered={t_buf} "
              f"speedup={row['speedup']} "
              f"avg_staleness={buf['staleness_mean']:.2f}")
        # sanity gates (what CI relies on): both variants learned and
        # the async run actually exercised out-of-order/stale updates
        if not all(np.isfinite(sync["accs"])) or \
                not all(np.isfinite(buf["accs"])):
            failures.append(f"non-finite accuracy at frac={frac}")
        if buf["staleness_mean"] <= 0.0:
            failures.append(f"no staleness observed at frac={frac}")

    report = {
        "bench": "async",
        "mode": "smoke" if args.smoke else "full",
        "model": cfg,
        "delay_dist": args.delay_dist,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "results": results,
        "sanity_ok": not failures,
    }
    at25 = results.get("0.25")
    if at25 is not None and at25["speedup"] is not None:
        report["buffered_wins_time_at_25"] = at25["speedup"] > 1.0
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if failures:
        raise SystemExit("async bench sanity FAILED: " +
                         "; ".join(failures))
    return report


if __name__ == "__main__":
    main()
